//! CLI: `cargo run -p repro-lint -- [root]` (default `rust/src`).
//!
//! Exit codes: 0 clean, 1 violations, 2 usage/IO error — ci.sh treats
//! any non-zero as a failed lint stage.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let root = args.next().unwrap_or_else(|| "rust/src".to_string());
    if root == "-h" || root == "--help" || args.next().is_some() {
        eprintln!("usage: repro-lint [root-dir]   (default: rust/src)");
        return ExitCode::from(2);
    }
    match repro_lint::run(Path::new(&root)) {
        Ok(report) => {
            for v in &report.violations {
                eprintln!("{v}");
            }
            if report.is_clean() {
                println!("repro-lint: {} files clean under {root}", report.files);
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "repro-lint: {} violation(s) across {} files — see README \
                     \"Static analysis\"",
                    report.violations.len(),
                    report.files
                );
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("repro-lint: cannot scan {root}: {e}");
            ExitCode::from(2)
        }
    }
}
