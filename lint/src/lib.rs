//! repro-lint — determinism-contract static analysis for the
//! Mem-AOP-GD tree (README "Static analysis").
//!
//! The reproduction's auditability story rests on invariants no
//! compiler checks: RNG stream domains must never collide (R1), the
//! step hot path must not read clocks, allocate, or hash (R2),
//! wire-visible iteration must be explicitly ordered (R3), every
//! `unsafe` must carry a `// SAFETY:` argument (R4), and every
//! exported `repro_*` metric name must come from one registry (R5).
//! This crate enforces all five as hard CI failures.
//!
//! It is deliberately **lexical**, not syntactic: the build
//! environment is offline (no syn), and every rule here is about
//! tokens-in-files, not type information. A small state machine
//! ([`lex`]) strips comments, blanks string/char literals out of the
//! code channel (recording string contents separately for R5), tracks
//! `#[cfg(test)]` regions by brace matching, and parses the
//! allow-escape grammar:
//!
//! ```text
//! // lint: allow(<rule-id>) <mandatory reason>
//! ```
//!
//! A comment-only allow line escapes the next code line; a trailing
//! comment escapes its own line. An allow without a reason is itself
//! a violation (`allow-syntax`) — escapes are part of the audit
//! trail, not a mute button.
//!
//! Known heuristic edges, documented rather than hidden:
//!
//! * R2's `.clone()` check exempts receivers named `rows`/`range` (or
//!   ending `_rows`/`_range`) — cloning a `Range` is a stack copy, and
//!   flooding the shard code with escapes would teach people to paste
//!   them.
//! * R4 accepts any comment containing "safety" (case-insensitive) on
//!   the same line or within the 8 preceding lines, so `# Safety` doc
//!   sections and one comment covering a short cluster of unsafe
//!   blocks both count.
//! * R1 skips `#[cfg(test)]` regions and the registry file itself
//!   (`tensor/rng.rs`) — tests there exercise raw stream keys on
//!   purpose.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Rule identifiers, as written in allow escapes and printed reports.
pub mod rules {
    pub const RNG_DOMAIN: &str = "rng-domain";
    pub const HOT_PATH_CLOCK: &str = "hot-path-clock";
    pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
    pub const HOT_PATH_HASH: &str = "hot-path-hash";
    pub const WIRE_ORDER: &str = "wire-order";
    pub const SAFETY_COMMENT: &str = "safety-comment";
    pub const METRIC_NAME: &str = "metric-name";
    pub const ALLOW_SYNTAX: &str = "allow-syntax";

    /// Every rule an allow escape may name.
    pub const ALL: &[&str] = &[
        RNG_DOMAIN,
        HOT_PATH_CLOCK,
        HOT_PATH_ALLOC,
        HOT_PATH_HASH,
        WIRE_ORDER,
        SAFETY_COMMENT,
        METRIC_NAME,
    ];
}

/// Which files each path-scoped rule applies to, matched by `/`-path
/// suffix against the path relative to the scanned root.
#[derive(Debug, Clone)]
pub struct Config {
    /// R2 hot-path purity files.
    pub hot_paths: Vec<&'static str>,
    /// R3 wire-rendering files.
    pub wire_paths: Vec<&'static str>,
    /// R4 SAFETY-coverage files.
    pub safety_paths: Vec<&'static str>,
    /// R1 stream-domain registry (also the one file exempt from R1).
    pub registry_path: &'static str,
    /// R5 metric-family registry.
    pub metrics_path: &'static str,
}

impl Config {
    /// The repository's contract, mirroring README "Static analysis".
    pub fn repo_default() -> Config {
        Config {
            hot_paths: vec![
                "train/step.rs",
                "exec/shard.rs",
                "tensor/ops.rs",
                "tensor/quant.rs",
                "aop/policy.rs",
            ],
            wire_paths: vec!["serve/handlers.rs"],
            safety_paths: vec![
                "exec/pool.rs",
                "exec/shard.rs",
                "train/graph.rs",
                "train/step.rs",
            ],
            registry_path: "tensor/rng.rs",
            metrics_path: "obs/prom.rs",
        }
    }
}

/// One source line after lexing: the code channel (comments stripped,
/// string/char contents blanked), the comment channel, the string
/// literals that *start* on this line, and test-region membership.
#[derive(Debug, Default, Clone)]
pub struct Line {
    pub num: usize,
    pub code: String,
    pub comment: String,
    pub strings: Vec<String>,
    pub in_test: bool,
}

/// A lexed file: normalized relative path + lines + per-line effective
/// allow escapes (rule-id sets).
#[derive(Debug)]
pub struct FileLex {
    pub path: String,
    pub lines: Vec<Line>,
    pub allows: Vec<BTreeSet<String>>,
}

impl FileLex {
    fn allowed(&self, idx: usize, rule: &str) -> bool {
        self.allows.get(idx).is_some_and(|s| s.contains(rule))
    }
}

/// One finding. Sorted by (file, line, rule) in the report.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A full run over one tree.
#[derive(Debug)]
pub struct Report {
    pub files: usize,
    pub violations: Vec<Violation>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// Lex Rust source into per-line channels. Handles line/nested block
/// comments, plain and raw strings (`r"…"`, `r#"…"#`, byte variants),
/// char literals vs lifetimes, and multi-line strings (contents attach
/// to the starting line).
pub fn lex(src: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        CharLit,
    }

    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line { num: 1, ..Line::default() };
    // (line index the string started on, contents so far)
    let mut str_buf: Option<(usize, String)> = None;
    let mut side_strings: Vec<(usize, String)> = Vec::new();
    let mut st = St::Code;
    let mut i = 0usize;

    macro_rules! flush_line {
        () => {{
            let num = cur.num;
            lines.push(std::mem::take(&mut cur));
            cur.num = num + 1;
        }};
    }

    macro_rules! peek {
        ($k:expr) => {
            chars.get(i + $k).copied()
        };
    }

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            if let Some((_, buf)) = str_buf.as_mut() {
                buf.push('\n');
            }
            flush_line!();
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && peek!(1) == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && peek!(1) == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push_str("\"\"");
                    str_buf = Some((lines.len(), String::new()));
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && !prev_is_ident(&chars, i)
                    && raw_str_hashes(&chars, i).is_some()
                {
                    let (skip, hashes) = raw_str_hashes(&chars, i).unwrap();
                    st = St::RawStr(hashes);
                    cur.code.push_str("\"\"");
                    str_buf = Some((lines.len(), String::new()));
                    i += skip;
                } else if c == 'b' && peek!(1) == Some('"') && !prev_is_ident(&chars, i) {
                    st = St::Str;
                    cur.code.push_str("\"\"");
                    str_buf = Some((lines.len(), String::new()));
                    i += 2;
                } else if c == '\'' {
                    // Lifetime vs char literal: `'a` followed by
                    // neither `'` nor an escape is a lifetime.
                    let is_char = match peek!(1) {
                        Some('\\') => true,
                        Some(_) => peek!(2) == Some('\''),
                        None => false,
                    };
                    if is_char {
                        st = St::CharLit;
                        cur.code.push_str("' '");
                        i += 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '*' && peek!(1) == Some('/') {
                    if depth == 1 {
                        st = St::Code;
                    } else {
                        st = St::BlockComment(depth - 1);
                    }
                    i += 2;
                } else if c == '/' && peek!(1) == Some('*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if let Some((_, buf)) = str_buf.as_mut() {
                        buf.push(c);
                        if let Some(n) = peek!(1) {
                            buf.push(n);
                        }
                    }
                    i += 2;
                } else if c == '"' {
                    if let Some((start, buf)) = str_buf.take() {
                        side_strings.push((start, buf));
                    }
                    st = St::Code;
                    i += 1;
                } else {
                    if let Some((_, buf)) = str_buf.as_mut() {
                        buf.push(c);
                    }
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && hashes_follow(&chars, i + 1, hashes) {
                    if let Some((start, buf)) = str_buf.take() {
                        side_strings.push((start, buf));
                    }
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    if let Some((_, buf)) = str_buf.as_mut() {
                        buf.push(c);
                    }
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    flush_line!();

    for (idx, s) in side_strings {
        if let Some(line) = lines.get_mut(idx) {
            line.strings.push(s);
        }
    }
    mark_tests(&mut lines);
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// If `chars[i..]` opens a raw string (`r"`, `r#"`, `br"`, …), return
/// (chars to skip past the opening quote, hash count).
fn raw_str_hashes(chars: &[char], i: usize) -> Option<(usize, u32)> {
    let mut j = i + 1;
    if chars[i] == 'b' {
        if chars.get(j) != Some(&'r') {
            return None;
        }
        j += 1;
    }
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

fn hashes_follow(chars: &[char], from: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(from + k) == Some(&'#'))
}

/// Mark every line inside a `#[cfg(test)] { … }` region by brace
/// matching on the code channel (strings are already blanked, so
/// braces in literals cannot desync the depth count).
fn mark_tests(lines: &mut [Line]) {
    let mut depth: i32 = 0;
    let mut close_at: Vec<i32> = Vec::new();
    let mut pending = false;
    for line in lines.iter_mut() {
        let mut active = !close_at.is_empty();
        if line.code.contains("#[cfg(test)]") {
            pending = true;
            active = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending {
                        close_at.push(depth);
                        pending = false;
                        active = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if close_at.last() == Some(&depth) {
                        close_at.pop();
                    }
                }
                _ => {}
            }
        }
        line.in_test = active || pending;
    }
}

// ---------------------------------------------------------------------------
// Allow escapes
// ---------------------------------------------------------------------------

const ALLOW_MARKER: &str = "lint: allow(";

/// Parse the escapes in one line's comment channel. Returns
/// `(rule, has_reason)` pairs.
fn parse_allows(comment: &str) -> Vec<(String, bool)> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(ALLOW_MARKER) {
        let after = &rest[pos + ALLOW_MARKER.len()..];
        let Some(close) = after.find(')') else {
            out.push((String::from("?"), false));
            break;
        };
        let rule = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let reason_end = tail.find(ALLOW_MARKER).unwrap_or(tail.len());
        let has_reason = !tail[..reason_end].trim().is_empty();
        out.push((rule, has_reason));
        rest = &tail[reason_end..];
    }
    out
}

/// Build per-line effective allow sets and report malformed escapes.
fn build_allows(path: &str, lines: &[Line], out: &mut Vec<Violation>) -> Vec<BTreeSet<String>> {
    let mut allows: Vec<BTreeSet<String>> = vec![BTreeSet::new(); lines.len()];
    let mut carry: BTreeSet<String> = BTreeSet::new();
    for (idx, line) in lines.iter().enumerate() {
        let mut here: BTreeSet<String> = BTreeSet::new();
        for (rule, has_reason) in parse_allows(&line.comment) {
            if !has_reason {
                out.push(Violation {
                    file: path.to_string(),
                    line: line.num,
                    rule: rules::ALLOW_SYNTAX,
                    msg: format!(
                        "allow({rule}) needs a reason: `// lint: allow({rule}) <why>`"
                    ),
                });
                continue;
            }
            if !rules::ALL.contains(&rule.as_str()) {
                out.push(Violation {
                    file: path.to_string(),
                    line: line.num,
                    rule: rules::ALLOW_SYNTAX,
                    msg: format!(
                        "allow({rule}) names no known rule (known: {})",
                        rules::ALL.join(", ")
                    ),
                });
                continue;
            }
            here.insert(rule);
        }
        if line.code.trim().is_empty() {
            // Comment-only line: escapes apply to the next code line.
            carry.extend(here);
        } else {
            let mut eff = std::mem::take(&mut carry);
            eff.extend(here);
            allows[idx] = eff;
        }
    }
    allows
}

// ---------------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------------

fn walk(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(&dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

fn path_matches(rel: &str, suffix: &str) -> bool {
    rel == suffix || rel.ends_with(&format!("/{suffix}"))
}

// ---------------------------------------------------------------------------
// R1: RNG stream-domain registry
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct DomainRegistry {
    /// (name, parsed literal value if it was one, defining line)
    entries: Vec<(String, Option<u64>, usize)>,
}

impl DomainRegistry {
    fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|(n, _, _)| n == name)
    }
}

fn parse_u64_literal(s: &str) -> Option<u64> {
    let t: String = s.trim().chars().filter(|&c| c != '_').collect();
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        t.parse().ok()
    }
}

/// Extract `pub const NAME: u64 = <literal>;` entries from the
/// `pub mod domains { … }` region of the registry file.
fn parse_domain_registry(f: &FileLex) -> DomainRegistry {
    let mut reg = DomainRegistry::default();
    let mut depth_opened: Option<i32> = None;
    let mut depth: i32 = 0;
    let mut pending_mod = false;
    for line in &f.lines {
        if line.code.contains("pub mod domains") {
            pending_mod = true;
        }
        let inside = depth_opened.is_some();
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending_mod && depth_opened.is_none() {
                        depth_opened = Some(depth);
                        pending_mod = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if depth_opened == Some(depth) {
                        depth_opened = None;
                    }
                }
                _ => {}
            }
        }
        if !inside {
            continue;
        }
        let code = line.code.trim();
        if let Some(rest) = code.strip_prefix("pub const ") {
            let Some((name, tail)) = rest.split_once(':') else { continue };
            let name = name.trim();
            if !tail.trim_start().starts_with("u64") {
                continue;
            }
            let value = tail
                .split_once('=')
                .and_then(|(_, v)| v.split(';').next().map(str::trim))
                .and_then(parse_u64_literal);
            reg.entries.push((name.to_string(), value, line.num));
        }
    }
    reg
}

fn check_registry_unique(path: &str, reg: &DomainRegistry, out: &mut Vec<Violation>) {
    let mut seen: BTreeMap<u64, &str> = BTreeMap::new();
    for (name, value, num) in &reg.entries {
        let Some(v) = value else { continue };
        if let Some(prev) = seen.get(v) {
            out.push(Violation {
                file: path.to_string(),
                line: *num,
                rule: rules::RNG_DOMAIN,
                msg: format!(
                    "domain {name} reuses stream key {v:#x} already taken by {prev} — \
                     colliding domains would draw correlated streams"
                ),
            });
        } else {
            seen.insert(*v, name);
        }
    }
}

/// Extract the first argument of a `for_stream(` call starting at
/// (line idx, byte offset just past the open paren), following
/// continuation lines.
fn first_arg(lines: &[Line], start: usize, from: usize) -> String {
    let mut depth = 0i32;
    let mut arg = String::new();
    for (k, line) in lines.iter().enumerate().skip(start) {
        let code: &str = if k == start { &line.code[from..] } else { &line.code };
        for c in code.chars() {
            match c {
                '(' | '[' => depth += 1,
                ')' | ']' if depth == 0 => return arg,
                ')' | ']' => depth -= 1,
                ',' if depth == 0 => return arg,
                _ => arg.push(c),
            }
        }
        arg.push(' ');
    }
    arg
}

fn is_screaming_const(tok: &str) -> bool {
    tok.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
        && tok.chars().any(|c| c.is_ascii_uppercase())
}

fn check_rng_domains(f: &FileLex, reg: &DomainRegistry, out: &mut Vec<Violation>) {
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test || f.allowed(idx, rules::RNG_DOMAIN) {
            continue;
        }
        // Domain-tag constants may only be declared in the registry.
        let code = line.code.trim();
        if (code.contains("const STREAM_") || code.contains("const FLT_"))
            && code.contains('=')
        {
            out.push(Violation {
                file: f.path.clone(),
                line: line.num,
                rule: rules::RNG_DOMAIN,
                msg: "stream-domain constants live in tensor::rng::domains, \
                      not in per-module consts (collision check needs one table)"
                    .to_string(),
            });
        }
        let mut search = 0usize;
        while let Some(pos) = line.code[search..].find("for_stream(") {
            let open = search + pos + "for_stream(".len();
            let arg = first_arg(&f.lines, idx, open);
            check_stream_key_expr(f, line.num, &arg, reg, out);
            search = open;
        }
    }
}

/// Validate one seed-key expression (`cfg.seed ^ STREAM_POLICY`, …):
/// no bare numeric literals, and every SCREAMING_CASE operand must be
/// a registered domain.
fn check_stream_key_expr(
    f: &FileLex,
    num: usize,
    arg: &str,
    reg: &DomainRegistry,
    out: &mut Vec<Violation>,
) {
    for operand in arg.split('^') {
        let operand = operand.trim();
        if operand.is_empty() {
            continue;
        }
        let last_seg = operand.rsplit("::").next().unwrap_or(operand).trim();
        let tok: String = last_seg
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if tok.is_empty() {
            continue;
        }
        if tok.starts_with(|c: char| c.is_ascii_digit()) {
            out.push(Violation {
                file: f.path.clone(),
                line: num,
                rule: rules::RNG_DOMAIN,
                msg: format!(
                    "bare stream key `{tok}` in for_stream — name it in \
                     tensor::rng::domains so collisions are checked"
                ),
            });
        } else if is_screaming_const(&tok) && !reg.contains(&tok) {
            out.push(Violation {
                file: f.path.clone(),
                line: num,
                rule: rules::RNG_DOMAIN,
                msg: format!(
                    "stream domain `{tok}` is not registered in tensor::rng::domains"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R2: hot-path purity
// ---------------------------------------------------------------------------

/// Receivers whose `.clone()` is a stack copy (`Range`), exempted to
/// keep the shard code free of boilerplate escapes.
fn clone_receiver_exempt(recv: &str) -> bool {
    recv == "rows" || recv == "range" || recv.ends_with("_rows") || recv.ends_with("_range")
}

fn check_hot_path(f: &FileLex, out: &mut Vec<Violation>) {
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut flag = |rule: &'static str, what: &str| {
            if !f.allowed(idx, rule) {
                out.push(Violation {
                    file: f.path.clone(),
                    line: line.num,
                    rule,
                    msg: format!("{what} on a hot path (escape: `// lint: allow({rule}) <why>`)"),
                });
            }
        };
        if code.contains("Instant::now") || code.contains("SystemTime::now") {
            flag(rules::HOT_PATH_CLOCK, "clock read");
        }
        if contains_word(code, "HashMap") || contains_word(code, "HashSet") {
            flag(rules::HOT_PATH_HASH, "randomized-order hash collection");
        }
        let alloc_tokens =
            ["Vec::new(", "vec!", ".to_vec()", ".collect()", ".collect::<", "format!", "Box::new("];
        for pat in alloc_tokens {
            if code.contains(pat) {
                let what = format!("allocation (`{}`)", pat.trim_end_matches('('));
                flag(rules::HOT_PATH_ALLOC, &what);
            }
        }
        let mut search = 0usize;
        while let Some(pos) = code[search..].find(".clone()") {
            let at = search + pos;
            let recv: String = code[..at]
                .chars()
                .rev()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect::<Vec<_>>()
                .into_iter()
                .rev()
                .collect();
            if !clone_receiver_exempt(&recv) {
                flag(rules::HOT_PATH_ALLOC, "owned-buffer clone");
            }
            search = at + ".clone()".len();
        }
    }
}

fn contains_word(code: &str, word: &str) -> bool {
    let mut search = 0usize;
    while let Some(pos) = code[search..].find(word) {
        let at = search + pos;
        let before = code[..at].chars().next_back();
        let after = code[at + word.len()..].chars().next();
        let bounded = |c: Option<char>| !c.is_some_and(|c| c.is_alphanumeric() || c == '_');
        if bounded(before) && bounded(after) {
            return true;
        }
        search = at + word.len();
    }
    false
}

// ---------------------------------------------------------------------------
// R3: unordered iteration feeding wire output
// ---------------------------------------------------------------------------

fn check_wire_order(f: &FileLex, out: &mut Vec<Violation>) {
    // Pass 1: names lexically bound to hash collections.
    let mut maps: BTreeSet<String> = BTreeSet::new();
    for line in &f.lines {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        if !(code.contains("HashMap") || code.contains("HashSet")) {
            continue;
        }
        if let Some(rest) = code.strip_prefix("let ") {
            let rest = rest.strip_prefix("mut ").unwrap_or(rest);
            let name: String =
                rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
            if !name.is_empty() {
                maps.insert(name);
            }
        } else if let Some((field, _)) = code.split_once(':') {
            let name: String = field
                .trim()
                .trim_start_matches("pub ")
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() && code.contains('<') {
                maps.insert(name);
            }
        }
    }
    // Pass 2: iteration over those names must sort before rendering.
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test || f.allowed(idx, rules::WIRE_ORDER) {
            continue;
        }
        for m in &maps {
            let iterated = ["iter()", "values()", "keys()", "into_iter()", "drain("]
                .iter()
                .any(|call| line.code.contains(&format!("{m}.{call}")))
                || line.code.contains(&format!(" in &{m}"))
                || line.code.contains(&format!(" in &mut {m}"))
                || line.code.contains(&format!(" in {m} "));
            if !iterated {
                continue;
            }
            // Escape hatch: an explicit sort on the same line or
            // within the next two code lines makes the order defined.
            let sorted_nearby = f.lines[idx..]
                .iter()
                .filter(|l| !l.code.trim().is_empty())
                .take(3)
                .any(|l| l.code.contains(".sort"));
            if !sorted_nearby {
                out.push(Violation {
                    file: f.path.clone(),
                    line: line.num,
                    rule: rules::WIRE_ORDER,
                    msg: format!(
                        "iteration over hash collection `{m}` reaches wire output \
                         without an explicit sort — scrape diffs would churn"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// R4: SAFETY-comment coverage
// ---------------------------------------------------------------------------

/// How far back (in lines) a safety comment may sit from its `unsafe`.
const SAFETY_WINDOW: usize = 8;

fn check_safety_comments(f: &FileLex, out: &mut Vec<Violation>) {
    for (idx, line) in f.lines.iter().enumerate() {
        if !contains_word(&line.code, "unsafe") || f.allowed(idx, rules::SAFETY_COMMENT) {
            continue;
        }
        let covered = f.lines[idx.saturating_sub(SAFETY_WINDOW)..=idx]
            .iter()
            .any(|l| l.comment.to_ascii_lowercase().contains("safety"));
        if !covered {
            out.push(Violation {
                file: f.path.clone(),
                line: line.num,
                rule: rules::SAFETY_COMMENT,
                msg: "unsafe without a `// SAFETY:` argument on this line or the \
                      8 lines above it"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R5: metric-name registry
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct MetricRegistry {
    names: Vec<String>,
    /// Line span of the `METRIC_FAMILIES` table (definitions exempt).
    table_lines: (usize, usize),
}

fn parse_metric_registry(f: &FileLex, out: &mut Vec<Violation>) -> MetricRegistry {
    let mut reg = MetricRegistry::default();
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut inside = false;
    for line in &f.lines {
        if !inside && line.code.contains("METRIC_FAMILIES") && line.code.contains("const") {
            inside = true;
            reg.table_lines.0 = line.num;
        }
        if inside {
            for s in &line.strings {
                strings.push((line.num, s.clone()));
            }
            if line.code.contains("];") {
                reg.table_lines.1 = line.num;
                break;
            }
        }
    }
    for chunk in strings.chunks(3) {
        let [(num, name), (_, kind), (_, _help)] = chunk else {
            out.push(Violation {
                file: f.path.clone(),
                line: chunk[0].0,
                rule: rules::METRIC_NAME,
                msg: "METRIC_FAMILIES entry is not a (name, kind, help) triple".to_string(),
            });
            continue;
        };
        if !matches!(kind.as_str(), "counter" | "gauge" | "histogram") {
            out.push(Violation {
                file: f.path.clone(),
                line: *num,
                rule: rules::METRIC_NAME,
                msg: format!("family {name} has unknown kind {kind:?}"),
            });
        }
        if reg.names.contains(name) {
            out.push(Violation {
                file: f.path.clone(),
                line: *num,
                rule: rules::METRIC_NAME,
                msg: format!("duplicate metric family {name}"),
            });
        }
        reg.names.push(name.clone());
    }
    reg
}

fn metric_name_of(literal: &str) -> Option<&str> {
    if !literal.starts_with("repro_") {
        return None;
    }
    let end = literal
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .unwrap_or(literal.len());
    // The bare namespace prefix is a prefix *check*, not a family name.
    Some(&literal[..end]).filter(|n| *n != "repro_")
}

fn check_metric_names(
    f: &FileLex,
    reg: &MetricRegistry,
    is_registry_file: bool,
    out: &mut Vec<Violation>,
) {
    for (idx, line) in f.lines.iter().enumerate() {
        if is_registry_file && (reg.table_lines.0..=reg.table_lines.1).contains(&line.num) {
            continue;
        }
        if f.allowed(idx, rules::METRIC_NAME) {
            continue;
        }
        for s in &line.strings {
            let Some(name) = metric_name_of(s) else { continue };
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            if !reg.names.iter().any(|n| n == name || n == base) {
                out.push(Violation {
                    file: f.path.clone(),
                    line: line.num,
                    rule: rules::METRIC_NAME,
                    msg: format!(
                        "metric name `{name}` is not declared in obs::prom::METRIC_FAMILIES \
                         — exported families are a stable interface"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

/// Lint `root` with the repository contract.
pub fn run(root: &Path) -> io::Result<Report> {
    run_with(root, &Config::repo_default())
}

/// Lint `root` with an explicit [`Config`] (fixtures use mini-trees).
pub fn run_with(root: &Path, cfg: &Config) -> io::Result<Report> {
    let mut violations: Vec<Violation> = Vec::new();
    let mut files: Vec<FileLex> = Vec::new();
    for p in walk(root)? {
        let src = fs::read_to_string(&p)?;
        let path = rel_path(root, &p);
        let lines = lex(&src);
        let allows = build_allows(&path, &lines, &mut violations);
        files.push(FileLex { path, lines, allows });
    }

    let domain_reg = files
        .iter()
        .find(|f| path_matches(&f.path, cfg.registry_path))
        .map(parse_domain_registry)
        .unwrap_or_default();
    if let Some(f) = files.iter().find(|f| path_matches(&f.path, cfg.registry_path)) {
        check_registry_unique(&f.path, &domain_reg, &mut violations);
    }
    let metric_reg = files
        .iter()
        .find(|f| path_matches(&f.path, cfg.metrics_path))
        .map(|f| parse_metric_registry(f, &mut violations))
        .unwrap_or_default();

    for f in &files {
        if !path_matches(&f.path, cfg.registry_path) {
            check_rng_domains(f, &domain_reg, &mut violations);
        }
        if cfg.hot_paths.iter().any(|p| path_matches(&f.path, p)) {
            check_hot_path(f, &mut violations);
        }
        if cfg.wire_paths.iter().any(|p| path_matches(&f.path, p)) {
            check_wire_order(f, &mut violations);
        }
        if cfg.safety_paths.iter().any(|p| path_matches(&f.path, p)) {
            check_safety_comments(f, &mut violations);
        }
        let is_metrics = path_matches(&f.path, cfg.metrics_path);
        check_metric_names(f, &metric_reg, is_metrics, &mut violations);
    }

    violations.sort();
    violations.dedup();
    Ok(Report { files: files.len(), violations })
}

// ---------------------------------------------------------------------------
// Lexer + rule unit tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_strips_comments_and_blanks_strings() {
        let src = "let a = 1; // trailing note\nlet s = \"repro_x { }\"; /* block */ let b = 2;\n";
        let lines = lex(src);
        assert_eq!(lines[0].code.trim(), "let a = 1;");
        assert_eq!(lines[0].comment.trim(), "trailing note");
        assert!(!lines[1].code.contains("repro_x"), "{:?}", lines[1].code);
        assert_eq!(lines[1].strings, vec!["repro_x { }".to_string()]);
        assert!(lines[1].code.contains("let b = 2;"));
        assert_eq!(lines[1].comment.trim(), "block");
    }

    #[test]
    fn lexer_handles_lifetimes_char_literals_and_raw_strings() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '}'; let r = r#\"{\"#; c }\n";
        let lines = lex(src);
        assert!(lines[0].code.contains("<'a>"));
        assert!(!lines[0].code.contains('}') || lines[0].code.matches('}').count() == 1);
        assert_eq!(lines[0].strings, vec!["{".to_string()]);
    }

    #[test]
    fn lexer_marks_cfg_test_regions() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}\n";
        let lines = lex(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test && lines[2].in_test && lines[3].in_test && lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn lexer_handles_nested_block_comments_and_multiline_strings() {
        let src = "/* a /* b */ still */ let x = 1;\nlet s = \"two\nlines\";\n";
        let lines = lex(src);
        assert_eq!(lines[0].code.trim(), "let x = 1;");
        assert_eq!(lines[1].strings, vec!["two\nlines".to_string()]);
    }

    #[test]
    fn allow_escapes_need_reasons_and_known_rules() {
        let lines = lex(
            "// lint: allow(hot-path-alloc) warmup only\nlet v = Vec::new();\n\
             // lint: allow(hot-path-alloc)\nlet w = Vec::new();\n\
             // lint: allow(no-such-rule) because\nlet z = 1;\n",
        );
        let mut v = Vec::new();
        let allows = build_allows("x.rs", &lines, &mut v);
        assert!(allows[1].contains(rules::HOT_PATH_ALLOC));
        assert!(allows[3].is_empty(), "reason-less escape must not apply");
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == rules::ALLOW_SYNTAX));
    }

    #[test]
    fn trailing_allow_applies_to_its_own_line() {
        let lines = lex("let v = Vec::new(); // lint: allow(hot-path-alloc) init only\n");
        let mut v = Vec::new();
        let allows = build_allows("x.rs", &lines, &mut v);
        assert!(allows[0].contains(rules::HOT_PATH_ALLOC));
        assert!(v.is_empty());
    }

    #[test]
    fn first_arg_spans_continuation_lines() {
        let lines = lex("Rng::for_stream(\n    seed ^ STREAM_X,\n    0,\n    1,\n);\n");
        let pos = lines[0].code.find("for_stream(").unwrap() + "for_stream(".len();
        let arg = first_arg(&lines, 0, pos);
        assert_eq!(arg.trim(), "seed ^ STREAM_X");
    }

    #[test]
    fn stream_key_expr_flags_literals_and_unregistered_consts() {
        let f = FileLex { path: "m.rs".into(), lines: vec![], allows: vec![] };
        let reg = DomainRegistry {
            entries: vec![("STREAM_OK".into(), Some(1), 1)],
        };
        let mut out = Vec::new();
        check_stream_key_expr(&f, 1, "seed ^ 0x1234", &reg, &mut out);
        check_stream_key_expr(&f, 2, "seed ^ STREAM_BAD", &reg, &mut out);
        let qualified = "cfg.seed ^ crate::tensor::rng::domains::STREAM_OK";
        check_stream_key_expr(&f, 3, qualified, &reg, &mut out);
        check_stream_key_expr(&f, 4, "self.seed ^ domain", &reg, &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out[0].msg.contains("0x1234"));
        assert!(out[1].msg.contains("STREAM_BAD"));
    }

    #[test]
    fn clone_exemption_is_for_ranges_only() {
        assert!(clone_receiver_exempt("rows"));
        assert!(clone_receiver_exempt("shard_range"));
        assert!(!clone_receiver_exempt("matrix"));
        assert!(!clone_receiver_exempt(""));
    }

    #[test]
    fn metric_name_extraction_handles_label_suffixes() {
        assert_eq!(metric_name_of("repro_jobs_total{state=\"done\"}"), Some("repro_jobs_total"));
        assert_eq!(metric_name_of("repro_x"), Some("repro_x"));
        assert_eq!(metric_name_of("# TYPE repro_x"), None);
    }
}
