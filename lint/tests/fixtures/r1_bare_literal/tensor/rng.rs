//! Fixture registry: one healthy domain.
pub mod domains {
    pub const STREAM_POLICY: u64 = 0x9011C4;

    pub const ALL: &[(&str, u64)] = &[("STREAM_POLICY", STREAM_POLICY)];
}
