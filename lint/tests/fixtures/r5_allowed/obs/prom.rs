//! Fixture metric registry with a single family.
pub const METRIC_FAMILIES: &[(&str, &str, &str)] = &[
    ("repro_requests_total", "counter", "Requests handled."),
];
