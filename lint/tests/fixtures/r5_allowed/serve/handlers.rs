//! Fixture: a registered name plus an escaped experimental one.
pub fn render(out: &mut String) {
    out.push_str("repro_requests_total 1\n");
    // lint: allow(metric-name) fixture: experimental family, not yet a stable promise
    out.push_str("repro_experimental_total 1\n");
}
