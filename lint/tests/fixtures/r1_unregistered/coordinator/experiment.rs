//! Fixture: a SCREAMING_CASE domain missing from the registry.
pub fn draw(seed: u64, epoch: u64, step: u64) -> u64 {
    for_stream(seed ^ STREAM_GHOST, epoch, step)
}

fn for_stream(key: u64, a: u64, b: u64) -> u64 {
    key ^ a ^ b
}
