//! Fixture registry: one healthy domain.
pub mod domains {
    pub const STREAM_POLICY: u64 = 0x9011C4;
}
