//! Fixture: HashMap iteration rendered to wire text without a sort.
use std::collections::HashMap;

pub fn render(out: &mut String) {
    let mut counts: HashMap<String, u64> = HashMap::new();
    counts.insert("a".to_string(), 1);
    for (k, v) in &counts {
        out.push_str(&format!("{k} {v}\n"));
    }
}
