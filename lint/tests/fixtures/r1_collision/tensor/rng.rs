//! Fixture: two domains sharing a stream key must trip rng-domain.
pub mod domains {
    pub const STREAM_A: u64 = 0x1234;
    pub const STREAM_B: u64 = 0x1234;
}
