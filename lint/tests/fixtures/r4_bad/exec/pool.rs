//! Fixture: an unjustified unsafe block must trip rule R4.
pub fn read_first(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    unsafe { *v.as_ptr() }
}
