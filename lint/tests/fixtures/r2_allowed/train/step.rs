//! Fixture: the same tokens, each behind a reasoned allow escape,
//! plus a Range clone (exempt by receiver name).
pub fn step(rows: std::ops::Range<usize>) -> usize {
    // lint: allow(hot-path-clock) fixture: measured region is diagnostics-only
    let t = std::time::Instant::now();
    // lint: allow(hot-path-alloc) fixture: one-time setup buffer
    let v: Vec<u32> = Vec::new();
    // lint: allow(hot-path-hash) fixture: bounded id set, never iterated to wire
    let m: std::collections::HashMap<u32, u32> = Default::default();
    let r = rows.clone();
    drop(t);
    v.len() + m.len() + r.len()
}
