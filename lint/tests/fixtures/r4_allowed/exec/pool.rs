//! Fixture: the same unsafe, justified.
pub fn read_first(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: asserted non-empty above, so the pointer is valid to read
    unsafe { *v.as_ptr() }
}
