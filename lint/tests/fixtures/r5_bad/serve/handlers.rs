//! Fixture: an unregistered repro_* family name.
pub fn render(out: &mut String) {
    out.push_str("repro_bogus_total 1\n");
}
