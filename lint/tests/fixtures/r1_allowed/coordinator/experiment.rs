//! Fixture: the same bare key, escaped with a reasoned allow.
pub fn draw(seed: u64, epoch: u64, step: u64) -> u64 {
    // lint: allow(rng-domain) fixture: pinned historical key, migration tracked elsewhere
    for_stream(seed ^ 0x9011C4, epoch, step)
}

fn for_stream(key: u64, a: u64, b: u64) -> u64 {
    key ^ a ^ b
}
