//! Fixture: clock read, allocation, and hashing on the hot path.
pub fn step() -> usize {
    let t = std::time::Instant::now();
    let v: Vec<u32> = Vec::new();
    let m: std::collections::HashMap<u32, u32> = Default::default();
    drop(t);
    v.len() + m.len()
}
