//! Fixture: the same map, but sorted before rendering.
use std::collections::HashMap;

pub fn render(out: &mut String) {
    let mut counts: HashMap<String, u64> = HashMap::new();
    counts.insert("a".to_string(), 1);
    let mut pairs: Vec<_> = counts.iter().collect();
    pairs.sort();
    for (k, v) in pairs {
        out.push_str(&format!("{k} {v}\n"));
    }
}
