//! The linter's own acceptance gate: the real tree under `rust/src`
//! must be violation-free. Running this as a cargo test (in addition
//! to the ci.sh `repro-lint` stage) means `cargo test -p repro-lint`
//! alone catches a contract regression.

use std::path::PathBuf;

#[test]
fn repo_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("rust").join("src");
    let report = repro_lint::run(&root).expect("scanning rust/src");
    assert!(report.files > 30, "suspiciously few files scanned: {}", report.files);
    assert!(
        report.is_clean(),
        "rust/src has {} lint violation(s):\n{}",
        report.violations.len(),
        report.violations.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
}
