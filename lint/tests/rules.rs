//! Fixture-backed rule tests: each rule has a known-bad mini-tree that
//! must trip it (and only it), and an allow-escaped / corrected twin
//! that must come back clean. The fixtures live under
//! `tests/fixtures/<case>/` and mirror the repo layout so the
//! path-scoped rules fire.

use std::collections::BTreeSet;
use std::path::PathBuf;

use repro_lint::{rules, Report};

fn lint_fixture(name: &str) -> Report {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    repro_lint::run(&root).unwrap_or_else(|e| panic!("scanning fixture {name}: {e}"))
}

fn rule_set(report: &Report) -> BTreeSet<&'static str> {
    report.violations.iter().map(|v| v.rule).collect()
}

fn assert_clean(name: &str) {
    let report = lint_fixture(name);
    assert!(
        report.is_clean(),
        "fixture {name} should be clean, got:\n{}",
        report.violations.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn r1_bare_literal_trips_rng_domain() {
    let report = lint_fixture("r1_bare_literal");
    assert_eq!(rule_set(&report), BTreeSet::from([rules::RNG_DOMAIN]), "{:?}", report.violations);
    assert!(report.violations[0].msg.contains("bare stream key"), "{:?}", report.violations);
    assert!(report.violations[0].file.ends_with("coordinator/experiment.rs"));
}

#[test]
fn r1_colliding_domains_trip_rng_domain() {
    let report = lint_fixture("r1_collision");
    assert_eq!(rule_set(&report), BTreeSet::from([rules::RNG_DOMAIN]), "{:?}", report.violations);
    assert!(report.violations[0].msg.contains("reuses stream key"), "{:?}", report.violations);
}

#[test]
fn r1_unregistered_domain_trips_rng_domain() {
    let report = lint_fixture("r1_unregistered");
    assert_eq!(rule_set(&report), BTreeSet::from([rules::RNG_DOMAIN]), "{:?}", report.violations);
    assert!(report.violations[0].msg.contains("not registered"), "{:?}", report.violations);
}

#[test]
fn r1_allow_escape_silences_rng_domain() {
    assert_clean("r1_allowed");
}

#[test]
fn r2_hot_path_impurities_all_trip() {
    let report = lint_fixture("r2_bad");
    assert_eq!(
        rule_set(&report),
        BTreeSet::from([rules::HOT_PATH_CLOCK, rules::HOT_PATH_ALLOC, rules::HOT_PATH_HASH]),
        "{:?}",
        report.violations
    );
}

#[test]
fn r2_allow_escapes_and_range_clone_exemption_hold() {
    assert_clean("r2_allowed");
}

#[test]
fn r3_unsorted_map_iteration_trips_wire_order() {
    let report = lint_fixture("r3_bad");
    assert_eq!(rule_set(&report), BTreeSet::from([rules::WIRE_ORDER]), "{:?}", report.violations);
}

#[test]
fn r3_sort_before_render_is_clean() {
    assert_clean("r3_allowed");
}

#[test]
fn r4_uncommented_unsafe_trips_safety_comment() {
    let report = lint_fixture("r4_bad");
    assert_eq!(
        rule_set(&report),
        BTreeSet::from([rules::SAFETY_COMMENT]),
        "{:?}",
        report.violations
    );
}

#[test]
fn r4_safety_comment_within_window_is_clean() {
    assert_clean("r4_allowed");
}

#[test]
fn r5_unregistered_metric_name_trips() {
    let report = lint_fixture("r5_bad");
    assert_eq!(rule_set(&report), BTreeSet::from([rules::METRIC_NAME]), "{:?}", report.violations);
    assert!(report.violations[0].msg.contains("repro_bogus_total"), "{:?}", report.violations);
}

#[test]
fn r5_registered_and_escaped_names_are_clean() {
    assert_clean("r5_allowed");
}
