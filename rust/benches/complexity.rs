//! The Sec. I computational-reduction claim, measured: exact weight
//! gradient vs compaction-regime AOP across the paper's K sweeps, on the
//! paper's shapes plus a large-layer shape where the asymptotics show.
//!
//! Also measures the end-to-end step (fwd + policy + apply) so the
//! *system-level* saving — what Fig. 2/3's x-axis of "computational
//! reduction" translates to in wall-clock — is on record next to the
//! kernel-level ratio.

use mem_aop_gd::aop::engine::AopEngine;
use mem_aop_gd::aop::{flops, Policy};
use mem_aop_gd::model::LossKind;
use mem_aop_gd::tensor::{init, ops, rng::Rng, Matrix};
use mem_aop_gd::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("complexity");
    let mut rng = Rng::new(0);

    for (name, m, n, p) in [
        ("energy", 144usize, 16usize, 1usize),
        ("mnist", 64, 784, 10),
        ("wide", 128, 1024, 1024), // where the reduction really pays
    ] {
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let g = Matrix::from_fn(m, p, |_, _| rng.normal());

        let exact = b.bench_with_work(
            &format!("{name}/weight-grad/exact M={m}"),
            Some(flops::exact_step(m, n, p).backward_only() as f64),
            || {
                black_box(ops::matmul_tn(&x, &g));
            },
        );

        for frac in [8usize, 4, 2] {
            let k = (m / frac).max(1);
            let sel: Vec<(usize, f32)> = (0..k).map(|i| (i, 1.0)).collect();
            let s = b.bench_with_work(
                &format!("{name}/weight-grad/aop K=M/{frac}"),
                Some(flops::aop_step(m, n, p, k).backward_only() as f64),
                || {
                    black_box(ops::masked_outer_compact(&x, &g, &sel));
                },
            );
            eprintln!(
                "    -> measured speedup {:.2}x (FLOP model predicts {:.2}x)",
                exact.median_ns / s.median_ns,
                m as f64 / k as f64
            );
        }

        // end-to-end step: exact vs K=M/4 topK with memory
        let y = Matrix::from_fn(m, p, |_, _| rng.normal());
        let mk_engine = |policy: Policy, k: usize, mem: bool, rng: &mut Rng| {
            AopEngine::new(
                init::glorot_uniform(rng, n, p),
                LossKind::Mse,
                m,
                policy,
                k,
                mem,
            )
        };
        let mut e_exact = mk_engine(Policy::Exact, m, false, &mut rng);
        let mut r1 = Rng::new(1);
        b.bench(&format!("{name}/full-step/exact"), || {
            black_box(e_exact.step(&x, &y, 0.01, &mut r1));
        });
        let mut e_aop = mk_engine(Policy::TopK, (m / 4).max(1), true, &mut rng);
        let mut r2 = Rng::new(2);
        b.bench(&format!("{name}/full-step/topk K=M/4 +mem"), || {
            black_box(e_aop.step(&x, &y, 0.01, &mut r2));
        });
    }

    b.finish();
}
