//! Kernel-level benchmarks: the AOP weight-gradient computation in both
//! execution regimes (mask vs compaction) against the exact outer-product
//! sum, on the paper's exact shapes, for both the native path and the
//! compiled HLO artifacts — plus the end-to-end `exec` training-step
//! throughput (serial vs threads=4), written to `BENCH_2.json`, the
//! layer-graph training-step throughput on a 2-hidden-layer shape with
//! heterogeneous per-layer K, written to `BENCH_3.json`, (§Perf pass)
//! the wide-layer workspace-resident step with an
//! **allocations-per-step counter**, written to `BENCH_4.json`, and the
//! **annealed-K** step (K ramping over resolved epochs on one resident
//! workspace — the K-schedule tentpole), written to `BENCH_5.json`, and
//! the **telemetry-on** graph step (obs tentpole: phase histograms +
//! event ring recording, allocs/step still asserted 0, per-phase
//! percentiles reported), written to `BENCH_6.json`, and the
//! **audited** step (PR 7: the exact K=M re-reduction of
//! `train::audit_into` interleaved every few steps, audit-on vs
//! audit-off rows/sec, allocs/step asserted 0 with audits included),
//! written to `BENCH_8.json` (`BENCH_7` is reserved for the conv
//! workload), and the **mixed-precision** trace/accum grid (quantized
//! forward traces + widened lane accumulation: rows/sec, backward-read
//! trace bytes, fixed-step loss drift per (trace, accum) cell), written
//! to `BENCH_9.json`, and the **serve-burst** workload (PR 9
//! resilience: a many-connection submit burst through
//! `submit_with_retry` against an in-process server whose admission
//! queue is deliberately small, reporting end-to-end jobs/sec plus
//! submit-latency percentiles and the retry/rejection counts the burst
//! absorbed), written to `BENCH_10.json` — so the repo's perf
//! trajectory is machine-readable.
//!
//! Work metric = FLOPs of the compaction-regime cost model, so the
//! reported work-rate is directly comparable across K (who computes the
//! same gradient with fewer FLOPs/second wins).
//!
//! The allocation counter is a thin `#[global_allocator]` wrapper that
//! counts `alloc`/`realloc` calls; the BENCH_4 section asserts the
//! serial steady-state step performs **zero** of them (the tentpole
//! claim of the workspace refactor) and reports the threads=4 count —
//! which is also expected to be zero with the job-slot `ExecPool`, but
//! is reported rather than asserted so a platform whose std primitives
//! allocate under contention cannot fail CI.

// Clock reads are deliberate here (benchmark harness timing) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use mem_aop_gd::aop::engine::AopEngine;
use mem_aop_gd::aop::{flops, Policy};
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule, Task};
use mem_aop_gd::exec::Executor;
use mem_aop_gd::model::loss::LossKind;
use mem_aop_gd::runtime::{Manifest, Runtime, Value};
use mem_aop_gd::serve::{Client, RetryPolicy, ServeOptions, Server};
use mem_aop_gd::tensor::{init, ops, rng::Rng, Matrix};
use mem_aop_gd::train::{self, AopLayerConfig, Graph, GraphState, GraphWorkspace};
use mem_aop_gd::util::bench::{black_box, Bencher};
use mem_aop_gd::util::json::{self, Json};

/// Counts every heap allocation (alloc + realloc) the process performs.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates verbatim to the system allocator; the counter is a
// relaxed atomic with no allocation of its own.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Steady-state rows/sec of full Mem-AOP-GD training steps on the MNIST
/// head shape (M=64, 784×10, topk K=32, memory on) at a thread count.
fn exec_rows_per_sec(threads: usize, measure: Duration) -> f64 {
    let (m, n, p, k) = (64usize, 784usize, 10usize, 32usize);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut engine = AopEngine::new(
        init::glorot_uniform(&mut wrng, n, p),
        LossKind::SoftmaxCrossEntropy,
        m,
        Policy::TopK,
        k,
        true,
    );
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    // warmup: populate memory, warm the pool's threads and caches
    for _ in 0..20 {
        black_box(engine.step_exec(&x, &y, 0.01, &mut srng, &exec));
    }
    let t0 = Instant::now();
    let mut steps = 0u64;
    while t0.elapsed() < measure {
        black_box(engine.step_exec(&x, &y, 0.01, &mut srng, &exec));
        steps += 1;
    }
    steps as f64 * m as f64 / t0.elapsed().as_secs_f64()
}

/// Measure serial vs threads=4 training throughput and write
/// `BENCH_2.json` (rows/sec + FLOPs/step, with the speedup ratio).
fn bench_exec_and_write_bench2() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let serial = exec_rows_per_sec(1, measure);
    let par4 = exec_rows_per_sec(4, measure);
    let speedup = par4 / serial;
    let step = flops::aop_step(64, 784, 10, 32);
    let flops_per_step = step.total() as f64;
    let flops_per_row = flops_per_step / 64.0;
    eprintln!(
        "{:44} {:>12.0} rows/s",
        "mnist/exec/train-step threads=1", serial
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({speedup:.2}x)",
        "mnist/exec/train-step threads=4", par4
    );
    let out = json::obj(vec![
        ("workload", json::s("mnist-784x10 topk K=32 mem train-step")),
        ("m", json::num(64.0)),
        ("n", json::num(784.0)),
        ("p", json::num(10.0)),
        ("k", json::num(32.0)),
        ("flops_per_step", json::num(flops_per_step)),
        (
            "serial",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(serial)),
                ("flops_per_sec", json::num(serial * flops_per_row)),
            ]),
        ),
        (
            "threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(par4)),
                ("flops_per_sec", json::num(par4 * flops_per_row)),
            ]),
        ),
        ("speedup", json::num(speedup)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_2.json", &text).is_ok() {
        eprintln!("[kernels] wrote BENCH_2.json (speedup {speedup:.2}x)");
    }
    let _ = write_results_copy(&out);
}

/// Also drop the record under `results/bench/` next to the other suites.
fn write_results_copy(v: &Json) -> std::io::Result<()> {
    std::fs::create_dir_all("results/bench")?;
    let mut text = v.dump();
    text.push('\n');
    std::fs::write("results/bench/exec_throughput.json", text)
}

/// The BENCH_3 workload: a 2-hidden-layer MNIST-head graph
/// (784→128→64→10, relu hiddens) with heterogeneous per-layer K.
const GRAPH_WIDTHS: [usize; 4] = [784, 128, 64, 10];
const GRAPH_KS: [usize; 3] = [32, 16, 8];
const GRAPH_BATCH: usize = 64;

/// Steady-state rows/sec of full layer-graph Mem-AOP-GD training steps
/// (the unified `train::step` core) at a thread count.
fn graph_rows_per_sec(threads: usize, measure: Duration) -> f64 {
    let m = GRAPH_BATCH;
    let (n, p) = (GRAPH_WIDTHS[0], GRAPH_WIDTHS[3]);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, &GRAPH_WIDTHS, LossKind::SoftmaxCrossEntropy);
    let cfgs: Vec<AopLayerConfig> = GRAPH_KS
        .iter()
        .map(|&k| AopLayerConfig {
            k,
            policy: Policy::TopK,
            memory: true,
        })
        .collect();
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    for _ in 0..10 {
        black_box(train::train_step(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true,
        ));
    }
    let t0 = Instant::now();
    let mut steps = 0u64;
    while t0.elapsed() < measure {
        black_box(train::train_step(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true,
        ));
        steps += 1;
    }
    steps as f64 * m as f64 / t0.elapsed().as_secs_f64()
}

/// Measure serial vs threads=4 layer-graph throughput and write
/// `BENCH_3.json` (rows/sec + FLOPs/step on the 2-hidden-layer shape).
fn bench_graph_and_write_bench3() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let serial = graph_rows_per_sec(1, measure);
    let par4 = graph_rows_per_sec(4, measure);
    let speedup = par4 / serial;
    // per-layer FLOPs from the cost model, summed over the graph
    let mut flops_per_step = 0.0f64;
    let mut layer_json = Vec::new();
    for (i, &k) in GRAPH_KS.iter().enumerate() {
        let (n, p) = (GRAPH_WIDTHS[i], GRAPH_WIDTHS[i + 1]);
        let lf = flops::aop_step(GRAPH_BATCH, n, p, k).total() as f64;
        flops_per_step += lf;
        layer_json.push(json::obj(vec![
            ("n", json::num(n as f64)),
            ("p", json::num(p as f64)),
            ("k", json::num(k as f64)),
            ("flops_per_step", json::num(lf)),
        ]));
    }
    let flops_per_row = flops_per_step / GRAPH_BATCH as f64;
    eprintln!(
        "{:44} {:>12.0} rows/s",
        "graph/exec/train-step threads=1", serial
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({speedup:.2}x)",
        "graph/exec/train-step threads=4", par4
    );
    let out = json::obj(vec![
        (
            "workload",
            json::s("graph-784x128x64x10 topk K=[32,16,8] mem train-step"),
        ),
        ("m", json::num(GRAPH_BATCH as f64)),
        ("layers", Json::Arr(layer_json)),
        ("flops_per_step", json::num(flops_per_step)),
        (
            "serial",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(serial)),
                ("flops_per_sec", json::num(serial * flops_per_row)),
            ]),
        ),
        (
            "threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(par4)),
                ("flops_per_sec", json::num(par4 * flops_per_row)),
            ]),
        ),
        ("speedup", json::num(speedup)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_3.json", &text).is_ok() {
        eprintln!("[kernels] wrote BENCH_3.json (speedup {speedup:.2}x)");
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/graph_throughput.json", text));
}

/// The BENCH_4 workload (§Perf pass): a wide hidden layer (784→4096→10,
/// relu, topk K=64, memory on, batch 128 — K < M, so the compaction
/// window filtering and nonzero memory retention are genuinely on the
/// measured path) stepped through the workspace-resident
/// `train::train_step_ws` — the shape where the lane-blocked kernels
/// and the cached transposes dominate, plus the allocations-per-step
/// counter proving the zero-allocation steady state. (The resident
/// per-shard outer-product partials for the 784×4096 layer make this a
/// ~100 MB workspace — a bench-box budget, deliberately.)
const WIDE_WIDTHS: [usize; 3] = [784, 4096, 10];
const WIDE_K: usize = 64;
const WIDE_BATCH: usize = 128;

/// Steady-state (rows/sec, allocations/step) of wide-layer training
/// steps at a thread count. Allocations are counted over the same timed
/// steps, after a warmup that populates every lazy buffer (workspace,
/// transpose caches, selection scratch).
fn wide_rows_per_sec(threads: usize, measure: Duration) -> (f64, f64) {
    let m = WIDE_BATCH;
    let (n, p) = (WIDE_WIDTHS[0], WIDE_WIDTHS[2]);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, &WIDE_WIDTHS, LossKind::SoftmaxCrossEntropy);
    let cfgs: Vec<AopLayerConfig> = (0..2)
        .map(|_| AopLayerConfig {
            k: WIDE_K,
            policy: Policy::TopK,
            memory: true,
        })
        .collect();
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let mut ws = GraphWorkspace::new(&graph, m);
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    for _ in 0..3 {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
    }
    let a0 = alloc_calls();
    let t0 = Instant::now();
    let mut steps = 0u64;
    while steps < 2 || t0.elapsed() < measure {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
        steps += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = (alloc_calls() - a0) as f64 / steps as f64;
    (steps as f64 * m as f64 / elapsed, allocs)
}

/// Measure the wide-layer workload and write `BENCH_4.json` (serial vs
/// threads=4 rows/sec + allocations/step). The serial steady state is
/// asserted allocation-free — the tentpole claim of the workspace
/// refactor — unless `BENCH_ALLOW_ALLOCS=1` downgrades the assert to a
/// warning (escape hatch for platforms whose std primitives allocate).
fn bench_wide_and_write_bench4() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let (serial, serial_allocs) = wide_rows_per_sec(1, measure);
    let (par4, par4_allocs) = wide_rows_per_sec(4, measure);
    let speedup = par4 / serial;
    let mut flops_per_step = 0.0f64;
    let mut layer_json = Vec::new();
    for i in 0..2 {
        let (n, p) = (WIDE_WIDTHS[i], WIDE_WIDTHS[i + 1]);
        let lf = flops::aop_step(WIDE_BATCH, n, p, WIDE_K).total() as f64;
        flops_per_step += lf;
        layer_json.push(json::obj(vec![
            ("n", json::num(n as f64)),
            ("p", json::num(p as f64)),
            ("k", json::num(WIDE_K as f64)),
            ("flops_per_step", json::num(lf)),
        ]));
    }
    let flops_per_row = flops_per_step / WIDE_BATCH as f64;
    eprintln!(
        "{:44} {:>12.0} rows/s  ({serial_allocs:.1} allocs/step)",
        "wide/exec/train-step threads=1", serial
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({speedup:.2}x, {par4_allocs:.1} allocs/step)",
        "wide/exec/train-step threads=4", par4
    );
    if serial_allocs != 0.0 {
        let msg = format!(
            "serial steady-state step performed {serial_allocs} allocations (expected 0)"
        );
        if std::env::var("BENCH_ALLOW_ALLOCS").ok().as_deref() == Some("1") {
            eprintln!("[kernels] WARNING: {msg}");
        } else {
            panic!("{msg}");
        }
    }
    let out = json::obj(vec![
        (
            "workload",
            json::s("wide-784x4096x10 topk K=64 mem train-step (workspace-resident)"),
        ),
        ("m", json::num(WIDE_BATCH as f64)),
        ("layers", Json::Arr(layer_json)),
        ("flops_per_step", json::num(flops_per_step)),
        (
            "serial",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(serial)),
                ("flops_per_sec", json::num(serial * flops_per_row)),
                ("allocs_per_step", json::num(serial_allocs)),
            ]),
        ),
        (
            "threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(par4)),
                ("flops_per_sec", json::num(par4 * flops_per_row)),
                ("allocs_per_step", json::num(par4_allocs)),
            ]),
        ),
        ("speedup", json::num(speedup)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_4.json", &text).is_ok() {
        eprintln!(
            "[kernels] wrote BENCH_4.json (speedup {speedup:.2}x, serial allocs/step {serial_allocs:.1})"
        );
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/wide_throughput.json", text));
}

/// The BENCH_5 workload (K-schedule tentpole): the BENCH_3 graph driven
/// through an annealed budget — every layer's K follows `linear:8:32`
/// across 6 resolved epochs on ONE resident workspace and state, so the
/// measured path includes mid-run k changes. The serial steady state is
/// asserted allocation-free even while k ramps (selection buffers are
/// pre-sized for the batch, the schedule's clamp ceiling), with the same
/// `BENCH_ALLOW_ALLOCS=1` escape hatch as BENCH_4.
const ANNEAL_EPOCHS: usize = 6;

fn annealed_rows_per_sec(threads: usize, measure: Duration) -> (f64, f64) {
    let m = GRAPH_BATCH;
    let (n, p) = (GRAPH_WIDTHS[0], GRAPH_WIDTHS[3]);
    let sched = KSchedule::parse("linear:8:32").unwrap();
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, &GRAPH_WIDTHS, LossKind::SoftmaxCrossEntropy);
    let cfgs = vec![
        AopLayerConfig {
            k: sched.k_at(1, ANNEAL_EPOCHS, m),
            policy: Policy::TopK,
            memory: true,
        };
        3
    ];
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let mut ws = GraphWorkspace::new(&graph, m);
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    let mut epoch = 0usize;
    let mut step_annealed =
        |graph: &mut Graph, state: &mut GraphState, ws: &mut GraphWorkspace, srng: &mut Rng| {
            epoch = epoch % ANNEAL_EPOCHS + 1;
            let k = sched.k_at(epoch, ANNEAL_EPOCHS, m);
            for ls in state.layers.iter_mut() {
                ls.cfg.k = k;
            }
            black_box(train::train_step_ws(
                graph, state, &x, &y, 0.01, srng, &exec, true, ws,
            ));
        };
    // warmup covers the whole k ramp, so every buffer has seen max k
    for _ in 0..2 * ANNEAL_EPOCHS {
        step_annealed(&mut graph, &mut state, &mut ws, &mut srng);
    }
    let a0 = alloc_calls();
    let t0 = Instant::now();
    let mut steps = 0u64;
    while steps < ANNEAL_EPOCHS as u64 || t0.elapsed() < measure {
        step_annealed(&mut graph, &mut state, &mut ws, &mut srng);
        steps += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = (alloc_calls() - a0) as f64 / steps as f64;
    (steps as f64 * m as f64 / elapsed, allocs)
}

/// Measure the annealed-K workload and write `BENCH_5.json` (serial vs
/// threads=4 rows/sec, mean FLOPs/step over the schedule's integral).
fn bench_annealed_and_write_bench5() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let (serial, serial_allocs) = annealed_rows_per_sec(1, measure);
    let (par4, par4_allocs) = annealed_rows_per_sec(4, measure);
    let speedup = par4 / serial;
    let sched = KSchedule::parse("linear:8:32").unwrap();
    // FLOPs/step = the schedule's integral over one epoch cycle / cycle
    // length — the honest work metric for an annealed budget
    let mut flops_cycle = 0.0f64;
    for e in 1..=ANNEAL_EPOCHS {
        let k = sched.k_at(e, ANNEAL_EPOCHS, GRAPH_BATCH);
        for i in 0..3 {
            let (n, p) = (GRAPH_WIDTHS[i], GRAPH_WIDTHS[i + 1]);
            flops_cycle += flops::aop_step(GRAPH_BATCH, n, p, k).total() as f64;
        }
    }
    let flops_per_step = flops_cycle / ANNEAL_EPOCHS as f64;
    let flops_per_row = flops_per_step / GRAPH_BATCH as f64;
    eprintln!(
        "{:44} {:>12.0} rows/s  ({serial_allocs:.1} allocs/step)",
        "annealed/exec/train-step threads=1", serial
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({speedup:.2}x, {par4_allocs:.1} allocs/step)",
        "annealed/exec/train-step threads=4", par4
    );
    if serial_allocs != 0.0 {
        let msg = format!(
            "serial annealed-K steady state performed {serial_allocs} allocations/step (expected 0)"
        );
        if std::env::var("BENCH_ALLOW_ALLOCS").ok().as_deref() == Some("1") {
            eprintln!("[kernels] WARNING: {msg}");
        } else {
            panic!("{msg}");
        }
    }
    let out = json::obj(vec![
        (
            "workload",
            json::s("graph-784x128x64x10 topk K=linear:8:32/6ep mem train-step (annealed)"),
        ),
        ("m", json::num(GRAPH_BATCH as f64)),
        ("k_schedule", json::s(&sched.name())),
        ("anneal_epochs", json::num(ANNEAL_EPOCHS as f64)),
        ("flops_per_step", json::num(flops_per_step)),
        (
            "serial",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(serial)),
                ("flops_per_sec", json::num(serial * flops_per_row)),
                ("allocs_per_step", json::num(serial_allocs)),
            ]),
        ),
        (
            "threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(par4)),
                ("flops_per_sec", json::num(par4 * flops_per_row)),
                ("allocs_per_step", json::num(par4_allocs)),
            ]),
        ),
        ("speedup", json::num(speedup)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_5.json", &text).is_ok() {
        eprintln!(
            "[kernels] wrote BENCH_5.json (speedup {speedup:.2}x, serial allocs/step {serial_allocs:.1})"
        );
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/annealed_throughput.json", text));
}

/// The BENCH_6 workload (obs tentpole): the BENCH_3 graph stepped
/// through the workspace-resident core with telemetry **on** — phase
/// histograms, realized-K counters, and the event ring all recording on
/// the hot path. Returns (rows/sec, allocs/step, the workspace) so the
/// caller can render per-phase percentiles from the run's own telemetry.
/// Telemetry is re-armed after warmup, so the reported counts cover
/// exactly the timed steps and the allocation window starts from an
/// already-sized ring.
fn obs_graph_run(threads: usize, measure: Duration) -> (f64, f64, GraphWorkspace) {
    use mem_aop_gd::obs::ObsConfig;
    let m = GRAPH_BATCH;
    let (n, p) = (GRAPH_WIDTHS[0], GRAPH_WIDTHS[3]);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, &GRAPH_WIDTHS, LossKind::SoftmaxCrossEntropy);
    let cfgs: Vec<AopLayerConfig> = GRAPH_KS
        .iter()
        .map(|&k| AopLayerConfig {
            k,
            policy: Policy::TopK,
            memory: true,
        })
        .collect();
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let mut ws = GraphWorkspace::with_obs(&graph, m, ObsConfig::on());
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    for _ in 0..10 {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
    }
    // zero the telemetry (pre-sized rebuild) BEFORE the alloc window, so
    // counts describe the timed steps and the ring is already capacity'd
    ws.set_obs(ObsConfig::on());
    let a0 = alloc_calls();
    let t0 = Instant::now();
    let mut steps = 0u64;
    while steps < 2 || t0.elapsed() < measure {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
        steps += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = (alloc_calls() - a0) as f64 / steps as f64;
    (steps as f64 * m as f64 / elapsed, allocs, ws)
}

/// Measure the obs-on workload and write `BENCH_6.json`: serial vs
/// threads=4 rows/sec, allocations/step with telemetry recording
/// (serial asserted **0** — the ISSUE 6 zero-allocation contract, same
/// `BENCH_ALLOW_ALLOCS=1` escape hatch as BENCH_4/5), and per-phase
/// latency percentiles straight from the run's own histograms.
fn bench_obs_and_write_bench6() {
    use mem_aop_gd::obs::Phase;
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let (serial, serial_allocs, ws) = obs_graph_run(1, measure);
    let (par4, par4_allocs, _) = obs_graph_run(4, measure);
    let speedup = par4 / serial;
    let mut flops_per_step = 0.0f64;
    for (i, &k) in GRAPH_KS.iter().enumerate() {
        let (n, p) = (GRAPH_WIDTHS[i], GRAPH_WIDTHS[i + 1]);
        flops_per_step += flops::aop_step(GRAPH_BATCH, n, p, k).total() as f64;
    }
    let flops_per_row = flops_per_step / GRAPH_BATCH as f64;
    eprintln!(
        "{:44} {:>12.0} rows/s  ({serial_allocs:.1} allocs/step)",
        "obs/exec/train-step threads=1", serial
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({speedup:.2}x, {par4_allocs:.1} allocs/step)",
        "obs/exec/train-step threads=4", par4
    );
    if serial_allocs != 0.0 {
        let msg = format!(
            "obs-enabled serial steady state performed {serial_allocs} allocations/step \
             (expected 0 — telemetry must be pre-sized)"
        );
        if std::env::var("BENCH_ALLOW_ALLOCS").ok().as_deref() == Some("1") {
            eprintln!("[kernels] WARNING: {msg}");
        } else {
            panic!("{msg}");
        }
    }
    let tele = ws.obs();
    let mut phase_json = Vec::new();
    for ph in Phase::ALL {
        let h = tele.phase(ph);
        if h.is_empty() {
            continue;
        }
        phase_json.push(json::obj(vec![
            ("phase", json::s(ph.name())),
            ("count", json::num(h.count() as f64)),
            ("p50_ns", json::num(h.quantile_ns(0.50) as f64)),
            ("p90_ns", json::num(h.quantile_ns(0.90) as f64)),
            ("p99_ns", json::num(h.quantile_ns(0.99) as f64)),
            ("mean_ns", json::num(h.mean_ns())),
            ("max_ns", json::num(h.max_ns() as f64)),
        ]));
    }
    let out = json::obj(vec![
        (
            "workload",
            json::s("graph-784x128x64x10 topk K=[32,16,8] mem train-step (telemetry on)"),
        ),
        ("m", json::num(GRAPH_BATCH as f64)),
        ("steps_observed", json::num(tele.steps() as f64)),
        ("flops_per_step", json::num(flops_per_step)),
        ("phases", Json::Arr(phase_json)),
        (
            "serial",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(serial)),
                ("flops_per_sec", json::num(serial * flops_per_row)),
                ("allocs_per_step", json::num(serial_allocs)),
            ]),
        ),
        (
            "threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(par4)),
                ("flops_per_sec", json::num(par4 * flops_per_row)),
                ("allocs_per_step", json::num(par4_allocs)),
            ]),
        ),
        ("speedup", json::num(speedup)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_6.json", &text).is_ok() {
        eprintln!(
            "[kernels] wrote BENCH_6.json (speedup {speedup:.2}x, serial allocs/step {serial_allocs:.1}, obs on)"
        );
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/obs_throughput.json", text));
}

/// Steps between audits in the BENCH_8 audit-on cell — models the
/// per-epoch cadence (one `audit_into` per audited epoch) at bench
/// scale so the overhead number covers steady state, not just the
/// audit step itself.
const AUDIT_EVERY: u64 = 8;

/// The BENCH_8 workload (gradient-fidelity auditor): the BENCH_6
/// obs-on graph, with `train::audit_into` re-reducing the exact K=M
/// memory-corrected gradient every [`AUDIT_EVERY`] steps when `audit`
/// is on. The audit scratch is sized during warmup, so the timed
/// window — audits included — must stay allocation-free.
fn audit_graph_run(audit: bool, threads: usize, measure: Duration) -> (f64, f64) {
    use mem_aop_gd::obs::ObsConfig;
    let m = GRAPH_BATCH;
    let (n, p) = (GRAPH_WIDTHS[0], GRAPH_WIDTHS[3]);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, &GRAPH_WIDTHS, LossKind::SoftmaxCrossEntropy);
    let cfgs: Vec<AopLayerConfig> = GRAPH_KS
        .iter()
        .map(|&k| AopLayerConfig {
            k,
            policy: Policy::TopK,
            memory: true,
        })
        .collect();
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let mut ws = GraphWorkspace::with_obs(&graph, m, ObsConfig::on());
    let exec = Executor::new(threads);
    let mut srng = Rng::new(2);
    let mut recs = Vec::new();
    for _ in 0..10 {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
    }
    if audit {
        // size the audit scratch (and the record vec) before the window
        train::audit_into(&graph, &state, &x, 0.01, &exec, true, &mut ws, &mut recs);
    }
    ws.set_obs(ObsConfig::on());
    let a0 = alloc_calls();
    let t0 = Instant::now();
    let mut steps = 0u64;
    while steps < 2 || t0.elapsed() < measure {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
        steps += 1;
        if audit && steps % AUDIT_EVERY == 0 {
            train::audit_into(&graph, &state, &x, 0.01, &exec, true, &mut ws, &mut recs);
            black_box(&recs);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = (alloc_calls() - a0) as f64 / steps as f64;
    (steps as f64 * m as f64 / elapsed, allocs)
}

/// Measure the auditor's cost and write `BENCH_8.json` (BENCH_7 is
/// reserved for the conv workload): audit-off vs audit-on rows/sec at
/// threads 1 and 4, the audit-on overhead ratio, and allocations/step
/// across the audited window (serial cells asserted **0** — the PR 7
/// observation-only contract extends the ISSUE 6 zero-allocation
/// guarantee through `audit_into`; same `BENCH_ALLOW_ALLOCS=1` hatch).
fn bench_audit_and_write_bench8() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let measure = if quick {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let (off1, off1_allocs) = audit_graph_run(false, 1, measure);
    let (on1, on1_allocs) = audit_graph_run(true, 1, measure);
    let (on4, on4_allocs) = audit_graph_run(true, 4, measure);
    let overhead = off1 / on1;
    eprintln!(
        "{:44} {:>12.0} rows/s  ({off1_allocs:.1} allocs/step)",
        "audit-off/exec/train-step threads=1", off1
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({:.2}x of audit-off, {on1_allocs:.1} allocs/step)",
        format!("audit-on(every {AUDIT_EVERY})/train-step threads=1"),
        on1,
        on1 / off1
    );
    eprintln!(
        "{:44} {:>12.0} rows/s  ({on4_allocs:.1} allocs/step)",
        format!("audit-on(every {AUDIT_EVERY})/train-step threads=4"),
        on4
    );
    for (cell, allocs) in [("audit-off serial", off1_allocs), ("audit-on serial", on1_allocs)] {
        if allocs != 0.0 {
            let msg = format!(
                "{cell} steady state performed {allocs} allocations/step \
                 (expected 0 — audit scratch must be pre-sized)"
            );
            if std::env::var("BENCH_ALLOW_ALLOCS").ok().as_deref() == Some("1") {
                eprintln!("[kernels] WARNING: {msg}");
            } else {
                panic!("{msg}");
            }
        }
    }
    let out = json::obj(vec![
        (
            "workload",
            json::s("graph-784x128x64x10 topk K=[32,16,8] mem train-step + K=M audit"),
        ),
        ("m", json::num(GRAPH_BATCH as f64)),
        ("audit_every_steps", json::num(AUDIT_EVERY as f64)),
        (
            "audit_off",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(off1)),
                ("allocs_per_step", json::num(off1_allocs)),
            ]),
        ),
        (
            "audit_on",
            json::obj(vec![
                ("threads", json::num(1.0)),
                ("rows_per_sec", json::num(on1)),
                ("allocs_per_step", json::num(on1_allocs)),
            ]),
        ),
        (
            "audit_on_threads4",
            json::obj(vec![
                ("threads", json::num(4.0)),
                ("rows_per_sec", json::num(on4)),
                ("allocs_per_step", json::num(on4_allocs)),
            ]),
        ),
        ("audit_overhead", json::num(overhead)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_8.json", &text).is_ok() {
        eprintln!(
            "[kernels] wrote BENCH_8.json (audit overhead {overhead:.2}x, \
             serial allocs/step {on1_allocs:.1}, audit every {AUDIT_EVERY} steps)"
        );
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/audit_throughput.json", text));
}

/// Fixed step count of the BENCH_9 curve-drift probe: every precision
/// cell trains exactly this many deterministic steps before the timed
/// window, so final losses are comparable across (trace, accum).
const PRECISION_DRIFT_STEPS: usize = 40;

/// One BENCH_9 precision cell: train a graph with the given per-layer
/// (trace, accum) on one resident workspace. Returns (rows/sec,
/// allocs/step, backward-read trace bytes total, trace bytes of the
/// compressible hidden layers, final drift-probe loss). Serial only —
/// the grid measures memory traffic and drift, not thread scaling (the
/// exec suite pins thread-invariance per precision config).
fn precision_cell(
    widths: &[usize],
    ks: &[usize],
    m: usize,
    trace: mem_aop_gd::tensor::quant::TraceMode,
    accum: mem_aop_gd::tensor::quant::AccumMode,
    measure: Duration,
) -> (f64, f64, usize, usize, f32) {
    use mem_aop_gd::tensor::quant::LayerPrecision;
    let (n, p) = (widths[0], widths[widths.len() - 1]);
    let mut rng = Rng::new(0);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32);
    let mut wrng = Rng::new(1);
    let mut graph = Graph::relu_mlp(&mut wrng, widths, LossKind::SoftmaxCrossEntropy);
    let cfgs: Vec<AopLayerConfig> = ks
        .iter()
        .map(|&k| AopLayerConfig { k, policy: Policy::TopK, memory: true })
        .collect();
    let mut state = GraphState::from_configs(&graph, m, &cfgs);
    let mut ws = GraphWorkspace::new(&graph, m);
    ws.set_precision(&graph, &vec![LayerPrecision { trace, accum }; ks.len()]);
    let exec = Executor::new(1);
    let mut srng = Rng::new(2);
    // drift probe doubles as warmup: deterministic steps, same seeds in
    // every cell, so final losses differ only by the precision knobs
    let mut last = f32::NAN;
    for _ in 0..PRECISION_DRIFT_STEPS {
        let out = train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        );
        last = out.loss;
    }
    let hidden: usize = (0..ks.len() - 1).map(|li| ws.layer_trace_bytes(li)).sum();
    let total = ws.trace_bytes();
    let a0 = alloc_calls();
    let t0 = Instant::now();
    let mut steps = 0u64;
    while steps < 2 || t0.elapsed() < measure {
        black_box(train::train_step_ws(
            &mut graph, &mut state, &x, &y, 0.01, &mut srng, &exec, true, &mut ws,
        ));
        steps += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let allocs = (alloc_calls() - a0) as f64 / steps as f64;
    (steps as f64 * m as f64 / elapsed, allocs, total, hidden, last)
}

/// The BENCH_9 workload (mixed-precision tentpole): the wide 784→4096→10
/// and deep 784→128→64→10 graphs stepped through every (trace, accum) ∈
/// {f32, bf16, q8} × {f32, f64} cell on one resident workspace each.
/// Reports rows/sec, backward-read trace bytes (with the reduction vs
/// the f32 baseline), and the fixed-step final-loss drift. Asserted:
/// the quantized serial steady state allocates **zero** (same
/// `BENCH_ALLOW_ALLOCS=1` hatch as BENCH_4..8), and the compressible
/// hidden-layer trace footprint shrinks ≥2× under bf16 (exactly 2×:
/// 2 bytes/element) and ≥3.9× under q8. Overall reduction is slightly
/// lower because the head trace is pinned f32 (it feeds the loss head).
fn bench_precision_and_write_bench9() {
    use mem_aop_gd::tensor::quant::{AccumMode, TraceMode};
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    // 12 cells: keep each window shorter than the single-workload suites
    let measure = if quick {
        Duration::from_millis(200)
    } else {
        Duration::from_secs(1)
    };
    let allow_allocs = std::env::var("BENCH_ALLOW_ALLOCS").ok().as_deref() == Some("1");
    let mut graph_json = Vec::new();
    for (label, widths, ks, m) in [
        ("wide-784x4096x10", &WIDE_WIDTHS[..], vec![WIDE_K; 2], WIDE_BATCH),
        ("deep-784x128x64x10", &GRAPH_WIDTHS[..], GRAPH_KS.to_vec(), GRAPH_BATCH),
    ] {
        let (base_rows, base_allocs, base_bytes, base_hidden, base_loss) =
            precision_cell(widths, &ks, m, TraceMode::F32, AccumMode::F32, measure);
        let mut cell_json = Vec::new();
        for trace in [TraceMode::F32, TraceMode::Bf16, TraceMode::Q8] {
            for accum in [AccumMode::F32, AccumMode::F64] {
                let (rows, allocs, bytes, hidden, loss) =
                    if trace == TraceMode::F32 && accum == AccumMode::F32 {
                        (base_rows, base_allocs, base_bytes, base_hidden, base_loss)
                    } else {
                        precision_cell(widths, &ks, m, trace, accum, measure)
                    };
                let reduction = base_bytes as f64 / bytes as f64;
                let hidden_reduction = base_hidden as f64 / hidden as f64;
                let drift = (loss - base_loss).abs() as f64 / base_loss.abs().max(1e-9) as f64;
                eprintln!(
                    "{:44} {:>12.0} rows/s  (trace {:.2}x smaller, drift {:.2e}, {allocs:.1} allocs/step)",
                    format!("{label}/trace={}/accum={}", trace.name(), accum.name()),
                    rows,
                    reduction,
                    drift
                );
                if allocs != 0.0 {
                    let msg = format!(
                        "{label} trace={} accum={} steady state performed {allocs} \
                         allocations/step (expected 0 — quantized traces must be pre-sized)",
                        trace.name(),
                        accum.name()
                    );
                    if allow_allocs {
                        eprintln!("[kernels] WARNING: {msg}");
                    } else {
                        panic!("{msg}");
                    }
                }
                cell_json.push(json::obj(vec![
                    ("trace", json::s(trace.name())),
                    ("accum", json::s(accum.name())),
                    ("rows_per_sec", json::num(rows)),
                    ("allocs_per_step", json::num(allocs)),
                    ("trace_bytes", json::num(bytes as f64)),
                    ("trace_reduction", json::num(reduction)),
                    ("hidden_trace_reduction", json::num(hidden_reduction)),
                    ("final_loss", json::num(loss as f64)),
                    ("loss_drift", json::num(drift)),
                ]));
                // the acceptance arithmetic, asserted where it is exact:
                // the hidden (non-pinned) traces shrink 2x under bf16;
                // q8 approaches 4x, less the 4-byte/row step overhead
                // (4c/(c+4) per layer — ~3.76x at the 64-wide hidden)
                if trace == TraceMode::Bf16 {
                    assert!(
                        hidden_reduction >= 2.0,
                        "{label}: bf16 hidden-trace reduction {hidden_reduction} < 2x"
                    );
                }
                if trace == TraceMode::Q8 {
                    assert!(
                        hidden_reduction >= 3.5,
                        "{label}: q8 hidden-trace reduction {hidden_reduction} < 3.5x"
                    );
                }
            }
        }
        graph_json.push(json::obj(vec![
            ("graph", json::s(label)),
            ("m", json::num(m as f64)),
            (
                "k",
                Json::Arr(ks.iter().map(|&k| json::num(k as f64)).collect()),
            ),
            ("drift_steps", json::num(PRECISION_DRIFT_STEPS as f64)),
            ("f32_trace_bytes", json::num(base_bytes as f64)),
            ("cells", Json::Arr(cell_json)),
        ]));
    }
    let out = json::obj(vec![
        (
            "workload",
            json::s("mixed-precision trace/accum grid (workspace-resident train-step)"),
        ),
        ("graphs", Json::Arr(graph_json)),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_9.json", &text).is_ok() {
        eprintln!("[kernels] wrote BENCH_9.json (trace/accum precision grid)");
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/precision_throughput.json", text));
}

/// The BENCH_10 workload (serve-tier resilience, PR 9): a
/// many-connection submit burst through `submit_with_retry` against an
/// in-process server whose admission queue is deliberately small
/// (2 workers, 8 pending slots), so the burst actually exercises
/// `queue_full` rejections and the client backoff path. Reports
/// end-to-end jobs/sec as the gated `serve_submit` rows_per_sec series,
/// p50/p99 per-submit wire latency (backoff included), the retry count
/// the burst absorbed, and the server's own `queue_full` rejection
/// counter. Unlike BENCH_4..9 there is no zero-alloc assertion here:
/// the serve path allocates by design (framing, job state); the gated
/// contract is that admission control does not collapse throughput.
fn bench_serve_and_write_bench10() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let (jobs, conns) = if quick { (16usize, 4usize) } else { (48usize, 8usize) };

    let server = Server::bind(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_capacity: 8,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = std::thread::spawn(move || server.run());

    // same quick job mix as the serve_throughput macro-bench: 2-epoch
    // energy jobs cycling through every policy
    let cfg = |i: usize| {
        let policies = Policy::all();
        let p = policies[i % policies.len()];
        let mut c = ExperimentConfig::preset(Task::Energy);
        c.policy = p;
        c.memory = p != Policy::Exact;
        c.k = KSchedule::constant(if p == Policy::Exact { c.m() } else { 18 });
        c.epochs = 2;
        c.seed = i as u64;
        c.backend = Backend::Native;
        c
    };

    let t0 = Instant::now();
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(jobs);
    let mut retries_total = 0u32;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..conns {
            let addr = addr.clone();
            let cfg = &cfg;
            handles.push(scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let policy = RetryPolicy {
                    attempts: 12,
                    seed: t as u64,
                    ..RetryPolicy::default()
                };
                let mut lats = Vec::new();
                let mut retries = 0u32;
                let mut ids = Vec::new();
                for i in (0..jobs).filter(|i| i % conns == t) {
                    let s0 = Instant::now();
                    let (id, r) = c
                        .submit_with_retry(&cfg(i), "bench10", &policy)
                        .expect("submit_with_retry");
                    lats.push(s0.elapsed().as_secs_f64() * 1e3);
                    retries += r;
                    ids.push(id);
                }
                for id in ids {
                    let job = c.wait(id, Duration::from_secs(600)).expect("wait");
                    assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));
                }
                (lats, retries)
            }));
        }
        for h in handles {
            let (lats, retries) = h.join().expect("client thread panicked");
            latencies_ms.extend(lats);
            retries_total += retries;
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let jobs_per_sec = jobs as f64 / elapsed;

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let pct = |p: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * p).round() as usize];

    // the server's own view: every queue_full the burst rode through
    let mut c = Client::connect(&addr).expect("connect");
    let m = c.metrics().expect("metrics");
    let queue_full = m
        .get("rejected")
        .and_then(|r| r.get("queue_full"))
        .and_then(|n| n.as_f64())
        .unwrap_or(0.0);
    c.shutdown().expect("shutdown");
    handle.join().expect("server thread panicked").expect("server run");

    eprintln!(
        "serve-burst: {jobs} jobs over {conns} conns in {elapsed:.2}s ({jobs_per_sec:.1} jobs/s), \
         submit p50 {:.1}ms p99 {:.1}ms, {retries_total} retries, {queue_full:.0} queue_full \
         rejections",
        pct(0.50),
        pct(0.99),
    );

    let out = json::obj(vec![
        (
            "workload",
            json::s("serve-tier submit burst under admission control (2 workers, 8-slot queue)"),
        ),
        ("jobs", json::num(jobs as f64)),
        ("conns", json::num(conns as f64)),
        (
            "serve_submit",
            json::obj(vec![
                ("rows_per_sec", json::num(jobs_per_sec)),
                ("submit_p50_ms", json::num(pct(0.50))),
                ("submit_p99_ms", json::num(pct(0.99))),
                ("retries", json::num(retries_total as f64)),
                ("queue_full_rejections", json::num(queue_full)),
            ]),
        ),
    ]);
    let mut text = out.dump();
    text.push('\n');
    if std::fs::write("BENCH_10.json", &text).is_ok() {
        eprintln!("[kernels] wrote BENCH_10.json (serve-burst under admission control)");
    }
    let _ = std::fs::create_dir_all("results/bench")
        .and_then(|_| std::fs::write("results/bench/serve_submit.json", text));
}

fn main() {
    let mut b = Bencher::new("kernels");
    let mut rng = Rng::new(0);

    bench_exec_and_write_bench2();
    bench_graph_and_write_bench3();
    bench_wide_and_write_bench4();
    bench_annealed_and_write_bench5();
    bench_obs_and_write_bench6();
    bench_audit_and_write_bench8();
    bench_precision_and_write_bench9();
    bench_serve_and_write_bench10();

    for (task, m, n, p, ks) in [
        ("energy", 144usize, 16usize, 1usize, vec![144usize, 18, 9, 3]),
        ("mnist", 64, 784, 10, vec![64, 32, 16, 8]),
    ] {
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let g = Matrix::from_fn(m, p, |_, _| rng.normal());

        // exact baseline: full outer-product sum (eq. (3))
        let work = 2.0 * (m * n * p) as f64;
        b.bench_with_work(&format!("{task}/native/exact-matmul_tn"), Some(work), || {
            black_box(ops::matmul_tn(&x, &g));
        });

        for &k in &ks {
            let sel: Vec<(usize, f32)> = (0..k).map(|i| (i % m, 1.0)).collect();
            let mut scale = vec![0.0f32; m];
            for &(i, s) in &sel {
                scale[i] = s;
            }
            let work_k = 2.0 * (k * n * p) as f64;
            b.bench_with_work(
                &format!("{task}/native/aop-compact K={k}"),
                Some(work_k),
                || {
                    black_box(ops::masked_outer_compact(&x, &g, &sel));
                },
            );
            b.bench_with_work(
                &format!("{task}/native/aop-mask K={k}"),
                Some(work_k),
                || {
                    black_box(ops::masked_outer(&x, &g, &scale));
                },
            );
        }

        // policy scores kernel
        b.bench(&format!("{task}/native/scores"), || {
            black_box(ops::norm_product_scores(&x, &g));
        });
    }

    // HLO apply-phase (the Pallas aop_outer inside the compiled artifact)
    // + the fused single-dispatch step (dispatch-count ablation, §Perf)
    if Manifest::default_dir().join("manifest.json").exists() {
        let rt = Runtime::from_default_artifacts().expect("runtime");
        for (task, m, n, p) in [("energy", 144usize, 16usize, 1usize), ("mnist", 64, 784, 10)] {
            use mem_aop_gd::runtime::ArgRef;
            let fused = rt.load(&format!("{task}_fused_topk_mem")).unwrap();
            let x = Matrix::from_fn(m, n, |_, _| rng.normal());
            let y = Matrix::from_fn(m, p, |r, c| ((r % p.max(1)) == c) as u32 as f32);
            let w = Matrix::zeros(n, p);
            let bias = vec![0.0f32; p];
            let mx = Matrix::zeros(m, n);
            let mg = Matrix::zeros(m, p);
            let noise = vec![0.5f32; m];
            b.bench(&format!("{task}/hlo/fused-step topk-mem"), || {
                let out = fused
                    .run_ref(&[
                        ArgRef::from(&x),
                        ArgRef::from(&y),
                        ArgRef::from(&w),
                        ArgRef::from(&bias),
                        ArgRef::from(&mx),
                        ArgRef::from(&mg),
                        ArgRef::from(&noise),
                        ArgRef::Scalar(0.01),
                    ])
                    .unwrap();
                black_box(out);
            });
        }
        for (task, m, n, p) in [("energy", 144usize, 16usize, 1usize), ("mnist", 64, 784, 10)] {
            let apply = rt.load(&format!("{task}_apply")).unwrap();
            let xhat = Matrix::from_fn(m, n, |_, _| rng.normal());
            let ghat = Matrix::from_fn(m, p, |_, _| rng.normal());
            let w = Matrix::zeros(n, p);
            let scale: Vec<f32> = (0..m).map(|i| (i % 4 == 0) as u32 as f32).collect();
            let keep: Vec<f32> = scale.iter().map(|v| 1.0 - v).collect();
            b.bench(&format!("{task}/hlo/apply-phase"), || {
                let out = apply
                    .run(&[
                        Value::Matrix(xhat.clone()),
                        Value::Matrix(ghat.clone()),
                        Value::Matrix(w.clone()),
                        Value::Vector(vec![0.0; p]),
                        Value::Vector(vec![0.0; p]),
                        Value::Vector(scale.clone()),
                        Value::Vector(keep.clone()),
                    ])
                    .unwrap();
                black_box(out);
            });
        }
    } else {
        eprintln!("[kernels] artifacts missing — HLO benches skipped");
    }

    b.finish();
}
