//! Kernel-level benchmarks: the AOP weight-gradient computation in both
//! execution regimes (mask vs compaction) against the exact outer-product
//! sum, on the paper's exact shapes, for both the native path and the
//! compiled HLO artifacts.
//!
//! Work metric = FLOPs of the compaction-regime cost model, so the
//! reported work-rate is directly comparable across K (who computes the
//! same gradient with fewer FLOPs/second wins).

use mem_aop_gd::runtime::{Manifest, Runtime, Value};
use mem_aop_gd::tensor::{ops, rng::Rng, Matrix};
use mem_aop_gd::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("kernels");
    let mut rng = Rng::new(0);

    for (task, m, n, p, ks) in [
        ("energy", 144usize, 16usize, 1usize, vec![144usize, 18, 9, 3]),
        ("mnist", 64, 784, 10, vec![64, 32, 16, 8]),
    ] {
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let g = Matrix::from_fn(m, p, |_, _| rng.normal());

        // exact baseline: full outer-product sum (eq. (3))
        let work = 2.0 * (m * n * p) as f64;
        b.bench_with_work(&format!("{task}/native/exact-matmul_tn"), Some(work), || {
            black_box(ops::matmul_tn(&x, &g));
        });

        for &k in &ks {
            let sel: Vec<(usize, f32)> = (0..k).map(|i| (i % m, 1.0)).collect();
            let mut scale = vec![0.0f32; m];
            for &(i, s) in &sel {
                scale[i] = s;
            }
            let work_k = 2.0 * (k * n * p) as f64;
            b.bench_with_work(
                &format!("{task}/native/aop-compact K={k}"),
                Some(work_k),
                || {
                    black_box(ops::masked_outer_compact(&x, &g, &sel));
                },
            );
            b.bench_with_work(
                &format!("{task}/native/aop-mask K={k}"),
                Some(work_k),
                || {
                    black_box(ops::masked_outer(&x, &g, &scale));
                },
            );
        }

        // policy scores kernel
        b.bench(&format!("{task}/native/scores"), || {
            black_box(ops::norm_product_scores(&x, &g));
        });
    }

    // HLO apply-phase (the Pallas aop_outer inside the compiled artifact)
    // + the fused single-dispatch step (dispatch-count ablation, §Perf)
    if Manifest::default_dir().join("manifest.json").exists() {
        let rt = Runtime::from_default_artifacts().expect("runtime");
        for (task, m, n, p) in [("energy", 144usize, 16usize, 1usize), ("mnist", 64, 784, 10)] {
            use mem_aop_gd::runtime::ArgRef;
            let fused = rt.load(&format!("{task}_fused_topk_mem")).unwrap();
            let x = Matrix::from_fn(m, n, |_, _| rng.normal());
            let y = Matrix::from_fn(m, p, |r, c| ((r % p.max(1)) == c) as u32 as f32);
            let w = Matrix::zeros(n, p);
            let bias = vec![0.0f32; p];
            let mx = Matrix::zeros(m, n);
            let mg = Matrix::zeros(m, p);
            let noise = vec![0.5f32; m];
            b.bench(&format!("{task}/hlo/fused-step topk-mem"), || {
                let out = fused
                    .run_ref(&[
                        ArgRef::from(&x),
                        ArgRef::from(&y),
                        ArgRef::from(&w),
                        ArgRef::from(&bias),
                        ArgRef::from(&mx),
                        ArgRef::from(&mg),
                        ArgRef::from(&noise),
                        ArgRef::Scalar(0.01),
                    ])
                    .unwrap();
                black_box(out);
            });
        }
        for (task, m, n, p) in [("energy", 144usize, 16usize, 1usize), ("mnist", 64, 784, 10)] {
            let apply = rt.load(&format!("{task}_apply")).unwrap();
            let xhat = Matrix::from_fn(m, n, |_, _| rng.normal());
            let ghat = Matrix::from_fn(m, p, |_, _| rng.normal());
            let w = Matrix::zeros(n, p);
            let scale: Vec<f32> = (0..m).map(|i| (i % 4 == 0) as u32 as f32).collect();
            let keep: Vec<f32> = scale.iter().map(|v| 1.0 - v).collect();
            b.bench(&format!("{task}/hlo/apply-phase"), || {
                let out = apply
                    .run(&[
                        Value::Matrix(xhat.clone()),
                        Value::Matrix(ghat.clone()),
                        Value::Matrix(w.clone()),
                        Value::Vector(vec![0.0; p]),
                        Value::Vector(vec![0.0; p]),
                        Value::Vector(scale.clone()),
                        Value::Vector(keep.clone()),
                    ])
                    .unwrap();
                black_box(out);
            });
        }
    } else {
        eprintln!("[kernels] artifacts missing — HLO benches skipped");
    }

    b.finish();
}
