//! Selection-policy cost on the per-step hot path: topK (partial
//! selection vs full sort), randK and Gumbel weightedK, across batch
//! sizes M. The policy must stay negligible next to the gradient matmul
//! — these benches back the §Perf claim that L3 is not the bottleneck.

use mem_aop_gd::aop::policy::{self, Policy};
use mem_aop_gd::tensor::rng::Rng;
use mem_aop_gd::util::bench::{black_box, Bencher};

/// Reference full-sort topK for comparison with the select_nth path.
fn top_k_via_sort(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    idx.truncate(k);
    idx
}

fn main() {
    let mut b = Bencher::new("policies");
    let mut rng = Rng::new(0);

    for m in [64usize, 144, 1024, 8192] {
        let scores: Vec<f32> = (0..m).map(|_| rng.uniform() + 0.01).collect();
        let k = m / 8;

        b.bench(&format!("topk-select_nth M={m}"), || {
            black_box(policy::top_k_indices(&scores, k));
        });
        b.bench(&format!("topk-full-sort M={m}"), || {
            black_box(top_k_via_sort(&scores, k));
        });

        let mut r2 = Rng::new(1);
        b.bench(&format!("randk M={m}"), || {
            black_box(r2.sample_without_replacement(m, k));
        });
        let mut r3 = Rng::new(2);
        b.bench(&format!("weightedk-gumbel M={m}"), || {
            black_box(r3.weighted_sample_without_replacement(&scores, k));
        });
        let mut r4 = Rng::new(3);
        b.bench(&format!("weightedk-repl M={m}"), || {
            black_box(r4.weighted_sample_with_replacement(&scores, k));
        });

        // the full select() wrapper including scale/keep vector builds
        let mut r5 = Rng::new(4);
        b.bench(&format!("select(topk,mem) M={m}"), || {
            black_box(policy::select(Policy::TopK, &scores, k, true, &mut r5));
        });
    }

    b.finish();
}
