//! Fig. 3 bench: per-training-step cost of every series in the MNIST
//! panels (baseline + 3 policies × {mem, nomem} at K = 32, 16, 8), both
//! backends. Complements `repro figure --fig 3` (the loss curves) with
//! the cost axis. Shapes here are where the paper's reduction actually
//! pays: N·P = 7840, so the weight gradient dominates the step.

use mem_aop_gd::aop::policy;
use mem_aop_gd::coordinator::config::ExperimentConfig;
use mem_aop_gd::coordinator::experiment::Trainer;
use mem_aop_gd::coordinator::hlo_trainer::HloTrainer;
use mem_aop_gd::coordinator::native_trainer::NativeTrainer;
use mem_aop_gd::coordinator::sweep;
use mem_aop_gd::data::digits;
use mem_aop_gd::runtime::{Manifest, Runtime};
use mem_aop_gd::tensor::rng::Rng;
use mem_aop_gd::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("fig3_mnist");
    let base = ExperimentConfig::mnist_preset();
    let have_artifacts = Manifest::default_dir().join("manifest.json").exists();
    let rt = if have_artifacts {
        Some(Runtime::from_default_artifacts().expect("runtime"))
    } else {
        eprintln!("[fig3] artifacts missing — HLO series skipped");
        None
    };

    // one fixed batch of synthetic digits for all series
    let ds = digits::digits_dataset(base.m(), 0xF163);
    let mut rng = Rng::new(5);

    for &k in &base.task.figure_ks() {
        for cfg in sweep::panel_configs(&base, k) {
            let label = format!("K={k}/{}", cfg.label());
            // panel configs are constant-K; resolve the schedule once
            let sel_k = cfg.k.k_at(1, cfg.epochs, cfg.m());

            let mut nt = NativeTrainer::new(&cfg).unwrap();
            b.bench(&format!("native/{label}"), || {
                let (_, scores) = nt.fwd_score(&ds.x, &ds.y).unwrap();
                let sel = policy::select(cfg.policy, &scores[0], sel_k, cfg.memory, &mut rng);
                black_box(nt.apply(std::slice::from_ref(&sel)).unwrap());
            });

            if let Some(rt) = &rt {
                let mut ht = HloTrainer::new(&cfg, rt).unwrap();
                b.bench(&format!("hlo/{label}"), || {
                    let (_, scores) = ht.fwd_score(&ds.x, &ds.y).unwrap();
                    let sel =
                        policy::select(cfg.policy, &scores[0], sel_k, cfg.memory, &mut rng);
                    black_box(ht.apply(std::slice::from_ref(&sel)).unwrap());
                });
            }
        }
    }
    b.finish();
}
