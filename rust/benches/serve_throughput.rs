//! Serve-subsystem macro-benchmark: end-to-end job throughput and wire
//! protocol overhead against an in-process server on an ephemeral port.
//!
//! Two numbers matter for the trainer-as-a-service story:
//!
//! * **jobs/sec** — submit→train→result for a burst of short energy-task
//!   jobs across every policy, over several concurrent connections (the
//!   scheduler + registry + persistence path, dominated by training);
//! * **requests/sec** — `ping` round-trips on one connection (pure
//!   framing/dispatch overhead; must be orders of magnitude above any
//!   plausible job rate so the protocol never bottlenecks the pool).
//!
//! ```sh
//! cargo bench --bench serve_throughput
//! ```

// Clock reads are deliberate here (benchmark harness timing) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule, Task};
use mem_aop_gd::serve::{Client, ServeOptions, Server};

fn quick_cfg(i: usize) -> ExperimentConfig {
    let policies = Policy::all();
    let p = policies[i % policies.len()];
    let mut cfg = ExperimentConfig::preset(Task::Energy);
    cfg.policy = p;
    cfg.memory = p != Policy::Exact;
    cfg.k = KSchedule::constant(if p == Policy::Exact { cfg.m() } else { 18 });
    cfg.epochs = 2;
    cfg.seed = i as u64;
    cfg.backend = Backend::Native;
    cfg
}

fn main() {
    let server = Server::bind(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 0,
        queue_capacity: 256,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = std::thread::spawn(move || server.run());

    // protocol overhead: ping round-trips on a single connection
    let mut c = Client::connect(&addr).expect("connect");
    let pings = 2000usize;
    let t0 = Instant::now();
    for _ in 0..pings {
        c.ping().expect("ping");
    }
    let ping_s = t0.elapsed().as_secs_f64();
    println!(
        "protocol: {pings} pings in {ping_s:.3}s  ({:.0} req/s, {:.1} us/req)",
        pings as f64 / ping_s,
        1e6 * ping_s / pings as f64
    );

    // end-to-end job throughput over concurrent connections
    let jobs = 64usize;
    let conns = 8usize;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..conns {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let ids: Vec<u64> = (0..jobs)
                    .filter(|i| i % conns == t)
                    .map(|i| c.submit(&quick_cfg(i), "bench").expect("submit"))
                    .collect();
                for id in ids {
                    let job = c.wait(id, Duration::from_secs(600)).expect("wait");
                    assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));
                }
            });
        }
    });
    let job_s = t0.elapsed().as_secs_f64();
    println!(
        "jobs: {jobs} (2-epoch energy, all policies) over {conns} conns in {job_s:.2}s  \
         ({:.1} jobs/s)",
        jobs as f64 / job_s
    );

    let m = c.metrics().expect("metrics");
    println!(
        "server-side: {} requests total, mean {:.2} jobs/s since start",
        m.get("requests_total").and_then(|n| n.as_f64()).unwrap_or(0.0) as u64,
        m.get("jobs_per_sec").and_then(|n| n.as_f64()).unwrap_or(0.0)
    );

    c.shutdown().expect("shutdown");
    handle.join().expect("server thread").expect("server run");
}
