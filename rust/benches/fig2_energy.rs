//! Fig. 2 bench: per-training-step cost of every series in the energy
//! panels (baseline + 3 policies × {mem, nomem} at K = 18, 9, 3), on both
//! backends. The paper's Fig. 2 reports loss-vs-epoch; this bench reports
//! the cost side of the trade-off (step time per series), which together
//! with `repro figure --fig 2` (loss curves) regenerates the full story.

use mem_aop_gd::aop::policy;
use mem_aop_gd::coordinator::config::ExperimentConfig;
use mem_aop_gd::coordinator::experiment::{self, Trainer};
use mem_aop_gd::coordinator::hlo_trainer::HloTrainer;
use mem_aop_gd::coordinator::native_trainer::NativeTrainer;
use mem_aop_gd::coordinator::sweep;
use mem_aop_gd::runtime::{Manifest, Runtime};
use mem_aop_gd::tensor::rng::Rng;
use mem_aop_gd::util::bench::{black_box, Bencher};

fn bench_series<T: Trainer>(
    b: &mut Bencher,
    name: &str,
    mut trainer: T,
    cfg: &ExperimentConfig,
) {
    let (train, _) = experiment::load_data(cfg);
    let idx: Vec<usize> = (0..cfg.m()).collect();
    let batch = train.gather(&idx);
    let mut rng = Rng::new(9);
    // panel configs are constant-K; resolve the schedule once
    let k = cfg.k.k_at(1, cfg.epochs, cfg.m());
    b.bench(name, || {
        let (_, scores) = trainer.fwd_score(&batch.x, &batch.y).unwrap();
        let sel = policy::select(cfg.policy, &scores[0], k, cfg.memory, &mut rng);
        black_box(trainer.apply(std::slice::from_ref(&sel)).unwrap());
    });
}

fn main() {
    let mut b = Bencher::new("fig2_energy");
    let base = ExperimentConfig::energy_preset();
    let have_artifacts = Manifest::default_dir().join("manifest.json").exists();
    let rt = if have_artifacts {
        Some(Runtime::from_default_artifacts().expect("runtime"))
    } else {
        eprintln!("[fig2] artifacts missing — HLO series skipped");
        None
    };

    for &k in &base.task.figure_ks() {
        for cfg in sweep::panel_configs(&base, k) {
            let label = format!("K={k}/{}", cfg.label());
            bench_series(
                &mut b,
                &format!("native/{label}"),
                NativeTrainer::new(&cfg).unwrap(),
                &cfg,
            );
            if let Some(rt) = &rt {
                bench_series(
                    &mut b,
                    &format!("hlo/{label}"),
                    HloTrainer::new(&cfg, rt).unwrap(),
                    &cfg,
                );
            }
        }
    }
    b.finish();
}
