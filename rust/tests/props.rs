//! Property-based invariants over the coordinator and the algorithm,
//! run through the in-tree `util::prop` framework (offline substitute
//! for proptest — seeded cases, reproducible failures).

use mem_aop_gd::aop::policy::{self, Policy};
use mem_aop_gd::aop::{flops, MemoryState};
use mem_aop_gd::coordinator::config::KSchedule;
use mem_aop_gd::data::batcher::Batcher;
use mem_aop_gd::data::Dataset;
use mem_aop_gd::tensor::{ops, Matrix};
use mem_aop_gd::util::json;
use mem_aop_gd::util::prop::{property, Gen};

fn randm(g: &mut Gen, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, g.vec_normal(r * c))
}

// ---------------------------------------------------------------------
// 8-lane kernel contract (§Perf pass): lane-blocked reductions agree
// with an f64 reference, and kernel path choice is a pure function of
// operand shapes — never of row-range position.
// ---------------------------------------------------------------------

#[test]
fn prop_lane_blocked_dot_matches_f64_reference() {
    property("dot vs f64", 80, |g| {
        // lengths straddling the 8-lane split and its scalar tail
        let len = g.usize_range(1, 300);
        let a = g.vec_normal(len);
        let b = g.vec_normal(len);
        let refd: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        let got = ops::dot(&a, &b) as f64;
        let scale: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x as f64 * y as f64).abs())
            .sum::<f64>()
            .max(1.0);
        assert!(
            (got - refd).abs() < 1e-5 * scale,
            "len={len}: {got} vs {refd}"
        );
    });
}

#[test]
fn prop_masked_outer_range_matches_f64_reference() {
    property("masked outer vs f64", 40, |g| {
        let m = g.usize_range(1, 40);
        let n = g.usize_range(1, 100); // crosses the transposed-layout shapes
        let p = g.usize_range(1, 12);
        let x = randm(g, m, n);
        let gm = randm(g, m, p);
        let scale = g.vec_uniform(m, 0.0, 2.0);
        let lo = g.usize_range(0, m - 1);
        let hi = g.usize_range(lo + 1, m);
        let out = ops::masked_outer_range(&x, &gm, &scale, lo..hi);
        // probe a handful of entries against exact f64 accumulation
        for probe in 0..4usize {
            let r = probe % n;
            let c = (probe * 3 + 1) % p;
            let refd: f64 = (lo..hi)
                .map(|row| scale[row] as f64 * x[(row, r)] as f64 * gm[(row, c)] as f64)
                .sum();
            let scale_mag: f64 = (lo..hi)
                .map(|row| (scale[row] as f64 * x[(row, r)] as f64 * gm[(row, c)] as f64).abs())
                .sum::<f64>()
                .max(1.0);
            assert!(
                (out[(r, c)] as f64 - refd).abs() < 1e-5 * scale_mag,
                "({m},{n},{p}) [{r},{c}]"
            );
        }
    });
}

#[test]
fn prop_kernel_path_is_shape_only_never_position() {
    // restricting the row range must be BITWISE identical to zeroing the
    // scales outside it: accumulation layout and per-term float ops
    // depend only on (n, p), not on where the range sits in the batch
    property("path choice shape-only", 40, |g| {
        let m = g.usize_range(2, 48);
        let n = g.usize_range(1, 120);
        let p = g.usize_range(1, 12);
        let x = randm(g, m, n);
        let gm = randm(g, m, p);
        let scale = g.vec_uniform(m, 0.1, 2.0);
        let lo = g.usize_range(0, m - 1);
        let hi = g.usize_range(lo + 1, m);
        let ranged = ops::masked_outer_range(&x, &gm, &scale, lo..hi);
        let mut masked = vec![0.0f32; m];
        masked[lo..hi].copy_from_slice(&scale[lo..hi]);
        let full = ops::masked_outer(&x, &gm, &masked);
        assert_eq!(ranged.data(), full.data(), "({m},{n},{p}) rows {lo}..{hi}");
    });
}

#[test]
fn prop_matmul_rows_slices_are_position_free() {
    // every row range of matmul_rows is bitwise the corresponding slice
    // of the whole-batch product, for narrow-B and blocked shapes alike
    property("matmul_rows position-free", 40, |g| {
        let m = g.usize_range(1, 30);
        let k = g.usize_range(1, 90);
        let n = g.usize_range(1, 40);
        let a = randm(g, m, k);
        let b = randm(g, k, n);
        let full = ops::matmul(&a, &b);
        let lo = g.usize_range(0, m - 1);
        let hi = g.usize_range(lo + 1, m);
        let mut out = vec![f32::NAN; (hi - lo) * n];
        ops::matmul_rows(&a, &b, lo..hi, &mut out);
        assert_eq!(&out[..], &full.data()[lo * n..hi * n], "({m},{k},{n})");
    });
}

// ---------------------------------------------------------------------
// AOP / eq. (4)-(7) invariants
// ---------------------------------------------------------------------

#[test]
fn prop_masked_outer_decomposition() {
    // masked(s) + masked(1-s) == full X^T G for any mask and any shapes
    property("mask decomposition", 60, |g| {
        let m = g.usize_range(1, 48);
        let n = g.usize_range(1, 32);
        let p = g.usize_range(1, 8);
        let x = randm(g, m, n);
        let gm = randm(g, m, p);
        let mask = g.mask(m, 0.5);
        let inv: Vec<f32> = mask.iter().map(|v| 1.0 - v).collect();
        let sum = ops::masked_outer(&x, &gm, &mask).add(&ops::masked_outer(&x, &gm, &inv));
        let full = ops::matmul_tn(&x, &gm);
        let tol = 1e-3 * (1.0 + full.frobenius());
        assert!(sum.max_abs_diff(&full) < tol);
    });
}

#[test]
fn prop_compact_equals_mask_regime() {
    property("compact == mask", 60, |g| {
        let m = g.usize_range(1, 40);
        let n = g.usize_range(1, 24);
        let p = g.usize_range(1, 6);
        let x = randm(g, m, n);
        let gm = randm(g, m, p);
        let mask = g.mask(m, 0.3);
        let pairs: Vec<(usize, f32)> = mask
            .iter()
            .enumerate()
            .filter(|(_, &s)| s != 0.0)
            .map(|(i, &s)| (i, s))
            .collect();
        let a = ops::masked_outer(&x, &gm, &mask);
        let b = ops::masked_outer_compact(&x, &gm, &pairs);
        assert!(a.max_abs_diff(&b) < 1e-4);
    });
}

#[test]
fn prop_selection_partition_invariant() {
    // For every policy with memory: sel_scale and keep partition the rows;
    // k_effective == k for without-replacement policies.
    property("selection partition", 80, |g| {
        let m = g.usize_range(2, 64);
        let k = g.usize_range(1, m);
        let scores = g.vec_uniform(m, 0.01, 10.0);
        for pol in [Policy::TopK, Policy::RandK, Policy::WeightedK] {
            let sel = policy::select(pol, &scores, k, true, g.rng());
            assert_eq!(sel.k_effective(), k, "{pol:?}");
            for i in 0..m {
                let s = sel.sel_scale[i] != 0.0;
                let kp = sel.keep[i] != 0.0;
                assert!(s ^ kp, "{pol:?} row {i}");
            }
        }
    });
}

#[test]
fn prop_topk_takes_largest() {
    property("topk order", 100, |g| {
        let m = g.usize_range(2, 100);
        let k = g.usize_range(1, m);
        let scores = g.vec_uniform(m, 0.0, 1.0);
        let idx = policy::top_k_indices(&scores, k);
        let min_sel = idx.iter().map(|&i| scores[i]).fold(f32::INFINITY, f32::min);
        let max_unsel = (0..m)
            .filter(|i| !idx.contains(i))
            .map(|i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        assert!(min_sel >= max_unsel - 1e-6, "{min_sel} < {max_unsel}");
    });
}

#[test]
fn prop_memory_rows_are_exact_copies_or_zero() {
    property("memory partition", 60, |g| {
        let m = g.usize_range(1, 32);
        let n = g.usize_range(1, 16);
        let p = g.usize_range(1, 4);
        let mut ms = MemoryState::new(m, n, p, true);
        let xhat = randm(g, m, n);
        let ghat = randm(g, m, p);
        let keep = g.mask(m, 0.5);
        ms.update(&xhat, &ghat, &keep);
        for r in 0..m {
            if keep[r] == 1.0 {
                assert_eq!(ms.mem_x.row(r), xhat.row(r));
                assert_eq!(ms.mem_g.row(r), ghat.row(r));
            } else {
                assert!(ms.mem_x.row(r).iter().all(|&v| v == 0.0));
            }
        }
    });
}

#[test]
fn prop_fold_is_affine_in_memory() {
    // fold(m, x, eta) == fold(0, x, eta) + m
    property("fold affine", 50, |g| {
        let m = g.usize_range(1, 24);
        let n = g.usize_range(1, 12);
        let eta = g.f32_range(0.001, 1.0);
        let x = randm(g, m, n);
        let gm = randm(g, m, 2);
        let mut with = MemoryState::new(m, n, 2, true);
        with.mem_x = randm(g, m, n);
        with.mem_g = randm(g, m, 2);
        let zero = MemoryState::new(m, n, 2, true);
        let (xa, ga) = with.fold(&x, &gm, eta);
        let (xb, gb) = zero.fold(&x, &gm, eta);
        assert!(xa.max_abs_diff(&xb.add(&with.mem_x)) < 1e-5);
        assert!(ga.max_abs_diff(&gb.add(&with.mem_g)) < 1e-5);
    });
}

#[test]
fn prop_flops_model_consistent() {
    property("flops ratios", 100, |g| {
        let m = g.usize_range(1, 512);
        let n = g.usize_range(1, 512);
        let p = g.usize_range(1, 64);
        let k = g.usize_range(1, m);
        let r = flops::backward_reduction(m, n, p, k);
        assert!((r - k as f64 / m as f64).abs() < 1e-12);
        assert!(flops::aop_step(m, n, p, k).total() >= flops::aop_step(m, n, p, 1).total());
    });
}

// ---------------------------------------------------------------------
// K-schedule invariants (per-layer annealed budgets)
// ---------------------------------------------------------------------

#[test]
fn prop_k_schedule_resolves_in_range_and_roundtrips() {
    property("k schedule range + roundtrip", 120, |g| {
        let batch = g.usize_range(1, 200);
        let total = g.usize_range(1, 60);
        let sched = match g.usize_range(0, 3) {
            0 => KSchedule::Constant(g.usize_range(1, 300)),
            1 => KSchedule::Step {
                k0: g.usize_range(1, 300),
                every: g.usize_range(1, 20),
                gamma: g.f32_range(0.05, 1.0),
            },
            2 => KSchedule::Cosine {
                k0: g.usize_range(1, 300),
                min_frac: g.f32_range(0.0, 1.0),
            },
            _ => KSchedule::Linear {
                from: g.usize_range(1, 300),
                to: g.usize_range(1, 300),
            },
        };
        sched.validate().unwrap_or_else(|e| panic!("{sched:?}: {e}"));
        // the canonical string and the wire form both round-trip exactly
        assert_eq!(KSchedule::parse(&sched.name()).unwrap(), sched, "{sched:?}");
        assert_eq!(
            KSchedule::from_json(&sched.to_json()).unwrap(),
            sched,
            "{sched:?}"
        );
        // resolution is total (epoch 0 and beyond-the-run included) and
        // always clamped to [1, batch]
        for epoch in [0usize, 1, total / 2, total, total + 7] {
            let k = sched.k_at(epoch, total, batch);
            assert!(
                (1..=batch).contains(&k),
                "{sched:?}: k_at({epoch}, {total}, {batch}) = {k}"
            );
            // no epoch beats the declared peak budget
            assert!(k <= sched.max_k().clamp(1, batch), "{sched:?} epoch {epoch}");
        }
        // monotone-decay shapes never grow across the run
        if matches!(sched, KSchedule::Step { .. } | KSchedule::Cosine { .. }) {
            let mut prev = usize::MAX;
            for epoch in 1..=total {
                let k = sched.k_at(epoch, total, batch);
                assert!(k <= prev, "{sched:?}: grew at epoch {epoch}");
                prev = k;
            }
        }
        // linear hits its (clamped) endpoints exactly
        if let KSchedule::Linear { from, to } = sched {
            assert_eq!(sched.k_at(1, total, batch), from.clamp(1, batch));
            if total >= 2 {
                assert_eq!(sched.k_at(total, total, batch), to.clamp(1, batch));
            }
        }
    });
}

// ---------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_partitions_every_epoch() {
    property("batcher partition", 50, |g| {
        let n = g.usize_range(4, 300);
        let bs = g.usize_range(1, n);
        let mut b = Batcher::new(n, bs);
        let mut rng = g.rng().fork(1);
        for _ in 0..3 {
            let batches = b.epoch(&mut rng);
            let mut seen: Vec<usize> = batches.into_iter().flatten().collect();
            assert_eq!(seen.len(), (n / bs) * bs);
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), (n / bs) * bs, "duplicate index in epoch");
        }
    });
}

#[test]
fn prop_dataset_gather_split_consistent() {
    property("dataset ops", 40, |g| {
        let n = g.usize_range(2, 60);
        let c = g.usize_range(1, 8);
        let ds = Dataset::new(randm(g, n, c), randm(g, n, 1));
        let cut = g.usize_range(1, n - 1);
        let (a, b) = ds.split_at(cut);
        assert_eq!(a.len() + b.len(), n);
        // gather with identity permutation reproduces the dataset
        let idx: Vec<usize> = (0..n).collect();
        let gathered = ds.gather(&idx);
        assert_eq!(gathered.x, ds.x);
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_flat_objects() {
    property("json roundtrip", 80, |g| {
        let n = g.usize_range(0, 12);
        let mut pairs = Vec::new();
        for i in 0..n {
            let v = match g.usize_range(0, 3) {
                0 => json::Json::Num(g.f32_range(-1e6, 1e6) as f64),
                1 => json::Json::Bool(g.bool()),
                2 => json::Json::Str(format!("s{}_\"q\"\n", g.u64())),
                _ => json::Json::Null,
            };
            pairs.push((format!("k{i}"), v));
        }
        let obj = json::Json::Obj(pairs);
        let parsed = json::parse(&obj.dump()).unwrap();
        // numbers survive with f64 round-trip precision
        match (&obj, &parsed) {
            (json::Json::Obj(a), json::Json::Obj(b)) => {
                assert_eq!(a.len(), b.len());
                for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
                    assert_eq!(ka, kb);
                    match (va, vb) {
                        (json::Json::Num(x), json::Json::Num(y)) => {
                            assert!((x - y).abs() <= x.abs() * 1e-12)
                        }
                        _ => assert_eq!(va, vb),
                    }
                }
            }
            _ => unreachable!(),
        }
    });
}

#[test]
fn prop_weighted_sampling_never_selects_zero_weight() {
    property("zero weights excluded", 60, |g| {
        let m = g.usize_range(4, 40);
        let mut w = g.vec_uniform(m, 0.5, 2.0);
        // zero half the weights
        let zeroed: Vec<usize> = (0..m).filter(|i| i % 2 == 0).collect();
        for &i in &zeroed {
            w[i] = 0.0;
        }
        let k = g.usize_range(1, m - zeroed.len());
        let idx = g.rng().weighted_sample_without_replacement(&w, k);
        for i in idx {
            assert!(w[i] > 0.0, "selected zero-weight row {i}");
        }
    });
}

#[test]
fn prop_audit_of_exact_memoryless_step_is_lossless() {
    // PR 7 invariant: when the applied update already IS the exact K=M
    // gradient (exact policy, memory off), the gradient-fidelity
    // auditor must report it as such — rel_err ≈ 0, cosine ≈ 1, and a
    // memory bias of exactly 0 (nothing was folded, nothing to re-fold).
    use mem_aop_gd::exec::Executor;
    use mem_aop_gd::model::LossKind;
    use mem_aop_gd::train::{self, AopLayerConfig, Graph, GraphState, GraphWorkspace};

    property("exact audit lossless", 25, |g| {
        let m = g.usize_range(2, 24);
        let n = g.usize_range(1, 10);
        let h = g.usize_range(1, 12);
        let p = g.usize_range(1, 4);
        let x = randm(g, m, n);
        let y = randm(g, m, p);
        let mut wrng = g.rng().fork(3);
        let mut graph = Graph::relu_mlp(&mut wrng, &[n, h, p], LossKind::Mse);
        let cfgs = vec![AopLayerConfig { k: m, policy: Policy::Exact, memory: false }; 2];
        let mut state = GraphState::from_configs(&graph, m, &cfgs);
        let exec = Executor::new(1);
        let mut rng = g.rng().fork(11);
        let mut ws = GraphWorkspace::new(&graph, m);
        for step in 0..3 {
            let out = train::train_step_ws(
                &mut graph, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut ws,
            );
            assert!(out.loss.is_finite());
            let mut recs = Vec::new();
            train::audit_into(&graph, &state, &x, 0.02, &exec, true, &mut ws, &mut recs);
            assert_eq!(recs.len(), 2, "one record per layer");
            for a in &recs {
                assert!(
                    a.rel_err <= 1e-6,
                    "step {step} layer {}: rel_err {}",
                    a.layer,
                    a.rel_err
                );
                assert!(
                    (a.cosine - 1.0).abs() <= 1e-9,
                    "step {step} layer {}: cosine {}",
                    a.layer,
                    a.cosine
                );
                assert_eq!(a.mem_bias, 0.0, "memory off folds nothing");
            }
        }
    });
}

// ---------------------------------------------------------------------
// Mixed-precision trace codecs + widened lane accumulation (§Mixed
// precision): quantization error bounds and accumulator fidelity.
// ---------------------------------------------------------------------

#[test]
fn prop_q8_round_trip_error_bounded_by_half_step() {
    use mem_aop_gd::tensor::quant::{q8_decode, q8_encode_row};
    property("q8 round trip", 80, |g| {
        let len = g.usize_range(1, 200);
        let scale = g.f32_range(0.001, 100.0);
        let row: Vec<f32> = g.vec_normal(len).iter().map(|v| v * scale).collect();
        let mut codes = vec![0i8; len];
        let step = q8_encode_row(&row, &mut codes);
        let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if max_abs == 0.0 {
            assert_eq!(step, 0.0);
            assert!(codes.iter().all(|&c| c == 0));
            return;
        }
        // the advertised per-element bound: half a quantization step
        // (max_abs / 254), padded one ulp for the encoder's division
        for (&v, &c) in row.iter().zip(codes.iter()) {
            let err = (v - q8_decode(c, step)).abs();
            assert!(
                err <= max_abs / 254.0 * (1.0 + 1e-5),
                "len={len} v={v} err={err} max_abs={max_abs}"
            );
        }
        // codes never escape the symmetric range
        assert!(codes.iter().all(|&c| (-127..=127).contains(&(c as i32))));
    });
}

#[test]
fn prop_bf16_exact_on_short_mantissas_and_relatively_bounded() {
    use mem_aop_gd::tensor::quant::{bf16_decode, bf16_encode};
    property("bf16 round trip", 80, |g| {
        // any value that already fits an 8-bit mantissa is a fixed point
        // of the codec: truncating once and truncating twice agree
        let v = g.f32_range(-1e6, 1e6);
        let short = bf16_decode(bf16_encode(v));
        assert_eq!(
            bf16_decode(bf16_encode(short)).to_bits(),
            short.to_bits(),
            "v={v}"
        );
        // and the single truncation is strictly inside one bf16 ulp
        // (2^-7 relative: dropped bits < 2^(e-7), |v| >= 2^e)
        assert!((v - short).abs() <= v.abs() / 128.0, "v={v} short={short}");
    });
}

#[test]
fn prop_widened_dot_tracks_f64_reference_tighter_than_f32() {
    property("widened dot vs f64", 80, |g| {
        use mem_aop_gd::tensor::quant::AccumMode;
        let len = g.usize_range(1, 400);
        let a = g.vec_normal(len);
        let b = g.vec_normal(len);
        let refd: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        let scale: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x as f64 * y as f64).abs())
            .sum::<f64>()
            .max(1.0);
        // f64 lanes round to f32 exactly once: error is one f32 ulp of
        // the result, far inside 1e-6 relative at these magnitudes
        let wide = ops::dot_acc(&a, &b, AccumMode::F64) as f64;
        assert!((wide - refd).abs() <= 1e-6 * scale, "len={len}: {wide} vs {refd}");
        // Kahan compensation holds the same tightened bound
        let kah = ops::dot_acc(&a, &b, AccumMode::Kahan) as f64;
        assert!((kah - refd).abs() <= 1e-6 * scale, "len={len}: {kah} vs {refd}");
        // and the f32 mode is the seed kernel, bit for bit
        assert_eq!(
            ops::dot_acc(&a, &b, AccumMode::F32).to_bits(),
            ops::dot(&a, &b).to_bits()
        );
    });
}

#[test]
fn prop_engine_step_keeps_weights_finite() {
    use mem_aop_gd::aop::AopEngine;
    use mem_aop_gd::model::LossKind;
    property("engine stability", 30, |g| {
        let m = g.usize_range(2, 32);
        let n = g.usize_range(1, 16);
        let k = g.usize_range(1, m);
        let x = randm(g, m, n);
        let y = randm(g, m, 1);
        let w0 = randm(g, n, 1).scale(0.1);
        let pol = match g.usize_range(0, 2) {
            0 => Policy::TopK,
            1 => Policy::RandK,
            _ => Policy::WeightedK,
        };
        let mut e = AopEngine::new(w0, LossKind::Mse, m, pol, k, g.bool());
        let mut rng = g.rng().fork(7);
        for _ in 0..10 {
            let st = e.step(&x, &y, 0.01, &mut rng);
            assert!(st.loss.is_finite());
        }
        assert!(e.w().is_finite());
    });
}
