//! Integration tests for the serve subsystem: a real server on an
//! ephemeral port, hammered over TCP by concurrent clients.
//!
//! Covers the PR acceptance criteria: concurrent submissions across every
//! policy and both backends complete without drops or deadlocks, served
//! loss curves are bit-identical to direct `experiment::run` calls of the
//! same configs, and the persistent run registry survives a full server
//! restart.

// Clock reads are deliberate here (test deadlines and polling timeouts) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;
use std::time::Duration;

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule, Task};
use mem_aop_gd::coordinator::experiment;
use mem_aop_gd::metrics::RunCurve;
use mem_aop_gd::serve::{Client, ServeOptions, Server};

fn spawn_server(
    workers: usize,
    dir: Option<PathBuf>,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    spawn_server_opts(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_capacity: 128,
        registry_dir: dir,
        ..ServeOptions::default()
    })
}

fn spawn_server_opts(
    opts: ServeOptions,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(&opts).expect("bind server");
    let addr = server.local_addr().expect("local addr").to_string();
    (addr, std::thread::spawn(move || server.run()))
}

fn shutdown(addr: &str, handle: std::thread::JoinHandle<anyhow::Result<()>>) {
    let mut c = Client::connect(addr).expect("connect for shutdown");
    c.shutdown().expect("shutdown op");
    handle.join().expect("server thread").expect("server run");
}

/// 5-policy native job mix (seed = index), 3 epochs of the energy task.
fn native_cfg(i: usize) -> ExperimentConfig {
    let policies = Policy::all();
    let p = policies[i % policies.len()];
    let mut cfg = ExperimentConfig::preset(Task::Energy);
    cfg.policy = p;
    cfg.memory = p != Policy::Exact;
    cfg.k = KSchedule::constant(if p == Policy::Exact { cfg.m() } else { [18, 9][i % 2] });
    cfg.epochs = 3;
    cfg.seed = i as u64;
    cfg.backend = Backend::Native;
    cfg
}

fn assert_bit_identical(served: &RunCurve, direct: &RunCurve, what: &str) {
    assert_eq!(served.epochs.len(), direct.epochs.len(), "{what}: length");
    assert_eq!(served.label, direct.label, "{what}: label");
    for (e, (a, b)) in served.epochs.iter().zip(&direct.epochs).enumerate() {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{what} ep{e}");
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits(), "{what} ep{e}");
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits(), "{what} ep{e}");
        assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits(), "{what} ep{e}");
        assert_eq!(a.mem_fro.to_bits(), b.mem_fro.to_bits(), "{what} ep{e}");
        assert_eq!(a.backward_flops, b.backward_flops, "{what} ep{e}");
    }
}

#[test]
fn concurrent_jobs_across_policies_and_backends() {
    let (addr, handle) = spawn_server(4, None);
    const NATIVE_JOBS: usize = 10;

    // 10 native jobs over 10 concurrent connections (one per thread)...
    let served: Vec<(usize, RunCurve)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..NATIVE_JOBS {
            let addr = addr.clone();
            handles.push(scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let id = c.submit(&native_cfg(i), &format!("job-{i}")).expect("submit");
                let job = c.wait(id, Duration::from_secs(120)).expect("wait");
                assert_eq!(
                    job.get("state").and_then(|s| s.as_str()),
                    Some("done"),
                    "job {i}: {}",
                    job.dump()
                );
                let (cfg, curve) = c.result(id).expect("result");
                assert_eq!(cfg.seed, i as u64);
                (i, curve)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // ...every curve bit-identical to a direct run of the same config
    assert_eq!(served.len(), NATIVE_JOBS);
    for (i, curve) in &served {
        let direct = experiment::run(&native_cfg(*i)).expect("direct run");
        assert_bit_identical(curve, &direct.curve, &format!("job {i}"));
    }

    // ...plus an HLO-backend job, which must fail *cleanly* in the
    // offline build (no `hlo` feature) with an actionable error
    let mut c = Client::connect(&addr).expect("connect");
    let mut hlo = native_cfg(0);
    hlo.backend = Backend::Hlo;
    let id = c.submit(&hlo, "hlo-job").expect("submit hlo");
    let job = c.wait(id, Duration::from_secs(120)).expect("wait hlo");
    if cfg!(feature = "hlo") {
        // with real bindings this would need artifacts; the stub vendor
        // crate still reports unavailability at runtime
        assert_ne!(job.get("state").and_then(|s| s.as_str()), Some("queued"));
    } else {
        assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("failed"));
        let err = job.get("error").and_then(|e| e.as_str()).unwrap_or("");
        assert!(err.contains("hlo") || err.contains("unavailable"), "{err}");
    }

    // metrics reflect the completed work with no dropped jobs
    let m = c.metrics().expect("metrics");
    let jobs = m.get("jobs").expect("jobs block");
    assert_eq!(
        jobs.get("done").and_then(|n| n.as_usize()),
        Some(NATIVE_JOBS),
        "{}",
        m.dump()
    );
    assert_eq!(jobs.get("queued").and_then(|n| n.as_usize()), Some(0));
    let pols = m.get("policies").and_then(|p| p.as_arr()).expect("policies");
    assert_eq!(pols.len(), Policy::all().len(), "one rollup row per policy");

    shutdown(&addr, handle);
}

#[test]
fn concurrent_load_metrics_accounting_is_exact_and_monotone() {
    // ISSUE 6 satellite: hammer the server from 8 concurrent clients
    // with a known request mix, then read the per-op accounting. Each
    // request records exactly one latency sample, so the op histogram
    // totals must sum to `requests_total` exactly — even though the
    // `wait` polls make the status count itself nondeterministic.
    use mem_aop_gd::util::json::{self, Json};

    let (addr, handle) = spawn_server(3, None);
    const CLIENTS: usize = 8;
    std::thread::scope(|scope| {
        for i in 0..CLIENTS {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                for _ in 0..3 {
                    c.ping().expect("ping");
                }
                let id = c.submit(&native_cfg(i), &format!("load-{i}")).expect("submit");
                let job = c.wait(id, Duration::from_secs(120)).expect("wait");
                assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));
                c.list().expect("list");
            });
        }
    });

    let mut c = Client::connect(&addr).expect("connect");
    let m = c.metrics().expect("metrics");
    let total = m.get("requests_total").and_then(|n| n.as_usize()).unwrap();
    let op_count = |m: &Json, op: &str| -> usize {
        m.get("ops")
            .and_then(|a| a.as_arr())
            .unwrap()
            .iter()
            .find(|o| o.get("op").and_then(|s| s.as_str()) == Some(op))
            .and_then(|o| o.get("count"))
            .and_then(|n| n.as_usize())
            .unwrap_or(0)
    };
    // deterministic slices of the mix
    assert_eq!(op_count(&m, "ping"), 3 * CLIENTS, "{}", m.dump());
    assert_eq!(op_count(&m, "submit"), CLIENTS);
    assert_eq!(op_count(&m, "list"), CLIENTS);
    assert_eq!(op_count(&m, "error"), 0);
    assert_eq!(op_count(&m, "metrics"), 1, "records itself before rendering");
    // the accounting invariant: every request left exactly one sample
    let sum: usize = m
        .get("ops")
        .and_then(|a| a.as_arr())
        .unwrap()
        .iter()
        .map(|o| o.get("count").and_then(|n| n.as_usize()).unwrap())
        .sum();
    assert_eq!(sum, total, "op histogram totals must equal requests_total");
    // the work itself is fully accounted: no dropped or stuck jobs
    let jobs = m.get("jobs").expect("jobs block");
    assert_eq!(jobs.get("done").and_then(|n| n.as_usize()), Some(CLIENTS));
    assert_eq!(jobs.get("queued").and_then(|n| n.as_usize()), Some(0));
    assert_eq!(jobs.get("running").and_then(|n| n.as_usize()), Some(0));
    assert_eq!(m.get("queue_depth").and_then(|n| n.as_usize()), Some(0));
    let pool = m.get("pool").expect("pool block");
    assert_eq!(pool.get("workers_busy").and_then(|n| n.as_usize()), Some(0));
    assert_eq!(pool.get("tasks_pending").and_then(|n| n.as_usize()), Some(0));

    // counters are monotone across scrapes, and the second scrape sees
    // the first one's sample
    let m2 = c.metrics().expect("metrics again");
    let total2 = m2.get("requests_total").and_then(|n| n.as_usize()).unwrap();
    assert!(total2 > total);
    assert_eq!(op_count(&m2, "metrics"), 2);
    for op in ["ping", "submit", "status", "list"] {
        assert!(op_count(&m2, op) >= op_count(&m, op), "{op} went backwards");
    }

    // Prometheus exposition round-trips through the wire format
    let text = c.metrics_prometheus().expect("prometheus");
    assert!(text.contains("# TYPE repro_requests_total counter"), "{text}");
    assert!(text.contains("# TYPE repro_request_latency_seconds histogram"));
    assert!(text.contains("repro_jobs_total{state=\"done\"} 8"), "{text}");
    assert!(text.contains("{op=\"ping\""), "{text}");
    assert!(text.contains("le=\"+Inf\""), "{text}");
    assert!(text.contains("repro_slots_total"));
    assert!(text.contains("repro_policy_jobs_total"));

    // compact metrics: gauges only — no per-op, policy, or pool blocks
    let mc = c.metrics_compact().expect("compact metrics");
    assert!(mc.get("requests_total").is_some());
    assert!(mc.get("ops").is_none(), "{}", mc.dump());
    assert!(mc.get("policies").is_none());
    assert!(mc.get("pool").is_none());

    // compact job views: the polled fields without the config echo;
    // the full view carries the per-job phase rollup (protocol v5)
    let done_id = {
        let listed = c.list().expect("list");
        listed[0].get("id").and_then(|n| n.as_usize()).unwrap() as u64
    };
    let full = c.status(done_id).expect("status");
    assert!(full.get("config").is_some());
    let phases = full.get("phases").expect("done native job carries phases");
    assert!(!matches!(phases, Json::Null), "{}", full.dump());
    assert!(phases.get("steps").and_then(|n| n.as_usize()).unwrap() > 0);
    let compact = c.status_compact(done_id).expect("compact status");
    assert!(compact.get("config").is_none(), "{}", compact.dump());
    assert!(compact.get("phases").is_none());
    assert!(compact.get("layers").is_none());
    assert_eq!(
        compact.get("state").and_then(|s| s.as_str()),
        Some("done"),
        "compact view still answers the polling question"
    );
    // compact list drops the echo from every element
    let resp = c
        .call(&json::obj(vec![
            ("op", json::s("list")),
            ("compact", Json::Bool(true)),
        ]))
        .expect("compact list");
    for v in resp.get("jobs").and_then(|a| a.as_arr()).unwrap() {
        assert!(v.get("config").is_none(), "{}", v.dump());
    }

    shutdown(&addr, handle);
}

#[test]
fn registry_survives_server_restart() {
    let dir = std::env::temp_dir().join(format!("memaop_serve_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // first server lifetime: run three jobs to completion
    let (addr, handle) = spawn_server(2, Some(dir.clone()));
    let mut ids = Vec::new();
    {
        let mut c = Client::connect(&addr).expect("connect");
        for i in 0..3 {
            ids.push(c.submit(&native_cfg(i), &format!("persisted-{i}")).expect("submit"));
        }
        for &id in &ids {
            let job = c.wait(id, Duration::from_secs(120)).expect("wait");
            assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));
        }
    }
    shutdown(&addr, handle);

    // second server over the same registry dir: history is back
    let (addr2, handle2) = spawn_server(2, Some(dir.clone()));
    let mut c = Client::connect(&addr2).expect("connect restarted");
    let jobs = c.list().expect("list");
    assert_eq!(jobs.len(), 3, "restored jobs missing");
    for v in &jobs {
        assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("done"));
        assert_eq!(v.get("restored").and_then(|b| b.as_bool()), Some(true));
    }
    // results (config + full curve) survive the restart bit-for-bit
    for (i, &id) in ids.iter().enumerate() {
        let (cfg, curve) = c.result(id).expect("restored result");
        assert_eq!(cfg.seed, i as u64);
        let direct = experiment::run(&native_cfg(i)).expect("direct run");
        assert_bit_identical(&curve, &direct.curve, &format!("restored job {id}"));
    }
    // fresh ids continue above the restored history
    let new_id = c.submit(&native_cfg(7), "after-restart").expect("submit");
    assert!(new_id > *ids.iter().max().unwrap());
    c.wait(new_id, Duration::from_secs(120)).expect("wait new");
    shutdown(&addr2, handle2);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn watch_streams_audited_epochs_over_tcp() {
    // PR 7 acceptance: a live `watch` subscriber receives every epoch
    // frame of an audited job, each audited frame carries finite
    // per-layer fidelity records, and the stream agrees bit-for-bit
    // with the job's final result and phase rollup.
    use std::time::Instant;

    let (addr, handle) = spawn_server(2, None);
    let mut c = Client::connect(&addr).expect("connect");

    let mut cfg = native_cfg(0);
    cfg.policy = Policy::TopK;
    cfg.memory = true;
    cfg.k = KSchedule::Constant(18);
    cfg.audit = Some(1); // audit every epoch
    let id = c.submit(&cfg, "watched").expect("submit");

    // long-poll until the job is terminal and the stream has drained
    let mut frames = Vec::new();
    let mut cursor = 0usize;
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (batch, next, state) = c.watch(id, cursor, 2_000).expect("watch");
        assert!(next >= cursor, "cursor went backwards");
        let drained = batch.is_empty();
        frames.extend(batch);
        cursor = next;
        if drained && matches!(state.as_str(), "done" | "failed" | "cancelled") {
            assert_eq!(state, "done", "watched job must complete");
            break;
        }
        assert!(Instant::now() < deadline, "watch never drained");
    }

    // every epoch arrived exactly once, in order, with audit records
    assert_eq!(frames.len(), cfg.epochs);
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.get("epoch").and_then(|n| n.as_usize()), Some(i + 1));
        let audit = f.get("audit").and_then(|a| a.as_arr()).expect("audited frame");
        assert_eq!(audit.len(), 1, "flat config = one layer");
        let a = &audit[0];
        let cosine = a.get("cosine").and_then(|v| v.as_f64()).unwrap();
        let rel_err = a.get("rel_err").and_then(|v| v.as_f64()).unwrap();
        let mem_bias = a.get("mem_bias").and_then(|v| v.as_f64()).unwrap();
        assert!(cosine.is_finite() && (-1.0..=1.0).contains(&cosine));
        assert!(rel_err.is_finite() && rel_err > 0.0, "K=18/144 approximates");
        assert!(mem_bias.is_finite());
    }

    // the stream agrees with the stored result bit-for-bit
    let (_, curve) = c.result(id).expect("result");
    assert_eq!(curve.epochs.len(), frames.len());
    for (f, m) in frames.iter().zip(curve.epochs.iter()) {
        let streamed = f.get("train_loss").and_then(|v| v.as_f64()).unwrap() as f32;
        assert_eq!(streamed.to_bits(), m.train_loss.to_bits());
        assert_eq!(m.audit.len(), 1, "result curve keeps the audit records");
    }

    // ...and with the job view's phase rollup (latest audit wins)
    let view = c.status(id).expect("status");
    let layers = view
        .get("phases")
        .and_then(|p| p.get("layers"))
        .and_then(|l| l.as_arr())
        .expect("phase rollup layers")
        .to_vec();
    let last = frames.last().unwrap().get("audit").and_then(|a| a.as_arr()).unwrap().to_vec();
    assert_eq!(
        layers[0].get("audits").and_then(|n| n.as_usize()),
        Some(cfg.epochs),
        "one audit per epoch at cadence every:1"
    );
    assert_eq!(
        layers[0].get("audit_cosine").and_then(|v| v.as_f64()),
        last[0].get("cosine").and_then(|v| v.as_f64()),
    );
    assert_eq!(
        layers[0].get("audit_rel_err").and_then(|v| v.as_f64()),
        last[0].get("rel_err").and_then(|v| v.as_f64()),
    );

    // cursor resume: re-watching from epoch 1 replays only 2..=N
    let (tail, _, state) = c.watch(id, 1, 0).expect("resume");
    assert_eq!(state, "done");
    assert_eq!(tail.len(), cfg.epochs - 1);
    assert_eq!(tail[0].get("epoch").and_then(|n| n.as_usize()), Some(2));
    // a cursor past the end streams nothing
    let (empty, _, _) = c.watch(id, cursor, 0).expect("past-end watch");
    assert!(empty.is_empty());

    // watching an unknown job is a clean protocol error
    assert!(c.watch(999_999, 0, 0).is_err());

    // a cancelled job's watch returns promptly — terminal state short-
    // circuits the long-poll instead of burning the full wait_ms
    let victim = c.submit(&native_cfg(1), "victim").expect("submit victim");
    let _ = c.cancel(victim); // may already be running; wait either way
    let v = c.wait(victim, Duration::from_secs(120)).expect("wait victim");
    let vstate = v.get("state").and_then(|s| s.as_str()).unwrap().to_string();
    let t0 = Instant::now();
    let (_, _, wstate) = c.watch(victim, 1_000, 10_000).expect("watch terminal");
    assert_eq!(wstate, vstate);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "terminal watch must not block for wait_ms"
    );

    shutdown(&addr, handle);
}

#[test]
fn cancellation_and_queue_ordering() {
    // one worker ⇒ jobs run strictly in submission order
    let (addr, handle) = spawn_server(1, None);
    let mut c = Client::connect(&addr).expect("connect");

    // a deliberately slower first job to hold the single worker...
    let mut slow = ExperimentConfig::preset(Task::Mnist);
    slow.policy = Policy::TopK;
    slow.k = KSchedule::Constant(16);
    slow.memory = true;
    slow.data_scale = 0.05;
    slow.epochs = 15;
    slow.seed = 99;
    slow.backend = Backend::Native;
    let slow_id = c.submit(&slow, "slow").expect("submit slow");

    // ...then quick jobs queue behind it; the last one gets cancelled
    // while still queued
    let a = c.submit(&native_cfg(1), "quick-a").expect("submit a");
    let victim = c.submit(&native_cfg(2), "victim").expect("submit victim");
    let state = c.cancel(victim).expect("cancel victim");
    assert!(
        state == "cancelled" || state == "cancelling",
        "unexpected cancel state {state}"
    );
    let v = c.wait(victim, Duration::from_secs(120)).expect("wait victim");
    assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("cancelled"));

    // the survivors complete normally
    for id in [slow_id, a] {
        let job = c.wait(id, Duration::from_secs(300)).expect("wait survivor");
        assert_eq!(
            job.get("state").and_then(|s| s.as_str()),
            Some("done"),
            "{}",
            job.dump()
        );
    }
    // double-cancel of a terminal job is a clean protocol error
    assert!(c.cancel(victim).is_err());

    shutdown(&addr, handle);
}

/// A deliberately slower config that holds a worker for a while.
fn slow_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Task::Mnist);
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(16);
    cfg.memory = true;
    cfg.data_scale = 0.05;
    cfg.epochs = 15;
    cfg.seed = seed;
    cfg.backend = Backend::Native;
    cfg
}

fn submit_frame(cfg: &ExperimentConfig, tag: &str) -> mem_aop_gd::util::json::Json {
    use mem_aop_gd::util::json::{self};
    json::obj(vec![
        ("op", json::s("submit")),
        ("config", cfg.to_json()),
        ("tag", json::s(tag)),
    ])
}

#[test]
fn queue_saturation_degrades_health_and_rejects_with_retry_hints() {
    use mem_aop_gd::serve::RetryPolicy;
    use mem_aop_gd::util::json::{self};

    // one worker, one queue slot: saturation is two submits away
    let (addr, handle) = spawn_server_opts(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 1,
        ..ServeOptions::default()
    });
    let mut c = Client::connect(&addr).expect("connect");

    // a fresh server is healthy, and the probe round-trips the pool
    let h = c.health().expect("health");
    assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(h.get("pool_alive").and_then(|b| b.as_bool()), Some(true));
    assert!(h.get("probe_ms").and_then(|n| n.as_f64()).unwrap() >= 0.0);
    assert_eq!(h.get("queue_capacity").and_then(|n| n.as_usize()), Some(1));

    // hold the worker, then fill the single queue slot
    let slow_id = c.submit(&slow_cfg(99), "slow").expect("submit slow");
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let s = c.status(slow_id).expect("status");
        if s.get("state").and_then(|v| v.as_str()) == Some("running") {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "slow job never started");
        std::thread::sleep(Duration::from_millis(10));
    }
    let queued_id = c.submit(&native_cfg(1), "queued").expect("submit queued");

    // the queue is at capacity: health degrades...
    let h = c
        .call(&json::obj(vec![("op", json::s("health")), ("wait_ms", json::num(500.0))]))
        .expect("health at capacity");
    assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("degraded"), "{}", h.dump());
    assert_eq!(h.get("queue_depth").and_then(|n| n.as_usize()), Some(1));

    // ...and the next submit is a structured queue_full rejection with a
    // usable retry hint, not a hang or a bare error string
    let r = c.call(&submit_frame(&native_cfg(2), "overflow")).expect("call");
    assert_eq!(r.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert_eq!(r.get("reason").and_then(|s| s.as_str()), Some("queue_full"), "{}", r.dump());
    let hint = r.get("retry_after_ms").and_then(|n| n.as_usize()).expect("retry hint");
    assert!(hint > 0 && hint <= 5_000, "hint {hint}ms");
    assert!(
        r.get("error").and_then(|e| e.as_str()).unwrap().contains("queue full"),
        "{}",
        r.dump()
    );

    // a retrying client rides out the saturation: cancel the *running*
    // job shortly after the retries start — it stops at the next epoch
    // boundary, the worker drains the queued job, and the queue frees up
    let addr2 = addr.clone();
    let canceller = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(300));
        let mut c2 = Client::connect(&addr2).expect("connect canceller");
        let _ = c2.cancel(slow_id);
    });
    let policy = RetryPolicy { attempts: 12, base_ms: 50, max_ms: 500, seed: 42 };
    let (retried_id, retries) = c
        .submit_with_retry(&native_cfg(3), "retried", &policy)
        .expect("retrying submit must eventually land");
    assert!(retries >= 1, "the first attempt hit a full queue");
    canceller.join().unwrap();

    // everything drains: the quick jobs complete, the slow one stopped
    // at an epoch boundary (or finished just before the cancel landed)
    for id in [queued_id, retried_id] {
        let job = c.wait(id, Duration::from_secs(300)).expect("wait");
        assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"), "{}", job.dump());
    }
    let slow = c.wait(slow_id, Duration::from_secs(300)).expect("wait slow");
    assert!(
        matches!(slow.get("state").and_then(|s| s.as_str()), Some("cancelled") | Some("done")),
        "{}",
        slow.dump()
    );
    let h = c.health().expect("health after drain");
    assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));

    // the rejection surfaced in the Prometheus scrape
    let text = c.metrics_prometheus().expect("prometheus");
    assert!(text.contains("# TYPE repro_rejected_total counter"), "{text}");
    assert!(!text.contains("repro_rejected_total{reason=\"queue_full\"} 0\n"), "{text}");
    assert!(text.contains("repro_health_status 1\n"), "{text}");

    shutdown(&addr, handle);
}

#[test]
fn rate_limited_submits_carry_hints_and_the_client_retries_through() {
    use mem_aop_gd::serve::RetryPolicy;

    let (addr, handle) = spawn_server_opts(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_capacity: 128,
        rate_limit_per_sec: 2.0,
        rate_limit_burst: 2.0,
        ..ServeOptions::default()
    });
    let mut c = Client::connect(&addr).expect("connect");

    // the burst budget admits two, the third bounces with a hint
    c.submit(&native_cfg(0), "rl-0").expect("submit 0");
    c.submit(&native_cfg(1), "rl-1").expect("submit 1");
    let r = c.call(&submit_frame(&native_cfg(2), "rl-2")).expect("call");
    assert_eq!(r.get("ok").and_then(|b| b.as_bool()), Some(false), "{}", r.dump());
    assert_eq!(r.get("reason").and_then(|s| s.as_str()), Some("rate_limited"));
    let hint = r.get("retry_after_ms").and_then(|n| n.as_usize()).expect("hint");
    assert!(hint >= 1 && hint <= 500, "hint {hint}ms at 2 tokens/s");

    // non-submit ops are never rate limited
    c.ping().expect("ping");
    c.list().expect("list");

    // the retrying client honors the hint and lands once a token refills
    let policy = RetryPolicy { seed: 7, ..RetryPolicy::default() };
    let (id, retries) =
        c.submit_with_retry(&native_cfg(2), "rl-2", &policy).expect("retry through");
    assert!(retries >= 1, "the limiter must have pushed back at least once");
    let job = c.wait(id, Duration::from_secs(120)).expect("wait");
    assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));

    let text = c.metrics_prometheus().expect("prometheus");
    assert!(!text.contains("repro_rejected_total{reason=\"rate_limited\"} 0\n"), "{text}");

    shutdown(&addr, handle);
}

#[test]
fn stalled_client_hits_the_frame_deadline_without_blocking_others() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (addr, handle) = spawn_server_opts(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_capacity: 16,
        frame_timeout: Duration::from_millis(500),
        ..ServeOptions::default()
    });

    // a client that sends half a frame and stalls forever
    let mut loris = TcpStream::connect(&addr).expect("connect stalled");
    loris.write_all(b"{\"op\":\"sub").expect("partial write");

    // a healthy client on another connection is completely unaffected
    let mut c = Client::connect(&addr).expect("connect healthy");
    let id = c.submit(&native_cfg(0), "healthy").expect("submit");
    let job = c.wait(id, Duration::from_secs(120)).expect("wait");
    assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("done"));

    // the stalled connection was told off and closed
    let mut reader = BufReader::new(loris.try_clone().expect("clone"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("read deadline response");
    assert!(line.contains("frame timeout"), "{line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).expect("read eof"), 0, "must be closed");

    shutdown(&addr, handle);
}

#[test]
fn wall_clock_timeout_fails_the_job_and_frees_its_slot() {
    let (addr, handle) = spawn_server(1, None);
    let mut c = Client::connect(&addr).expect("connect");

    // a budget far below what 15 mnist epochs need: the job must be
    // finalized as failed at an epoch boundary, not run to completion
    let mut cfg = slow_cfg(5);
    cfg.timeout_s = Some(0.02);
    let id = c.submit(&cfg, "budgeted").expect("submit");
    let job = c.wait(id, Duration::from_secs(120)).expect("wait");
    assert_eq!(job.get("state").and_then(|s| s.as_str()), Some("failed"), "{}", job.dump());
    let err = job.get("error").and_then(|e| e.as_str()).unwrap_or("");
    assert!(err.contains("timeout") && err.contains("0.02"), "{err}");

    // the single worker slot was released: an untimed job runs to done
    let id2 = c.submit(&native_cfg(0), "after").expect("submit after");
    let job2 = c.wait(id2, Duration::from_secs(120)).expect("wait after");
    assert_eq!(job2.get("state").and_then(|s| s.as_str()), Some("done"));

    shutdown(&addr, handle);
}

#[test]
fn chaos_soak_leaves_no_stuck_jobs_and_completions_stay_bit_identical() {
    use mem_aop_gd::serve::{FaultPlan, RetryPolicy};
    use mem_aop_gd::util::json::Json;
    use std::time::Instant;

    let dir = std::env::temp_dir().join(format!("memaop_serve_chaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // every fault family at once: worker panics at epoch boundaries,
    // torn registry writes, connections dropped before replies
    let faults = FaultPlan::parse("seed=7,panic=150,torn=250,drop=60").expect("fault spec");
    let (addr, handle) = spawn_server_opts(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        queue_capacity: 128,
        registry_dir: Some(dir.clone()),
        faults,
        ..ServeOptions::default()
    });

    // a 64-job burst over 8 connections, submitted with the retrying
    // client (dropped connections re-dial; duplicate submits are fine —
    // determinism makes the twin train the identical curve)
    const JOBS: usize = 64;
    const CONNS: usize = 8;
    std::thread::scope(|scope| {
        for t in 0..CONNS {
            let addr = addr.clone();
            scope.spawn(move || {
                let policy = RetryPolicy { seed: t as u64, ..RetryPolicy::default() };
                let mut c = Client::connect(&addr).expect("connect");
                for i in (0..JOBS).filter(|i| i % CONNS == t) {
                    c.submit_with_retry(&native_cfg(i), &format!("chaos-{i}"), &policy)
                        .expect("submit under chaos");
                }
            });
        }
    });

    // drain resiliently: list until nothing is queued or running (a
    // dropped reply just means reconnect and ask again)
    let mut c = Client::connect(&addr).expect("connect");
    let deadline = Instant::now() + Duration::from_secs(300);
    let views: Vec<Json> = loop {
        let views = match c.list() {
            Ok(v) => v,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(50));
                c = Client::connect(&addr).expect("reconnect");
                continue;
            }
        };
        let live = views
            .iter()
            .filter(|v| {
                matches!(
                    v.get("state").and_then(|s| s.as_str()),
                    Some("queued") | Some("running")
                )
            })
            .count();
        if live == 0 && views.len() >= JOBS {
            break views;
        }
        assert!(Instant::now() < deadline, "jobs stuck under chaos ({live} live)");
        std::thread::sleep(Duration::from_millis(100));
    };

    // zero stuck jobs; every job is done or failed-by-injection, and
    // every completed job's curve is bit-identical to its fault-free twin
    let mut done = 0usize;
    let mut failed = 0usize;
    let mut verified = std::collections::BTreeSet::new();
    for v in &views {
        let id = v.get("id").and_then(|n| n.as_usize()).unwrap() as u64;
        let tag = v.get("tag").and_then(|s| s.as_str()).unwrap_or("").to_string();
        let i: usize = tag.strip_prefix("chaos-").expect("chaos tag").parse().unwrap();
        match v.get("state").and_then(|s| s.as_str()).unwrap_or("?") {
            "done" => {
                done += 1;
                if verified.insert(i) {
                    let (cfg, curve) = loop {
                        match c.result(id) {
                            Ok(r) => break r,
                            Err(_) => c = Client::connect(&addr).expect("reconnect"),
                        }
                    };
                    assert_eq!(cfg.seed, i as u64);
                    let direct = experiment::run(&native_cfg(i)).expect("direct twin");
                    assert_bit_identical(&curve, &direct.curve, &format!("chaos job {id}"));
                }
            }
            "failed" => {
                failed += 1;
                let err = v.get("error").and_then(|e| e.as_str()).unwrap_or("");
                assert!(
                    err.contains("injected worker panic"),
                    "job {id} failed for a non-injected reason: {err}"
                );
            }
            other => panic!("job {id} left in state {other}"),
        }
    }
    assert!(done > 0, "no jobs completed under chaos");
    assert!(failed > 0, "panic rate 150/1000 per epoch should fail some of {JOBS} jobs");

    // shut down resiliently (the shutdown reply itself can be dropped)
    loop {
        match Client::connect(&addr) {
            Ok(mut sc) => {
                if sc.shutdown().is_ok() {
                    break;
                }
            }
            Err(_) => break, // listener already gone: the flag landed
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.join().expect("server thread").expect("server run");

    // restart over the same registry, faults off: torn entries were
    // skipped at load, every restored job is a healthy completion
    let (addr2, handle2) = spawn_server(2, Some(dir.clone()));
    let mut c2 = Client::connect(&addr2).expect("connect restarted");
    let restored = c2.list().expect("list restored");
    assert!(
        restored.len() <= done,
        "restored {} jobs but only {done} completed",
        restored.len()
    );
    for v in &restored {
        assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("done"), "{}", v.dump());
        assert_eq!(v.get("restored").and_then(|b| b.as_bool()), Some(true));
    }
    shutdown(&addr2, handle2);

    let _ = std::fs::remove_dir_all(&dir);
}
