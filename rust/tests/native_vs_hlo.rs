//! Cross-check: the native Rust path and the AOT/PJRT path are the same
//! algorithm.
//!
//! Both backends share seeds for weight init, data generation, epoch
//! shuffling and policy draws (owned by `experiment::run_with_trainer`),
//! so for any configuration their curves and final weights must agree to
//! float32 accumulation tolerance. This is the strongest correctness
//! statement in the repo: it ties the Pallas kernels (inside the HLO) to
//! the hand-written Rust math over full multi-epoch trainings.
//!
//! Requires `make artifacts`; the suite is skipped (with a note) if the
//! artifacts directory is missing.

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::experiment::{self, RunResult};
use mem_aop_gd::runtime::{Manifest, Runtime};

fn artifacts_available() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

fn run_both(mut cfg: ExperimentConfig) -> Option<(RunResult, RunResult)> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    cfg.backend = Backend::Native;
    let native = experiment::run(&cfg).expect("native run");
    cfg.backend = Backend::Hlo;
    let rt = Runtime::from_default_artifacts().expect("runtime");
    let hlo = experiment::run_hlo(&cfg, &rt).expect("hlo run");
    Some((native, hlo))
}

fn assert_close_curves(a: &RunResult, b: &RunResult, tol: f32) {
    assert_eq!(a.curve.epochs.len(), b.curve.epochs.len());
    for (ma, mb) in a.curve.epochs.iter().zip(b.curve.epochs.iter()) {
        let d = (ma.val_loss - mb.val_loss).abs();
        let rel = d / ma.val_loss.abs().max(1e-6);
        assert!(
            rel < tol || d < tol,
            "epoch {}: native {} vs hlo {} (rel {rel})",
            ma.epoch,
            ma.val_loss,
            mb.val_loss
        );
    }
    let wd = a.final_w().max_abs_diff(b.final_w());
    let scale = a.final_w().frobenius().max(1e-6);
    assert!(wd / scale < tol, "weight divergence {wd} (scale {scale})");
}

#[test]
fn energy_exact_baseline_agrees() {
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.epochs = 15;
    if let Some((n, h)) = run_both(cfg) {
        assert_close_curves(&n, &h, 2e-3);
    }
}

#[test]
fn energy_topk_with_memory_agrees() {
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(18);
    cfg.memory = true;
    cfg.epochs = 15;
    if let Some((n, h)) = run_both(cfg) {
        assert_close_curves(&n, &h, 2e-3);
    }
}

#[test]
fn energy_randk_no_memory_agrees() {
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = Policy::RandK;
    cfg.k = KSchedule::Constant(9);
    cfg.memory = false;
    cfg.epochs = 10;
    if let Some((n, h)) = run_both(cfg) {
        assert_close_curves(&n, &h, 2e-3);
    }
}

#[test]
fn energy_weightedk_agrees() {
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = Policy::WeightedK;
    cfg.k = KSchedule::Constant(9);
    cfg.memory = true;
    cfg.epochs = 10;
    cfg.seed = 3;
    if let Some((n, h)) = run_both(cfg) {
        assert_close_curves(&n, &h, 2e-3);
    }
}

#[test]
fn mnist_topk_agrees_scaled() {
    let mut cfg = ExperimentConfig::mnist_preset();
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(16);
    cfg.memory = true;
    cfg.epochs = 2;
    cfg.data_scale = 0.02;
    if let Some((n, h)) = run_both(cfg) {
        // larger model, more accumulation divergence allowed
        assert_close_curves(&n, &h, 5e-3);
    }
}

#[test]
fn mnist_weightedk_replacement_agrees_scaled() {
    let mut cfg = ExperimentConfig::mnist_preset();
    cfg.policy = Policy::WeightedKReplacement;
    cfg.k = KSchedule::Constant(16);
    cfg.memory = true;
    cfg.epochs = 2;
    cfg.data_scale = 0.02;
    if let Some((n, h)) = run_both(cfg) {
        assert_close_curves(&n, &h, 5e-3);
    }
}
