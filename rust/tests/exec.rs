//! Determinism property tests for the `exec` data-parallel engine and
//! the layer-graph training core on top of it.
//!
//! The contract under test: **the thread count is not a hyperparameter**.
//! For every selection policy, both execution regimes (mask and
//! compaction), memory on/off, every activation, homogeneous *and*
//! heterogeneous per-layer K — engine-level, graph-level,
//! experiment-level, and through a served job — `threads ∈ {1, 2, 4, 7}`
//! must produce bit-identical losses, curves, and final weights. Every
//! comparison here is exact (`to_bits` / slice equality), never
//! tolerance-based.
//!
//! `ci.sh` runs this suite at two `REPRO_THREADS` settings; the
//! `determinism_at_env_worker_count` test picks its parallelism from
//! that env var so the two CI runs genuinely exercise different pools.

use std::time::Duration;

use mem_aop_gd::aop::engine::AopEngine;
use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{ExperimentConfig, KSchedule, LayerSpec, Task};
use mem_aop_gd::coordinator::experiment::{self, RunResult};
use mem_aop_gd::exec::Executor;
use mem_aop_gd::model::activations::Activation;
use mem_aop_gd::model::loss::LossKind;
use mem_aop_gd::serve::{Client, ServeOptions, Server};
use mem_aop_gd::tensor::{init, rng::Rng, Matrix};
use mem_aop_gd::train::{self, AopLayerConfig, Graph, GraphState, GraphWorkspace};
use mem_aop_gd::util::pool;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn synth_data(seed: u64, m: usize, n: usize, p: usize) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let teacher = Matrix::from_fn(n, p, |_, _| rng.normal());
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let y = x.matmul(&teacher);
    (x, y)
}

/// Train one engine for `steps` and return (per-step losses, w, b).
fn train_engine(
    policy: Policy,
    compact: bool,
    memory: bool,
    threads: usize,
    steps: usize,
) -> (Vec<u32>, Matrix, Vec<f32>) {
    let (m, n, p) = (48usize, 12usize, 3usize);
    let (x, y) = synth_data(7, m, n, p);
    let mut wrng = Rng::new(13);
    let mut e = AopEngine::new(
        init::glorot_uniform(&mut wrng, n, p),
        LossKind::Mse,
        m,
        policy,
        12,
        memory,
    );
    e.compact = compact;
    let exec = Executor::new(threads);
    let mut rng = Rng::new(99);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let st = e.step_exec(&x, &y, 0.02, &mut rng, &exec);
        assert!(st.loss.is_finite());
        losses.push(st.loss.to_bits());
    }
    (losses, e.w().clone(), e.b().to_vec())
}

#[test]
fn engine_bit_identical_across_threads_for_all_policies_and_regimes() {
    for policy in Policy::all() {
        for compact in [true, false] {
            for memory in [true, false] {
                let (l1, w1, b1) = train_engine(policy, compact, memory, 1, 30);
                for threads in &THREAD_COUNTS[1..] {
                    let (lt, wt, bt) = train_engine(policy, compact, memory, *threads, 30);
                    assert_eq!(
                        l1, lt,
                        "{policy:?} compact={compact} mem={memory} threads={threads}: losses"
                    );
                    assert_eq!(
                        w1.data(),
                        wt.data(),
                        "{policy:?} compact={compact} mem={memory} threads={threads}: weights"
                    );
                    assert_eq!(
                        b1, bt,
                        "{policy:?} compact={compact} mem={memory} threads={threads}: bias"
                    );
                }
            }
        }
    }
}

/// Train a 2-hidden-layer graph with a *heterogeneous* per-layer config
/// (different K at every layer, the given activation and policy) and
/// return (per-step losses, per-step k vectors, final layer weights).
///
/// `reuse_ws` switches between one `GraphWorkspace` reused across every
/// step (the steady-state zero-allocation path) and a fresh workspace
/// per step — the two must be bit-identical at every thread count.
fn train_graph(
    activation: Activation,
    policy: Policy,
    threads: usize,
    steps: usize,
    reuse_ws: bool,
) -> (Vec<u32>, Vec<Vec<usize>>, Graph) {
    let (m, n, p) = (24usize, 6usize, 3usize);
    let (x, y) = synth_data(31, m, n, p);
    let mut wrng = Rng::new(41);
    let mut g = Graph::relu_mlp(&mut wrng, &[n, 10, 8, p], LossKind::Mse);
    for li in 0..2 {
        g.layers[li].activation = activation;
    }
    // heterogeneous budgets: k differs at every layer (exact keeps M)
    let ks: [usize; 3] = if policy == Policy::Exact { [m, m, m] } else { [6, 12, 18] };
    let cfgs: Vec<AopLayerConfig> = ks
        .iter()
        .map(|&k| AopLayerConfig {
            k,
            policy,
            memory: policy != Policy::Exact,
        })
        .collect();
    let mut state = GraphState::from_configs(&g, m, &cfgs);
    let exec = Executor::new(threads);
    let mut rng = Rng::new(17);
    let mut resident = GraphWorkspace::new(&g, m);
    let mut losses = Vec::with_capacity(steps);
    let mut layer_ks = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (out, lk) = if reuse_ws {
            let out = train::train_step_ws(
                &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut resident,
            );
            (out, resident.layer_k().to_vec())
        } else {
            let mut fresh = GraphWorkspace::new(&g, m);
            let out = train::train_step_ws(
                &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut fresh,
            );
            (out, fresh.layer_k().to_vec())
        };
        assert!(out.loss.is_finite());
        losses.push(out.loss.to_bits());
        layer_ks.push(lk);
    }
    (losses, layer_ks, g)
}

#[test]
fn graph_bit_identical_across_threads_for_activation_policy_layerk_grid() {
    // the acceptance grid: every activation × every policy ×
    // heterogeneous per-layer K × (fresh vs reused workspace),
    // threads=1 vs threads=7, exact to_bits
    for activation in [Activation::Relu, Activation::Tanh, Activation::Sigmoid] {
        for policy in Policy::all() {
            let (l1, k1, g1) = train_graph(activation, policy, 1, 12, false);
            for (threads, reuse) in [(7usize, false), (1, true), (7, true)] {
                let what = format!("{activation:?} {policy:?} threads={threads} reuse={reuse}");
                let (lt, kt, gt) = train_graph(activation, policy, threads, 12, reuse);
                assert_eq!(l1, lt, "{what}: losses");
                assert_eq!(k1, kt, "{what}: per-layer k_effective");
                for (a, b) in g1.layers.iter().zip(gt.layers.iter()) {
                    assert_eq!(a.w.data(), b.w.data(), "{what}: weights");
                    assert_eq!(a.b, b.b, "{what}: bias");
                }
            }
            // heterogeneous budgets actually took effect
            if policy != Policy::Exact && policy != Policy::WeightedKReplacement {
                assert_eq!(k1[0], vec![6, 12, 18], "{activation:?} {policy:?}");
            }
        }
    }
}

fn energy_cfg(policy: Policy, threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Task::Energy);
    cfg.policy = policy;
    cfg.k = KSchedule::constant(if policy == Policy::Exact { cfg.m() } else { 9 });
    cfg.memory = policy != Policy::Exact;
    cfg.epochs = 4;
    cfg.seed = 3;
    cfg.threads = threads;
    cfg
}

/// A 2-layer energy config with per-layer {k, policy, memory} and the
/// given hidden activation.
fn layered_energy_cfg_with(threads: usize, hidden: Activation) -> ExperimentConfig {
    let mut cfg = energy_cfg(Policy::TopK, threads);
    cfg.k = KSchedule::Constant(18);
    cfg.layers = Some(vec![
        LayerSpec {
            width: 8,
            activation: Some(hidden),
            k: Some(KSchedule::Constant(36)),
            policy: Some(Policy::WeightedK),
            memory: Some(true),
        },
        LayerSpec::plain(1), // head inherits k=18 / topk / mem
    ]);
    cfg
}

fn layered_energy_cfg(threads: usize) -> ExperimentConfig {
    layered_energy_cfg_with(threads, Activation::Tanh)
}

fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.curve.epochs.len(), b.curve.epochs.len(), "{what}: epochs");
    for (ma, mb) in a.curve.epochs.iter().zip(b.curve.epochs.iter()) {
        assert_eq!(
            ma.train_loss.to_bits(),
            mb.train_loss.to_bits(),
            "{what}: epoch {} train loss",
            ma.epoch
        );
        assert_eq!(
            ma.val_loss.to_bits(),
            mb.val_loss.to_bits(),
            "{what}: epoch {} val loss",
            ma.epoch
        );
        assert_eq!(
            ma.wstar_fro.to_bits(),
            mb.wstar_fro.to_bits(),
            "{what}: epoch {} wstar",
            ma.epoch
        );
        assert_eq!(
            ma.mem_fro.to_bits(),
            mb.mem_fro.to_bits(),
            "{what}: epoch {} mem",
            ma.epoch
        );
        assert_eq!(ma.backward_flops, mb.backward_flops, "{what}: flops");
        assert_eq!(ma.layers, mb.layers, "{what}: per-layer metrics");
    }
    assert_eq!(
        a.final_layers.len(),
        b.final_layers.len(),
        "{what}: layer count"
    );
    for ((wa, ba), (wb, bb)) in a.final_layers.iter().zip(b.final_layers.iter()) {
        assert_eq!(wa.data(), wb.data(), "{what}: final weights");
        assert_eq!(ba, bb, "{what}: final bias");
    }
}

#[test]
fn experiment_curves_bit_identical_across_threads_for_all_policies() {
    for policy in Policy::all() {
        let serial = experiment::run(&energy_cfg(policy, 1)).unwrap();
        for threads in &THREAD_COUNTS[1..] {
            let par = experiment::run(&energy_cfg(policy, *threads)).unwrap();
            assert_runs_identical(&serial, &par, &format!("{policy:?} threads={threads}"));
        }
    }
}

#[test]
fn layered_experiment_bit_identical_across_threads() {
    // per-layer {k, policy, memory} + tanh/sigmoid hiddens through the
    // whole experiment loop — the acceptance cases beyond relu
    for hidden in [Activation::Tanh, Activation::Sigmoid] {
        let serial = experiment::run(&layered_energy_cfg_with(1, hidden)).unwrap();
        assert_eq!(serial.final_layers.len(), 2, "{hidden:?}");
        // per-layer metrics carry the heterogeneous budgets
        let last = serial.curve.epochs.last().unwrap();
        assert_eq!(last.layers.len(), 2, "{hidden:?}");
        // weightedk w/o replacement: exactly k distinct products
        assert_eq!(last.layers[0].k_effective, 36.0, "{hidden:?}");
        assert_eq!(last.layers[1].k_effective, 18.0, "{hidden:?}");
        assert!(last.layers[0].backward_flops > 0, "{hidden:?}");
        assert_eq!(
            last.backward_flops,
            last.layers.iter().map(|l| l.backward_flops).sum::<u64>(),
            "{hidden:?}"
        );
        for threads in &THREAD_COUNTS[1..] {
            let par = experiment::run(&layered_energy_cfg_with(*threads, hidden)).unwrap();
            assert_runs_identical(
                &serial,
                &par,
                &format!("layered {hidden:?} threads={threads}"),
            );
        }
    }
}

/// A 2-layer energy config where BOTH layers' budgets anneal over the
/// run: the hidden layer on its own step schedule, the head inheriting
/// the flat linear ramp — the acceptance case for per-layer K schedules.
fn annealed_energy_cfg(threads: usize) -> ExperimentConfig {
    let mut cfg = energy_cfg(Policy::TopK, threads);
    cfg.epochs = 6;
    cfg.k = KSchedule::parse("linear:3:18").unwrap();
    cfg.layers = Some(vec![
        LayerSpec {
            width: 8,
            activation: Some(Activation::Tanh),
            k: Some(KSchedule::parse("step:36:2:0.5").unwrap()),
            policy: Some(Policy::WeightedK),
            memory: Some(true),
        },
        LayerSpec::plain(1), // head inherits the flat linear:3:18 ramp
    ]);
    cfg
}

#[test]
fn annealed_k_experiment_bit_identical_across_threads() {
    let serial = experiment::run(&annealed_energy_cfg(1)).unwrap();
    // the budgets actually anneal: per-epoch k_effective follows each
    // layer's schedule exactly (both policies draw without replacement)
    let m = 144;
    for (ei, ep) in serial.curve.epochs.iter().enumerate() {
        let epoch = ei + 1;
        let hidden = KSchedule::parse("step:36:2:0.5").unwrap().k_at(epoch, 6, m);
        let head = KSchedule::parse("linear:3:18").unwrap().k_at(epoch, 6, m);
        assert_eq!(ep.layers[0].k_effective, hidden as f64, "epoch {epoch} hidden");
        assert_eq!(ep.layers[1].k_effective, head as f64, "epoch {epoch} head");
    }
    assert_eq!(serial.curve.epochs[0].layers[1].k_effective, 3.0);
    assert_eq!(serial.curve.epochs[5].layers[1].k_effective, 18.0);
    // mid-run budget changes keep the exec determinism contract: every
    // thread count reproduces the annealed curve bit for bit
    for threads in &THREAD_COUNTS[1..] {
        let par = experiment::run(&annealed_energy_cfg(*threads)).unwrap();
        assert_runs_identical(&serial, &par, &format!("annealed threads={threads}"));
    }
    // and the schedule round-trips the wire format
    let cfg = annealed_energy_cfg(1);
    let decoded = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(decoded.k, cfg.k);
    assert_eq!(decoded.layers, cfg.layers);
}

#[test]
fn annealed_k_steps_bit_identical_fresh_vs_reused_workspace() {
    // step-level version of the annealing guarantee: k changes between
    // steps on one long-lived GraphState; a workspace reused across the
    // whole k ramp must match a fresh workspace per step, bit for bit,
    // at threads 1 and 7
    let sched = KSchedule::parse("linear:2:12").unwrap();
    let run = |threads: usize, reuse: bool| -> (Vec<u32>, Vec<Vec<usize>>, Graph) {
        let (m, n, p) = (24usize, 6usize, 3usize);
        let (x, y) = synth_data(57, m, n, p);
        let mut wrng = Rng::new(43);
        let mut g = Graph::relu_mlp(&mut wrng, &[n, 10, 8, p], LossKind::Mse);
        let cfgs =
            vec![AopLayerConfig { k: 2, policy: Policy::TopK, memory: true }; 3];
        let mut state = GraphState::from_configs(&g, m, &cfgs);
        let exec = Executor::new(threads);
        let mut rng = Rng::new(19);
        let mut resident = GraphWorkspace::new(&g, m);
        let mut losses = Vec::new();
        let mut layer_ks = Vec::new();
        for step in 0..12 {
            let k = sched.k_at(step + 1, 12, m);
            for ls in state.layers.iter_mut() {
                ls.cfg.k = k;
            }
            let (out, lk) = if reuse {
                let out = train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut resident,
                );
                (out, resident.layer_k().to_vec())
            } else {
                let mut fresh = GraphWorkspace::new(&g, m);
                let out = train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut fresh,
                );
                (out, fresh.layer_k().to_vec())
            };
            assert!(out.loss.is_finite());
            assert_eq!(lk, vec![k; 3], "step {step}: k_effective follows the ramp");
            losses.push(out.loss.to_bits());
            layer_ks.push(lk);
        }
        (losses, layer_ks, g)
    };
    let (l1, k1, g1) = run(1, false);
    for (threads, reuse) in [(7usize, false), (1, true), (7, true)] {
        let what = format!("annealed steps threads={threads} reuse={reuse}");
        let (lt, kt, gt) = run(threads, reuse);
        assert_eq!(l1, lt, "{what}: losses");
        assert_eq!(k1, kt, "{what}: per-layer k_effective");
        for (a, b) in g1.layers.iter().zip(gt.layers.iter()) {
            assert_eq!(a.w.data(), b.w.data(), "{what}: weights");
            assert_eq!(a.b, b.b, "{what}: bias");
        }
    }
}

#[test]
fn layered_config_json_roundtrip_and_flat_backcompat() {
    // the layers spec survives the wire format...
    let cfg = layered_energy_cfg(2);
    let decoded = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(decoded.layers, cfg.layers);
    assert_eq!(decoded.layer_plan(), cfg.layer_plan());
    assert_eq!(decoded.threads, 2);
    // ...and a flat config (no `layers` key) resolves to the historical
    // single identity layer with the flat knobs
    let flat = energy_cfg(Policy::TopK, 1);
    let fj = flat.to_json();
    assert!(fj.get("layers").is_none());
    let fd = ExperimentConfig::from_json(&fj).unwrap();
    assert!(fd.layers.is_none());
    let plan = fd.layer_plan();
    assert_eq!(plan.len(), 1);
    assert_eq!((plan[0].fan_in, plan[0].fan_out), (16, 1));
    assert_eq!(plan[0].activation, Activation::Identity);
    assert_eq!(plan[0].k, flat.k);
    assert_eq!(plan[0].policy, flat.policy);
    assert_eq!(plan[0].memory, flat.memory);
}

#[test]
fn determinism_at_env_worker_count() {
    // parallelism comes from REPRO_THREADS: ci.sh runs this suite twice
    // with different settings, so the gate compares real distinct pools
    let threads = pool::default_workers().min(12);
    let serial = experiment::run(&energy_cfg(Policy::WeightedK, 1)).unwrap();
    let par = experiment::run(&energy_cfg(Policy::WeightedK, threads.max(2))).unwrap();
    assert_runs_identical(&serial, &par, &format!("env threads={threads}"));
}

#[test]
fn mnist_shape_bit_identical_across_threads() {
    // the 784×10 acceptance workload, scaled down in samples (not shape)
    let mut cfg = ExperimentConfig::preset(Task::Mnist);
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(32);
    cfg.memory = true;
    cfg.epochs = 2;
    cfg.data_scale = 0.02;
    cfg.threads = 1;
    let serial = experiment::run(&cfg).unwrap();
    cfg.threads = 4;
    let par = experiment::run(&cfg).unwrap();
    assert_runs_identical(&serial, &par, "mnist threads=4");
}

#[test]
fn mlp_training_bit_identical_across_threads() {
    let (x, y) = {
        let mut rng = Rng::new(11);
        let x = Matrix::from_fn(40, 6, |_, _| rng.normal());
        let y = Matrix::from_fn(40, 3, |r, c| ((r % 3) == c) as u32 as f32);
        (x, y)
    };
    let train = |threads: usize| -> (Vec<u32>, Graph) {
        let mut rng = Rng::new(5);
        let mut mlp = Graph::relu_mlp(&mut rng, &[6, 17, 3], LossKind::SoftmaxCrossEntropy);
        let mut state = GraphState::uniform(&mlp, 40, Policy::WeightedK, 10, true);
        let exec = Executor::new(threads);
        let mut prng = Rng::new(23);
        let mut losses = Vec::new();
        for _ in 0..25 {
            let info = mlp.train_step_aop_exec(&x, &y, 0.05, &mut state, &mut prng, &exec);
            losses.push(info.loss.to_bits());
        }
        (losses, mlp)
    };
    let (l1, mlp1) = train(1);
    for threads in &THREAD_COUNTS[1..] {
        let (lt, mlpt) = train(*threads);
        assert_eq!(l1, lt, "threads={threads}: losses");
        for (a, b) in mlp1.layers.iter().zip(mlpt.layers.iter()) {
            assert_eq!(a.w.data(), b.w.data(), "threads={threads}: layer weights");
            assert_eq!(a.b, b.b, "threads={threads}: layer bias");
        }
    }
}

#[test]
fn obs_enabled_training_bit_identical_across_threads_and_workspaces() {
    // ISSUE 6 acceptance: telemetry reads clocks but never feeds them
    // back into execution, so the full determinism grid — obs on/off ×
    // threads {1, 7} × fresh-vs-reused workspace — collapses to one
    // bit-exact curve. The obs-off serial fresh-workspace run is the
    // baseline every other cell is compared against.
    use mem_aop_gd::obs::{ObsConfig, Phase};

    let steps = 12usize;
    let (m, n, p) = (24usize, 6usize, 3usize);
    let k = 6usize;
    let run = |threads: usize, reuse: bool, obs: bool| -> (Vec<u32>, Vec<Vec<usize>>, Graph) {
        let (x, y) = synth_data(71, m, n, p);
        let mut wrng = Rng::new(47);
        let mut g = Graph::relu_mlp(&mut wrng, &[n, 10, 8, p], LossKind::Mse);
        let cfgs = vec![AopLayerConfig { k, policy: Policy::WeightedK, memory: true }; 3];
        let mut state = GraphState::from_configs(&g, m, &cfgs);
        let exec = Executor::new(threads);
        let mut rng = Rng::new(29);
        let ws_cfg = if obs { ObsConfig::on() } else { ObsConfig::off() };
        let mut resident = GraphWorkspace::with_obs(&g, m, ws_cfg);
        let mut losses = Vec::with_capacity(steps);
        let mut layer_ks = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (out, lk) = if reuse {
                let out = train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut resident,
                );
                (out, resident.layer_k().to_vec())
            } else {
                let mut fresh = GraphWorkspace::with_obs(&g, m, ws_cfg);
                let out = train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut fresh,
                );
                (out, fresh.layer_k().to_vec())
            };
            assert!(out.loss.is_finite());
            losses.push(out.loss.to_bits());
            layer_ks.push(lk);
        }
        if obs && reuse {
            // the resident workspace saw the whole run: every step
            // recorded once, each per-step phase exactly `steps` times,
            // dispatch/reduce once per layer per step, and the realized
            // per-layer budget equal to k × steps
            let tele = resident.obs();
            assert_eq!(tele.steps(), steps as u64, "threads={threads}");
            for ph in [Phase::Fwd, Phase::Score, Phase::Select, Phase::Apply] {
                assert_eq!(
                    tele.phase(ph).count(),
                    steps as u64,
                    "threads={threads} {}",
                    ph.name()
                );
            }
            assert_eq!(tele.phase(Phase::Dispatch).count(), (3 * steps) as u64);
            assert_eq!(tele.phase(Phase::Reduce).count(), (3 * steps) as u64);
            assert_eq!(tele.layer_k_sum(), &[(k * steps) as u64; 3][..]);
            assert!(tele.layer_flops().iter().all(|&f| f > 0));
            assert!(exec.dispatches() > 0, "shard dispatch counter never moved");
            assert_eq!(exec.active(), 0, "dispatch gauge must settle to zero");
        } else if !obs {
            assert_eq!(resident.obs().steps(), 0, "obs off must record nothing");
            assert!(resident.obs().phase(Phase::Fwd).is_empty());
        }
        (losses, layer_ks, g)
    };

    let (l0, k0, g0) = run(1, false, false);
    for (threads, reuse) in [(1usize, false), (7, false), (1, true), (7, true)] {
        let what = format!("obs-on threads={threads} reuse={reuse}");
        let (lt, kt, gt) = run(threads, reuse, true);
        assert_eq!(l0, lt, "{what}: losses");
        assert_eq!(k0, kt, "{what}: per-layer k_effective");
        for (a, b) in g0.layers.iter().zip(gt.layers.iter()) {
            assert_eq!(a.w.data(), b.w.data(), "{what}: weights");
            assert_eq!(a.b, b.b, "{what}: bias");
        }
    }
}

#[test]
fn audit_enabled_experiment_bit_identical_to_audit_off_across_threads() {
    // PR 7 acceptance: the gradient-fidelity auditor is observation-only.
    // The audit-off serial run is the baseline; audit-on at threads
    // {1, 7} must reproduce losses, weights, and per-layer metrics bit
    // for bit — the auditor consumes no RNG and mutates no model state.
    let baseline = experiment::run(&layered_energy_cfg(1)).unwrap();
    assert!(
        baseline.curve.epochs.iter().all(|m| m.audit.is_empty()),
        "audit-off runs must carry no audit records"
    );
    for threads in [1usize, 7] {
        let mut cfg = layered_energy_cfg(threads);
        cfg.audit = Some(2); // epochs 1 and 3 of 4
        let audited = experiment::run(&cfg).unwrap();
        assert_runs_identical(
            &baseline,
            &audited,
            &format!("audit-on threads={threads}"),
        );
        for m in &audited.curve.epochs {
            if (m.epoch - 1) % 2 == 0 {
                assert_eq!(m.audit.len(), 2, "epoch {}: one record per layer", m.epoch);
                for a in &m.audit {
                    assert!(a.cosine.is_finite() && (-1.0..=1.0).contains(&a.cosine));
                    assert!(a.rel_err.is_finite() && a.rel_err >= 0.0);
                    assert!(a.mem_bias.is_finite());
                }
                // K=36/144 and K=18/144 genuinely approximate: the
                // audited fidelity gap is real, not a degenerate zero
                assert!(m.audit.iter().any(|a| a.rel_err > 0.0), "epoch {}", m.epoch);
            } else {
                assert!(m.audit.is_empty(), "epoch {} off-cadence", m.epoch);
            }
        }
    }
    // audit records themselves are deterministic across thread counts
    let runs: Vec<RunResult> = [1usize, 7]
        .iter()
        .map(|&t| {
            let mut cfg = layered_energy_cfg(t);
            cfg.audit = Some(2);
            experiment::run(&cfg).unwrap()
        })
        .collect();
    for (a, b) in runs[0].curve.epochs.iter().zip(runs[1].curve.epochs.iter()) {
        assert_eq!(a.audit, b.audit, "epoch {} audit records", a.epoch);
    }
}

#[test]
fn audit_step_bit_identical_fresh_vs_reused_workspace() {
    // step-level version: interleaving `audit_into` after every apply
    // must not perturb the training trajectory, whether the audit runs
    // in the resident workspace or a fresh one per step, at threads
    // {1, 7}. The no-audit serial fresh-workspace run is the baseline.
    use mem_aop_gd::obs::AuditLayerRecord;

    let steps = 8usize;
    let (m, n, p) = (24usize, 6usize, 3usize);
    let run = |threads: usize, reuse: bool, audit: bool| -> (Vec<u32>, Vec<Vec<AuditLayerRecord>>, Graph) {
        let (x, y) = synth_data(83, m, n, p);
        let mut wrng = Rng::new(53);
        let mut g = Graph::relu_mlp(&mut wrng, &[n, 10, 8, p], LossKind::Mse);
        let cfgs = vec![AopLayerConfig { k: 6, policy: Policy::WeightedK, memory: true }; 3];
        let mut state = GraphState::from_configs(&g, m, &cfgs);
        let exec = Executor::new(threads);
        let mut rng = Rng::new(37);
        let mut resident = GraphWorkspace::new(&g, m);
        let mut losses = Vec::with_capacity(steps);
        let mut audits = Vec::new();
        for _ in 0..steps {
            let mut ws = if reuse {
                None
            } else {
                Some(GraphWorkspace::new(&g, m))
            };
            let w = ws.as_mut().unwrap_or(&mut resident);
            let out = train::train_step_ws(
                &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, w,
            );
            assert!(out.loss.is_finite());
            losses.push(out.loss.to_bits());
            if audit {
                let mut recs = Vec::new();
                train::audit_into(&g, &state, &x, 0.02, &exec, true, w, &mut recs);
                assert_eq!(recs.len(), 3, "one record per layer");
                for a in &recs {
                    assert!(a.cosine.is_finite() && (-1.0..=1.0).contains(&a.cosine));
                    assert!(a.rel_err.is_finite() && a.rel_err >= 0.0);
                }
                audits.push(recs);
            }
        }
        (losses, audits, g)
    };

    let (l0, _, g0) = run(1, false, false);
    let mut audit_cells: Vec<Vec<Vec<AuditLayerRecord>>> = Vec::new();
    for (threads, reuse) in [(1usize, false), (7, false), (1, true), (7, true)] {
        let what = format!("audit threads={threads} reuse={reuse}");
        let (lt, at, gt) = run(threads, reuse, true);
        assert_eq!(l0, lt, "{what}: losses");
        for (a, b) in g0.layers.iter().zip(gt.layers.iter()) {
            assert_eq!(a.w.data(), b.w.data(), "{what}: weights");
            assert_eq!(a.b, b.b, "{what}: bias");
        }
        audit_cells.push(at);
    }
    // the fidelity records agree across every cell of the grid
    for cell in &audit_cells[1..] {
        assert_eq!(&audit_cells[0], cell, "audit records differ across grid cells");
    }
}

#[test]
fn precision_grid_bit_identical_across_threads_and_workspaces() {
    // ISSUE 8 acceptance grid: trace {f32, bf16, q8} × accum {f32, f64}
    // × threads {1, 7} × fresh-vs-reused workspace. Quantized traces and
    // widened lanes change the *numbers*; within each precision cell the
    // thread count and workspace lifetime must still be invisible —
    // every cell collapses to one bit-exact trajectory.
    use mem_aop_gd::tensor::quant::{AccumMode, LayerPrecision, TraceMode};

    let steps = 10usize;
    let (m, n, p) = (24usize, 6usize, 3usize);
    let run = |trace: TraceMode,
               accum: AccumMode,
               threads: usize,
               reuse: bool|
     -> (Vec<u32>, Graph) {
        let (x, y) = synth_data(91, m, n, p);
        let mut wrng = Rng::new(59);
        let mut g = Graph::relu_mlp(&mut wrng, &[n, 10, 8, p], LossKind::Mse);
        let cfgs = vec![AopLayerConfig { k: 6, policy: Policy::TopK, memory: true }; 3];
        let mut state = GraphState::from_configs(&g, m, &cfgs);
        let exec = Executor::new(threads);
        let mut rng = Rng::new(61);
        let prec = vec![LayerPrecision { trace, accum }; 3];
        let mut resident = GraphWorkspace::new(&g, m);
        resident.set_precision(&g, &prec);
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let out = if reuse {
                train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut resident,
                )
            } else {
                let mut fresh = GraphWorkspace::new(&g, m);
                fresh.set_precision(&g, &prec);
                train::train_step_ws(
                    &mut g, &mut state, &x, &y, 0.02, &mut rng, &exec, true, &mut fresh,
                )
            };
            assert!(out.loss.is_finite());
            losses.push(out.loss.to_bits());
        }
        (losses, g)
    };

    for trace in [TraceMode::F32, TraceMode::Bf16, TraceMode::Q8] {
        for accum in [AccumMode::F32, AccumMode::F64] {
            let (l1, g1) = run(trace, accum, 1, false);
            for (threads, reuse) in [(7usize, false), (1, true), (7, true)] {
                let what = format!(
                    "trace={} accum={} threads={threads} reuse={reuse}",
                    trace.name(),
                    accum.name()
                );
                let (lt, gt) = run(trace, accum, threads, reuse);
                assert_eq!(l1, lt, "{what}: losses");
                for (a, b) in g1.layers.iter().zip(gt.layers.iter()) {
                    assert_eq!(a.w.data(), b.w.data(), "{what}: weights");
                    assert_eq!(a.b, b.b, "{what}: bias");
                }
            }
        }
    }
    // q8 traces must genuinely perturb the update relative to the f32
    // baseline — otherwise the knob quietly became a no-op
    let (base, _) = run(TraceMode::F32, AccumMode::F32, 1, false);
    let (q8, _) = run(TraceMode::Q8, AccumMode::F32, 1, false);
    assert_ne!(base, q8, "q8 traces left the trajectory bit-identical to f32");
}

#[test]
fn precision_experiment_bit_identical_across_threads() {
    // end-to-end: quantized traces + widened accumulation through the
    // whole experiment loop (layered config, memory on) stay bit-exact
    // across thread counts, including the per-layer metrics
    use mem_aop_gd::tensor::quant::{AccumMode, TraceMode};

    for (trace, accum) in [
        (TraceMode::Bf16, AccumMode::Kahan),
        (TraceMode::Q8, AccumMode::F64),
    ] {
        let mk = |threads: usize| {
            let mut cfg = layered_energy_cfg(threads);
            cfg.trace = trace;
            cfg.accum = accum;
            cfg
        };
        let serial = experiment::run(&mk(1)).unwrap();
        for threads in [4usize, 7] {
            let par = experiment::run(&mk(threads)).unwrap();
            assert_runs_identical(
                &serial,
                &par,
                &format!(
                    "trace={} accum={} threads={threads}",
                    trace.name(),
                    accum.name()
                ),
            );
        }
    }
}

#[test]
fn experiment_rollup_reports_phases_without_perturbing_the_curve() {
    // the native trainer runs with telemetry on by default; the rollup
    // rides along on RunResult while the curve stays bit-identical to
    // whatever the determinism tests above pinned
    let r = experiment::run(&energy_cfg(Policy::TopK, 2)).unwrap();
    let rollup = r.phases.expect("native runs carry a phase rollup");
    assert!(rollup.steps > 0);
    let by_name = |name: &str| {
        rollup
            .phases
            .iter()
            .find(|ps| ps.phase.name() == name)
            .unwrap_or_else(|| panic!("missing phase {name}"))
    };
    // every per-step phase fired once per step — including `select`,
    // which is timed by the experiment loop rather than the workspace
    for name in ["fwd", "score", "select", "apply"] {
        assert_eq!(by_name(name).count, rollup.steps, "{name}");
        assert!(by_name(name).total_ns > 0, "{name}");
        assert!(by_name(name).p50_ns <= by_name(name).p99_ns, "{name}");
    }
    assert_eq!(rollup.layers.len(), 1, "flat config = single layer");
    assert!(rollup.layers[0].k_sum > 0);
    assert!(rollup.layers[0].backward_flops > 0);
}

#[test]
fn served_jobs_with_threads_are_bit_identical_and_bounded() {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 6,
        queue_capacity: 16,
        registry_dir: None,
    };
    let server = Server::bind(&opts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());
    let mut c = Client::connect(&addr).unwrap();

    // same config at threads=1 and threads=4 through the wire
    let id1 = c.submit(&energy_cfg(Policy::WeightedK, 1), "t1").unwrap();
    let id4 = c.submit(&energy_cfg(Policy::WeightedK, 4), "t4").unwrap();
    c.wait(id1, Duration::from_secs(120)).unwrap();
    c.wait(id4, Duration::from_secs(120)).unwrap();
    let (cfg1, curve1) = c.result(id1).unwrap();
    let (cfg4, curve4) = c.result(id4).unwrap();
    assert_eq!(cfg1.threads, 1);
    assert_eq!(cfg4.threads, 4);
    assert_eq!(curve1.epochs.len(), curve4.epochs.len());
    for (a, b) in curve1.epochs.iter().zip(curve4.epochs.iter()) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits());
        assert_eq!(a.backward_flops, b.backward_flops);
    }
    // ... and both match a direct local run of the same config
    let local = experiment::run(&energy_cfg(Policy::WeightedK, 1)).unwrap();
    for (a, b) in curve1.epochs.iter().zip(local.curve.epochs.iter()) {
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits());
    }

    // a job that can never fit the slot budget is rejected with a clear
    // protocol error (not queued, not deadlocked)
    let err = c
        .submit(&energy_cfg(Policy::TopK, 7), "too-big")
        .unwrap_err()
        .to_string();
    assert!(err.contains("threads=7"), "{err}");

    c.shutdown().unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn served_layered_job_reports_per_layer_k_effective() {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 5,
        queue_capacity: 8,
        registry_dir: None,
    };
    let server = Server::bind(&opts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());
    let mut c = Client::connect(&addr).unwrap();

    // per-layer {k, policy} through the wire at two thread counts
    let id1 = c.submit(&layered_energy_cfg(1), "l1").unwrap();
    let id4 = c.submit(&layered_energy_cfg(4), "l4").unwrap();
    c.wait(id1, Duration::from_secs(120)).unwrap();
    c.wait(id4, Duration::from_secs(120)).unwrap();

    // the job view exposes the resolved per-layer config (protocol v3)
    let view = c.status(id1).unwrap();
    let layers = view.get("layers").and_then(|l| l.as_arr()).unwrap().to_vec();
    assert_eq!(layers.len(), 2);
    assert_eq!(layers[0].get("k").unwrap().as_usize().unwrap(), 36);
    assert_eq!(
        layers[0].get("policy").unwrap().as_str().unwrap(),
        "weightedk"
    );
    assert_eq!(
        layers[0].get("activation").unwrap().as_str().unwrap(),
        "tanh"
    );
    assert_eq!(layers[1].get("k").unwrap().as_usize().unwrap(), 18);

    // the returned metrics carry per-layer k_effective, and the curves
    // are bit-identical across thread counts
    let (_, curve1) = c.result(id1).unwrap();
    let (_, curve4) = c.result(id4).unwrap();
    for (a, b) in curve1.epochs.iter().zip(curve4.epochs.iter()) {
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits());
        assert_eq!(a.layers, b.layers);
    }
    let last = curve1.epochs.last().unwrap();
    assert_eq!(last.layers.len(), 2);
    assert_eq!(last.layers[0].k_effective, 36.0);
    assert_eq!(last.layers[1].k_effective, 18.0);

    c.shutdown().unwrap();
    handle.join().unwrap().unwrap();
}
