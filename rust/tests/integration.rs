//! Cross-module integration tests: runtime ⇄ coordinator ⇄ data ⇄ aop,
//! including failure injection on the runtime boundary and short
//! end-to-end trainings with quality thresholds.
//!
//! Artifact-dependent cases skip with a note when `make artifacts` has
//! not been run.

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::experiment;
use mem_aop_gd::coordinator::mlp_driver::{train_mlp, MlpDriver, MlpVariant};
use mem_aop_gd::data::digits;
use mem_aop_gd::runtime::{Manifest, Runtime, Value};
use mem_aop_gd::tensor::Matrix;

fn runtime() -> Option<Runtime> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Runtime::from_default_artifacts().expect("runtime"))
}

// ---------------------------------------------------------------------
// runtime boundary
// ---------------------------------------------------------------------

#[test]
fn artifact_shape_validation_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let eval = rt.load("energy_eval").unwrap();
    // wrong rank
    let bad = eval.run(&[
        Value::Scalar(1.0),
        Value::Scalar(1.0),
        Value::Scalar(1.0),
        Value::Scalar(1.0),
    ]);
    assert!(bad.is_err());
    // wrong arity
    let bad2 = eval.run(&[Value::Scalar(1.0)]);
    assert!(bad2.is_err());
    let msg = format!("{:#}", bad2.unwrap_err());
    assert!(msg.contains("expected 4"), "{msg}");
}

#[test]
fn eval_artifact_matches_native_loss() {
    let Some(rt) = runtime() else { return };
    use mem_aop_gd::model::LossKind;
    use mem_aop_gd::tensor::rng::Rng;
    let eval = rt.load("energy_eval").unwrap();
    let mut rng = Rng::new(1);
    let x = Matrix::from_fn(192, 16, |_, _| rng.normal());
    let y = Matrix::from_fn(192, 1, |_, _| rng.normal());
    let w = Matrix::from_fn(16, 1, |_, _| 0.1 * rng.normal());
    let b = vec![0.05f32];
    let out = eval
        .run(&[
            Value::Matrix(x.clone()),
            Value::Matrix(y.clone()),
            Value::Matrix(w.clone()),
            Value::Vector(b.clone()),
        ])
        .unwrap();
    let hlo_loss = out[0].as_scalar().unwrap();
    let o = x.matmul(&w).add_row_broadcast(&b);
    let native_loss = LossKind::Mse.loss(&o, &y);
    assert!(
        (hlo_loss - native_loss).abs() / native_loss < 1e-4,
        "hlo {hlo_loss} vs native {native_loss}"
    );
}

#[test]
fn exec_stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let eval = rt.load("energy_eval").unwrap();
    let before = eval.stats().calls;
    let x = Matrix::zeros(192, 16);
    let y = Matrix::zeros(192, 1);
    let w = Matrix::zeros(16, 1);
    for _ in 0..3 {
        eval.run(&[
            Value::Matrix(x.clone()),
            Value::Matrix(y.clone()),
            Value::Matrix(w.clone()),
            Value::Vector(vec![0.0]),
        ])
        .unwrap();
    }
    let st = eval.stats();
    assert_eq!(st.calls, before + 3);
    assert!(st.mean_us() > 0.0);
    // the runtime cache must return the same executable
    let again = rt.load("energy_eval").unwrap();
    assert_eq!(again.stats().calls, st.calls);
}

#[test]
fn manifest_contract_complete() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    m.check_files().unwrap();
    for task in ["energy", "mnist"] {
        for phase in ["fwd_score", "apply", "eval"] {
            assert!(
                m.artifacts.contains_key(&format!("{task}_{phase}")),
                "{task}_{phase} missing"
            );
        }
    }
    for v in ["mlp_exact", "mlp_topk_mem", "mlp_topk_nomem", "mlp_randk_mem", "mlp_weightedk_mem", "mlp_eval"] {
        assert!(m.artifacts.contains_key(v), "{v} missing");
    }
    // two-phase contract: apply's first two inputs match fwd_score's
    // xhat/ghat outputs
    let fs = m.artifact("mnist_fwd_score").unwrap();
    let ap = m.artifact("mnist_apply").unwrap();
    assert_eq!(fs.outputs[1].shape, ap.inputs[0].shape); // xhat
    assert_eq!(fs.outputs[2].shape, ap.inputs[1].shape); // ghat
    assert_eq!(fs.outputs[4].shape, vec![64]); // scores = M
}

#[test]
fn corrupt_artifact_fails_cleanly() {
    let Some(_rt) = runtime() else { return };
    // copy artifacts to a temp dir, corrupt one HLO file, expect a clean
    // parse error (not a crash) on load
    let src = Manifest::default_dir();
    let dst = std::env::temp_dir().join(format!("memaop_corrupt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    std::fs::write(dst.join("energy_eval.hlo.txt"), "ENTRY garbage {").unwrap();
    let rt = Runtime::new(&dst).unwrap();
    let err = match rt.load("energy_eval") {
        Err(e) => e,
        Ok(_) => panic!("corrupt artifact loaded"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("energy_eval"), "{msg}");
    // other artifacts still load fine
    rt.load("energy_fwd_score").unwrap();
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn missing_manifest_is_reported() {
    let dst = std::env::temp_dir().join(format!("memaop_nomanifest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    let err = match Runtime::new(&dst) {
        Err(e) => e,
        Ok(_) => panic!("runtime built without manifest"),
    };
    // actionable either way: the real client points at the artifact
    // pipeline, the no-`hlo` stub at the missing feature/backend switch
    let msg = format!("{err:#}");
    assert!(
        msg.contains("make artifacts") || msg.contains("hlo"),
        "{msg}"
    );
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn lr_schedule_changes_hlo_training_without_recompile() {
    let Some(rt) = runtime() else { return };
    use mem_aop_gd::coordinator::config::LrSchedule;
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(18);
    cfg.memory = true;
    cfg.epochs = 6;
    let constant = experiment::run_hlo(&cfg, &rt).unwrap();
    cfg.schedule = LrSchedule::Cosine { min_frac: 0.01 };
    let cosine = experiment::run_hlo(&cfg, &rt).unwrap();
    // same artifacts, different dynamics
    assert_ne!(
        constant.final_val_loss(),
        cosine.final_val_loss()
    );
}

#[test]
fn fused_step_matches_two_phase_topk() {
    // The single-dispatch deployment artifact must produce exactly the
    // two-phase path's update for the deterministic topK policy.
    let Some(rt) = runtime() else { return };
    use mem_aop_gd::aop::policy;
    use mem_aop_gd::coordinator::hlo_trainer::HloTrainer;
    use mem_aop_gd::coordinator::experiment::Trainer;
    use mem_aop_gd::runtime::ArgRef;
    use mem_aop_gd::tensor::rng::Rng;

    let mut cfg = ExperimentConfig::mnist_preset();
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(32);
    cfg.memory = true;
    let mut two_phase = HloTrainer::new(&cfg, &rt).unwrap();

    let mut rng = Rng::new(77);
    let x = Matrix::from_fn(64, 784, |_, _| rng.normal().abs() * 0.5);
    let y = Matrix::from_fn(64, 10, |r, c| ((r % 10) == c) as u32 as f32);
    let w0 = two_phase.w.clone();
    let b0 = two_phase.b.clone();

    // two-phase step
    let (_, scores) = two_phase.fwd_score(&x, &y).unwrap();
    let sel = policy::select(Policy::TopK, &scores[0], 32, true, &mut rng);
    two_phase.apply(std::slice::from_ref(&sel)).unwrap();

    // fused step (same initial state)
    let fused = rt.load("mnist_fused_topk_mem").unwrap();
    let noise = vec![0.5f32; 64];
    let out = fused
        .run_ref(&[
            ArgRef::from(&x),
            ArgRef::from(&y),
            ArgRef::from(&w0),
            ArgRef::from(&b0),
            ArgRef::Matrix(&Matrix::zeros(64, 784)),
            ArgRef::Matrix(&Matrix::zeros(64, 10)),
            ArgRef::from(&noise),
            ArgRef::Scalar(cfg.lr),
        ])
        .unwrap();
    let w_fused = out[1].clone().into_matrix().unwrap();
    let d = w_fused.max_abs_diff(&two_phase.w);
    assert!(d < 1e-5, "fused vs two-phase |Δw|∞ = {d}");
}

// ---------------------------------------------------------------------
// end-to-end trainings with thresholds
// ---------------------------------------------------------------------

#[test]
fn hlo_energy_full_paper_run_reaches_threshold() {
    let Some(rt) = runtime() else { return };
    // Tab. I configuration, topK K=18 with memory — paper's Fig. 2 top
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = Policy::TopK;
    cfg.k = KSchedule::Constant(18);
    cfg.memory = true;
    let r = experiment::run_hlo(&cfg, &rt).unwrap();
    // standardized-target MSE: a fitted linear model lands well under 0.3
    assert!(
        r.final_val_loss() < 0.3,
        "val loss {} too high",
        r.final_val_loss()
    );
}

#[test]
fn native_energy_panel_paper_shape_at_high_k() {
    // Fig. 2 top panel claim: with-memory Mem-AOP-GD ≈ or beats baseline.
    let mut base = ExperimentConfig::energy_preset();
    base.backend = Backend::Native;
    let configs = mem_aop_gd::coordinator::sweep::panel_configs(&base, 18);
    let results = mem_aop_gd::coordinator::sweep::run_sweep(&configs, 7);
    let mut baseline = f32::NAN;
    let mut best_mem = f32::INFINITY;
    for r in results {
        let r = r.unwrap();
        let t = r.curve.tail_mean_val_loss(5);
        if r.config.label() == "baseline" {
            baseline = t;
        } else if r.config.memory {
            best_mem = best_mem.min(t);
        }
    }
    assert!(
        best_mem < baseline * 1.5,
        "with-memory series ({best_mem}) far above baseline ({baseline})"
    );
}

#[test]
fn mlp_e2e_short_training_learns() {
    let Some(rt) = runtime() else { return };
    let train = digits::digits_dataset(1280, 11);
    let val = digits::digits_dataset(256, 12);
    let (_driver, curve) =
        train_mlp(&rt, MlpVariant::TopKMem, &train, &val, 60, 0.05, 20, 11).unwrap();
    let acc = curve.final_val_acc();
    assert!(acc > 0.5, "e2e MLP acc {acc} after 60 steps");
    // memory variant must actually defer mass
    assert!(curve.epochs.last().unwrap().mem_fro > 0.0);
}

#[test]
fn mlp_nomem_variant_keeps_memory_zero() {
    let Some(rt) = runtime() else { return };
    let train = digits::digits_dataset(256, 13);
    let mut driver = MlpDriver::new(&rt, MlpVariant::TopKNoMem, 5).unwrap();
    let idx: Vec<usize> = (0..driver.batch).collect();
    let b = train.gather(&idx);
    for _ in 0..3 {
        driver.step(&b.x, &b.y, 0.05).unwrap();
    }
    assert_eq!(driver.mem_fro(), 0.0);
}

#[test]
fn mlp_exact_beats_chance_quickly() {
    let Some(rt) = runtime() else { return };
    let train = digits::digits_dataset(1280, 14);
    let val = digits::digits_dataset(256, 15);
    let (_d, curve) =
        train_mlp(&rt, MlpVariant::Exact, &train, &val, 40, 0.05, 40, 14).unwrap();
    assert!(curve.final_val_acc() > 0.5);
}

// ---------------------------------------------------------------------
// backend equivalence at the single-step level (no policy noise)
// ---------------------------------------------------------------------

#[test]
fn single_step_exact_native_vs_hlo_weights_match() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.epochs = 1;
    cfg.backend = Backend::Native;
    let n = experiment::run(&cfg).unwrap();
    let h = experiment::run_hlo(&cfg, &rt).unwrap();
    let d = n.final_w().max_abs_diff(h.final_w());
    assert!(d < 1e-4, "after 1 epoch, |Δw|∞ = {d}");
    for (a, b) in n.final_b().iter().zip(h.final_b().iter()) {
        assert!((a - b).abs() < 1e-4);
    }
}
