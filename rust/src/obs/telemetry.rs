//! [`StepTelemetry`] — the per-run handle recording where step time
//! goes, owned by `GraphWorkspace` (and through it `NativeTrainer`).
//!
//! Phases ([`Phase`]): the two halves of the split step (`fwd` =
//! forward trace + head loss, `score` = the backward fold/score/chain
//! sweep), the policy draw (`select`), the update (`apply`), and — as
//! sub-phases *nested inside* `apply` — the per-layer outer-product
//! shard dispatch (`dispatch`) and fixed-order reduction (`reduce`).
//! `dispatch`/`reduce` totals therefore overlap `apply`, not add to it.
//! The gradient-fidelity auditor (ISSUE 7) runs after `apply` on
//! audited epochs only and times under its own `audit` phase, so
//! non-audited steps record exactly the six historical phases.
//!
//! Hard constraints (ISSUE 6), and how they are met:
//!
//! * **disabled ⇒ free**: [`StepTelemetry::start`] returns `None`
//!   without reading any clock when the config is off; every recording
//!   method is an early-return branch. The hot path's entire obs cost
//!   when disabled is a handful of predictable branches.
//! * **enabled ⇒ zero allocations**: histograms are fixed inline
//!   arrays, the trace ring and per-layer counters are pre-sized at
//!   construction (workspace build time). Steady-state steps with
//!   telemetry on allocate nothing — asserted by the counting
//!   allocator in `benches/kernels.rs` (BENCH_6).
//! * **determinism**: telemetry reads clocks but never feeds them back
//!   into execution; the `threads {1,7}` bit-identity grid in
//!   `rust/tests/exec.rs` runs with obs on and off.

// Clock reads are deliberate here (phase timing is this module's purpose) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use crate::obs::hist::Histogram;
use crate::obs::trace::{TraceEvent, TraceRing};
use crate::obs::ObsConfig;
use crate::util::json::{self, Json};

/// A timed phase of the training step (see the module docs for how
/// `dispatch`/`reduce` nest inside `apply`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Fwd,
    Score,
    Select,
    Apply,
    Dispatch,
    Reduce,
    Audit,
}

impl Phase {
    pub const COUNT: usize = 7;
    pub const ALL: [Phase; Phase::COUNT] = [
        Phase::Fwd,
        Phase::Score,
        Phase::Select,
        Phase::Apply,
        Phase::Dispatch,
        Phase::Reduce,
        Phase::Audit,
    ];

    /// Stable wire name (Prometheus labels, trace events, rollups).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Fwd => "fwd",
            Phase::Score => "score",
            Phase::Select => "select",
            Phase::Apply => "apply",
            Phase::Dispatch => "dispatch",
            Phase::Reduce => "reduce",
            Phase::Audit => "audit",
        }
    }

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }
}

#[inline]
fn saturating_ns(ns: u128) -> u64 {
    ns.min(u64::MAX as u128) as u64
}

/// Pre-allocated per-run step telemetry: one latency histogram per
/// phase, monotonic step/per-layer counters, and a bounded event trace.
pub struct StepTelemetry {
    cfg: ObsConfig,
    /// Time origin for trace timestamps (construction instant).
    origin: Instant,
    steps: u64,
    phases: [Histogram; Phase::COUNT],
    /// Cumulative realized K (distinct outer products) per layer.
    layer_k_sum: Vec<u64>,
    /// Cumulative backward weight-gradient FLOPs per layer.
    layer_flops: Vec<u64>,
    /// Most recent gradient-fidelity audit per layer (ISSUE 7).
    layer_audit: Vec<LayerAudit>,
    /// Backward-read bytes of each layer's stored forward trace
    /// (§Mixed precision); 0 for uncompressed (f32) layers.
    layer_trace_bytes: Vec<u64>,
    trace: TraceRing,
}

impl StepTelemetry {
    pub fn new(cfg: ObsConfig, n_layers: usize) -> StepTelemetry {
        let trace_cap = if cfg.enabled { cfg.trace_capacity } else { 0 };
        StepTelemetry {
            cfg,
            origin: Instant::now(),
            steps: 0,
            phases: std::array::from_fn(|_| Histogram::new()),
            layer_k_sum: vec![0; n_layers],
            layer_flops: vec![0; n_layers],
            layer_audit: vec![LayerAudit::default(); n_layers],
            layer_trace_bytes: vec![0; n_layers],
            trace: TraceRing::with_capacity(trace_cap),
        }
    }

    pub fn config(&self) -> ObsConfig {
        self.cfg
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Open a phase timer. Returns `None` — with **no clock read** —
    /// when telemetry is disabled.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.cfg.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a timer opened by [`Self::start`]: record the elapsed ns
    /// into the phase histogram and the trace ring. No-op (and no
    /// clock read) for `None`.
    #[inline]
    pub fn finish(&mut self, phase: Phase, started: Option<Instant>) {
        let Some(t0) = started else { return };
        let dur_ns = saturating_ns(t0.elapsed().as_nanos());
        let start_ns = saturating_ns(t0.duration_since(self.origin).as_nanos());
        self.phases[phase.index()].record(dur_ns);
        self.trace.push(TraceEvent { phase, start_ns, dur_ns, step: self.steps });
    }

    /// Record an externally-timed phase duration (the experiment loop
    /// times `select` outside the workspace on the trait path).
    pub fn record_ns(&mut self, phase: Phase, dur_ns: u64) {
        if !self.cfg.enabled {
            return;
        }
        self.phases[phase.index()].record(dur_ns);
        let end_ns = saturating_ns(self.origin.elapsed().as_nanos());
        self.trace.push(TraceEvent {
            phase,
            start_ns: end_ns.saturating_sub(dur_ns),
            dur_ns,
            step: self.steps,
        });
    }

    /// Count one completed step (called at the end of `apply`).
    #[inline]
    pub fn record_step(&mut self) {
        if self.cfg.enabled {
            self.steps += 1;
        }
    }

    /// Accumulate one layer's realized budget for the applied step.
    #[inline]
    pub fn record_layer(&mut self, li: usize, k: usize, backward_flops: u64) {
        if !self.cfg.enabled {
            return;
        }
        if let Some(s) = self.layer_k_sum.get_mut(li) {
            *s += k as u64;
        }
        if let Some(f) = self.layer_flops.get_mut(li) {
            *f += backward_flops;
        }
    }

    /// Record one layer's gradient-fidelity audit (latest wins; the
    /// count is cumulative). Pre-sized at construction — no allocation.
    #[inline]
    pub fn record_audit(&mut self, li: usize, cosine: f64, rel_err: f64, mem_bias: f64) {
        if !self.cfg.enabled {
            return;
        }
        if let Some(a) = self.layer_audit.get_mut(li) {
            a.audits += 1;
            a.cosine = cosine;
            a.rel_err = rel_err;
            a.mem_bias = mem_bias;
        }
    }

    /// Record one layer's compressed-trace footprint (§Mixed precision):
    /// the bytes the backward pass re-reads for its stored forward
    /// trace. Latest wins — it is a gauge, not a counter; callers
    /// record once per (re)configuration, leaving f32 layers at 0.
    #[inline]
    pub fn record_trace_bytes(&mut self, li: usize, bytes: u64) {
        if !self.cfg.enabled {
            return;
        }
        if let Some(b) = self.layer_trace_bytes.get_mut(li) {
            *b = bytes;
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn phase(&self, p: Phase) -> &Histogram {
        &self.phases[p.index()]
    }

    pub fn layer_k_sum(&self) -> &[u64] {
        &self.layer_k_sum
    }

    pub fn layer_flops(&self) -> &[u64] {
        &self.layer_flops
    }

    pub fn layer_trace_bytes(&self) -> &[u64] {
        &self.layer_trace_bytes
    }

    pub fn trace(&self) -> &TraceRing {
        &self.trace
    }

    /// Chrome trace-event JSON of the retained events (see
    /// [`TraceRing::chrome_trace_json`]).
    pub fn chrome_trace_json(&self) -> Json {
        self.trace.chrome_trace_json()
    }

    /// Compact summary for job views and CLI reporting.
    pub fn rollup(&self) -> PhaseRollup {
        PhaseRollup {
            steps: self.steps,
            phases: Phase::ALL
                .iter()
                .map(|&p| {
                    let h = &self.phases[p.index()];
                    PhaseStat {
                        phase: p,
                        count: h.count(),
                        total_ns: h.sum_ns(),
                        p50_ns: h.quantile_ns(0.5),
                        p99_ns: h.quantile_ns(0.99),
                    }
                })
                .collect(),
            layers: self
                .layer_k_sum
                .iter()
                .zip(self.layer_flops.iter())
                .zip(self.layer_audit.iter())
                .zip(self.layer_trace_bytes.iter())
                .map(|(((&k_sum, &backward_flops), &audit), &trace_bytes)| LayerStat {
                    k_sum,
                    backward_flops,
                    audit,
                    trace_bytes,
                })
                .collect(),
        }
    }
}

/// The most recent gradient-fidelity audit of one layer (ISSUE 7):
/// how the applied Mem-AOP update compared against the exact K=M
/// same-mini-batch gradient. `audits == 0` means the layer was never
/// audited and the float fields are meaningless.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerAudit {
    /// Number of audits recorded for this layer.
    pub audits: u64,
    /// Cosine similarity of applied update vs exact gradient.
    pub cosine: f64,
    /// Relative Frobenius error ‖approx − exact‖ / ‖exact‖.
    pub rel_err: f64,
    /// ‖exact(memory-folded) − exact(raw)‖ / ‖exact(raw)‖ — how much
    /// the error-feedback memory bends the exact gradient.
    pub mem_bias: f64,
}

/// One phase's summary inside a [`PhaseRollup`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseStat {
    pub phase: Phase,
    pub count: u64,
    pub total_ns: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

/// One layer's cumulative realized budget inside a [`PhaseRollup`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerStat {
    /// Cumulative realized K (distinct outer products) across steps.
    pub k_sum: u64,
    /// Cumulative backward weight-gradient FLOPs.
    pub backward_flops: u64,
    /// Latest gradient-fidelity audit (ISSUE 7); `audits == 0` when
    /// the run never audited.
    pub audit: LayerAudit,
    /// Backward-read bytes of this layer's compressed forward trace
    /// (§Mixed precision); 0 when the layer stores f32.
    pub trace_bytes: u64,
}

/// Frozen summary of a run's [`StepTelemetry`]: steps, per-phase
/// count/total/percentiles, per-layer realized K and backward FLOPs.
/// Attached to `RunResult` and rendered into serve `JobView`s
/// (protocol v5). Timings describe the run that happened — they never
/// feed back into execution, so two runs of one seed may differ here
/// while agreeing bit-for-bit on every curve.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRollup {
    pub steps: u64,
    pub phases: Vec<PhaseStat>,
    pub layers: Vec<LayerStat>,
}

impl PhaseRollup {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("steps", json::num(self.steps as f64)),
            (
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            json::obj(vec![
                                ("phase", json::s(p.phase.name())),
                                ("count", json::num(p.count as f64)),
                                ("total_ns", json::num(p.total_ns as f64)),
                                ("p50_ns", json::num(p.p50_ns as f64)),
                                ("p99_ns", json::num(p.p99_ns as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            let mut pairs = vec![
                                ("k_sum", json::num(l.k_sum as f64)),
                                ("backward_flops", json::num(l.backward_flops as f64)),
                            ];
                            // audit fields ride along only when the run
                            // actually audited — un-audited rollups keep
                            // the exact v5 frame shape
                            if l.audit.audits > 0 {
                                pairs.push(("audits", json::num(l.audit.audits as f64)));
                                pairs.push(("audit_cosine", json::num(l.audit.cosine)));
                                pairs.push(("audit_rel_err", json::num(l.audit.rel_err)));
                                pairs.push(("audit_mem_bias", json::num(l.audit.mem_bias)));
                            }
                            // same pattern for the compressed-trace
                            // footprint: all-f32 runs keep the v5 shape
                            if l.trace_bytes > 0 {
                                pairs.push(("trace_bytes", json::num(l.trace_bytes as f64)));
                            }
                            json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing_and_reads_no_timer() {
        let mut t = StepTelemetry::new(ObsConfig::off(), 2);
        assert!(!t.enabled());
        let started = t.start();
        assert!(started.is_none(), "off ⇒ no timer handle");
        t.finish(Phase::Fwd, started);
        t.record_ns(Phase::Select, 500);
        t.record_step();
        t.record_layer(0, 7, 1000);
        assert_eq!(t.steps(), 0);
        assert!(t.phase(Phase::Fwd).is_empty());
        assert!(t.phase(Phase::Select).is_empty());
        assert_eq!(t.layer_k_sum(), &[0, 0]);
        assert!(t.trace().is_empty());
    }

    #[test]
    fn enabled_records_phases_steps_and_layers() {
        let mut t = StepTelemetry::new(ObsConfig::on(), 2);
        assert!(t.enabled());
        for _ in 0..3 {
            let s = t.start();
            assert!(s.is_some());
            t.finish(Phase::Fwd, s);
            t.record_ns(Phase::Select, 250);
            t.record_layer(0, 6, 100);
            t.record_layer(1, 12, 400);
            t.record_step();
        }
        assert_eq!(t.steps(), 3);
        assert_eq!(t.phase(Phase::Fwd).count(), 3);
        assert_eq!(t.phase(Phase::Select).count(), 3);
        assert_eq!(t.phase(Phase::Select).sum_ns(), 750);
        assert_eq!(t.phase(Phase::Apply).count(), 0);
        assert_eq!(t.layer_k_sum(), &[18, 36]);
        assert_eq!(t.layer_flops(), &[300, 1200]);
        assert_eq!(t.trace().total(), 6, "one event per finish/record_ns");
    }

    #[test]
    fn rollup_summarizes_every_phase_and_layer() {
        let mut t = StepTelemetry::new(ObsConfig::on(), 1);
        t.record_ns(Phase::Apply, 1000);
        t.record_ns(Phase::Apply, 3000);
        t.record_layer(0, 9, 5000);
        t.record_step();
        let r = t.rollup();
        assert_eq!(r.steps, 1);
        assert_eq!(r.phases.len(), Phase::COUNT);
        let apply = r.phases.iter().find(|p| p.phase == Phase::Apply).unwrap();
        assert_eq!(apply.count, 2);
        assert_eq!(apply.total_ns, 4000);
        assert!(apply.p50_ns >= 1000 && apply.p50_ns <= 2047, "{}", apply.p50_ns);
        assert_eq!(
            r.layers,
            vec![LayerStat { k_sum: 9, backward_flops: 5000, ..LayerStat::default() }]
        );
        // JSON render keeps the stable phase names
        let j = r.to_json();
        let phases = j.get("phases").and_then(|p| p.as_arr()).unwrap();
        let names: Vec<&str> =
            phases.iter().filter_map(|p| p.get("phase").and_then(|n| n.as_str())).collect();
        assert_eq!(names, vec!["fwd", "score", "select", "apply", "dispatch", "reduce", "audit"]);
        // un-audited layers keep the exact v5 layer frame shape
        let layers = j.get("layers").and_then(|l| l.as_arr()).unwrap();
        assert!(layers[0].get("audit_cosine").is_none());
    }

    #[test]
    fn phase_names_are_stable() {
        // these names are a wire-format promise (Prometheus labels,
        // trace events, job views) — changing one is a breaking change
        let names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names, vec!["fwd", "score", "select", "apply", "dispatch", "reduce", "audit"]);
    }

    #[test]
    fn audit_records_latest_per_layer_and_renders_in_rollup() {
        let mut t = StepTelemetry::new(ObsConfig::on(), 2);
        t.record_audit(0, 0.5, 0.9, 0.1);
        t.record_audit(0, 0.99, 0.05, 0.02);
        let r = t.rollup();
        let a0 = r.layers[0].audit;
        assert_eq!(a0.audits, 2, "count is cumulative");
        assert_eq!(a0.cosine, 0.99, "latest audit wins");
        assert_eq!(r.layers[1].audit.audits, 0, "layer 1 never audited");
        let j = r.to_json();
        let layers = j.get("layers").and_then(|l| l.as_arr()).unwrap();
        assert_eq!(layers[0].get("audit_cosine").and_then(|v| v.as_f64()), Some(0.99));
        assert_eq!(layers[0].get("audit_rel_err").and_then(|v| v.as_f64()), Some(0.05));
        assert_eq!(layers[0].get("audit_mem_bias").and_then(|v| v.as_f64()), Some(0.02));
        assert!(layers[1].get("audit_cosine").is_none());
        // disabled telemetry drops audits like every other record
        let mut off = StepTelemetry::new(ObsConfig::off(), 1);
        off.record_audit(0, 1.0, 0.0, 0.0);
        assert_eq!(off.rollup().layers[0].audit.audits, 0);
    }

    #[test]
    fn trace_bytes_gauge_is_latest_wins_and_renders_only_when_compressed() {
        let mut t = StepTelemetry::new(ObsConfig::on(), 2);
        t.record_trace_bytes(1, 4096);
        t.record_trace_bytes(1, 2048); // re-key: latest wins
        let r = t.rollup();
        assert_eq!(r.layers[0].trace_bytes, 0);
        assert_eq!(r.layers[1].trace_bytes, 2048);
        let j = r.to_json();
        let layers = j.get("layers").and_then(|l| l.as_arr()).unwrap();
        assert!(layers[0].get("trace_bytes").is_none(), "f32 layers keep the v5 shape");
        assert_eq!(layers[1].get("trace_bytes").and_then(|v| v.as_usize()), Some(2048));
        // disabled telemetry drops the gauge like every other record
        let mut off = StepTelemetry::new(ObsConfig::off(), 1);
        off.record_trace_bytes(0, 999);
        assert_eq!(off.rollup().layers[0].trace_bytes, 0);
    }
}
