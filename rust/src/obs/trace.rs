//! Bounded ring-buffer event trace + Chrome trace-event rendering.
//!
//! [`TraceRing`] retains the most recent `capacity` phase events in a
//! pre-allocated buffer: pushes write into reserved slots (`Vec::push`
//! within capacity, then wrapping overwrites of the oldest slot), so
//! recording on the step hot path performs **zero heap allocations**.
//! Events are fixed-size [`TraceEvent`] values — no strings; the phase
//! name is resolved only at render time.
//!
//! [`TraceRing::chrome_trace_json`] renders the retained events as a
//! Chrome trace-event JSON array (complete `"X"` events, microsecond
//! timestamps) — load the dump of `repro trace` straight into
//! chrome://tracing or Perfetto.

use crate::obs::telemetry::Phase;
use crate::util::json::{self, Json};

/// One recorded phase interval. `start_ns` is relative to the owning
/// telemetry's time origin (its construction instant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub phase: Phase,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Step counter at record time (the serve tier reuses this slot as
    /// a request counter).
    pub step: u64,
}

/// Fixed-capacity ring of recent [`TraceEvent`]s, oldest-overwriting.
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Next slot to write (== `buf.len()` until the first wrap).
    next: usize,
    total: u64,
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> TraceRing {
        TraceRing { buf: Vec::with_capacity(cap), cap, next: 0, total: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events ever pushed (> `len()` once the ring has wrapped).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Record one event. Never allocates: the buffer only grows within
    /// its pre-reserved capacity, then wraps over the oldest slot. A
    /// zero-capacity ring drops everything (the disabled configuration).
    pub fn push(&mut self, ev: TraceEvent) {
        if self.cap == 0 {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Retained events, oldest first.
    pub fn iter_in_order(&self) -> impl Iterator<Item = &TraceEvent> {
        let split = if self.buf.len() < self.cap { 0 } else { self.next };
        self.buf[split..].iter().chain(self.buf[..split].iter())
    }

    /// Render the retained events as a Chrome trace-event JSON array:
    /// complete (`"ph":"X"`) events with microsecond `ts`/`dur`, one
    /// track (`pid`/`tid` 1), the step counter in `args.step`.
    pub fn chrome_trace_json(&self) -> Json {
        Json::Arr(
            self.iter_in_order()
                .map(|ev| {
                    json::obj(vec![
                        ("name", json::s(ev.phase.name())),
                        ("cat", json::s("repro")),
                        ("ph", json::s("X")),
                        ("ts", json::num(ev.start_ns as f64 / 1000.0)),
                        ("dur", json::num(ev.dur_ns as f64 / 1000.0)),
                        ("pid", json::num(1.0)),
                        ("tid", json::num(1.0)),
                        ("args", json::obj(vec![("step", json::num(ev.step as f64))])),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(phase: Phase, start_ns: u64) -> TraceEvent {
        TraceEvent { phase, start_ns, dur_ns: 10, step: start_ns / 100 }
    }

    #[test]
    fn wraps_over_oldest() {
        let mut r = TraceRing::with_capacity(3);
        assert!(r.is_empty());
        for i in 0..5u64 {
            r.push(ev(Phase::Fwd, i * 100));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.total(), 5);
        let starts: Vec<u64> = r.iter_in_order().map(|e| e.start_ns).collect();
        assert_eq!(starts, vec![200, 300, 400], "oldest two evicted, order kept");
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let mut r = TraceRing::with_capacity(0);
        r.push(ev(Phase::Apply, 0));
        assert!(r.is_empty());
        assert_eq!(r.total(), 0);
        assert!(matches!(r.chrome_trace_json(), Json::Arr(a) if a.is_empty()));
    }

    #[test]
    fn chrome_trace_roundtrips_through_json() {
        let mut r = TraceRing::with_capacity(8);
        r.push(ev(Phase::Fwd, 1000));
        r.push(ev(Phase::Score, 2500));
        let dumped = r.chrome_trace_json().dump();
        let parsed = json::parse(&dumped).unwrap();
        let arr = parsed.as_arr().expect("array of events");
        assert_eq!(arr.len(), 2);
        let first = &arr[0];
        assert_eq!(first.get("name").and_then(|v| v.as_str()), Some("fwd"));
        assert_eq!(first.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(first.get("ts").and_then(|v| v.as_f64()), Some(1.0)); // 1000 ns = 1 µs
        assert_eq!(
            first.get("args").and_then(|a| a.get("step")).and_then(|v| v.as_usize()),
            Some(10)
        );
        assert_eq!(arr[1].get("name").and_then(|v| v.as_str()), Some("score"));
    }
}
