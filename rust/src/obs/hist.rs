//! Fixed-bucket latency histograms — the pre-allocated recording
//! primitives behind `obs`.
//!
//! Buckets are powers of two in nanoseconds: bucket `i` counts samples
//! with `2^i ≤ ns < 2^(i+1)` (bucket 0 also absorbs 0 ns), and the last
//! bucket absorbs everything from `2^(BUCKETS-1)` ns up. 40 buckets
//! span 1 ns to ~9 minutes, which covers any phase of any training
//! step or serve request. The scheme has multiplicative resolution by
//! construction (every bucket is a 2× band), so quantile estimates
//! carry at most a 2× quantization error — plenty for "where does the
//! step spend its time", and it makes `record` a `leading_zeros` plus
//! three adds: cheap enough for hot paths, with **zero allocations**
//! (the bucket array is a fixed-size inline array).
//!
//! Two variants share the scheme: [`Histogram`] (plain `u64` counts,
//! for single-writer paths like the step telemetry) and
//! [`AtomicHistogram`] (relaxed atomics, for the serve tier's
//! concurrent request accounting). `AtomicHistogram::snapshot` bridges
//! the two for rendering.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets (`2^0` .. `2^39` ns ≈ 9.2 minutes).
pub const BUCKETS: usize = 40;

/// Bucket index for a sample of `ns` nanoseconds.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    if ns < 2 {
        0
    } else {
        (63 - ns.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` in nanoseconds (`u64::MAX` for
/// the overflow bucket).
pub fn bucket_upper_ns(i: usize) -> u64 {
    if i + 1 >= BUCKETS {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// Single-writer power-of-two-ns latency histogram. Fixed size, never
/// allocates; `record` is safe on the zero-allocation step hot path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram { counts: [0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Raw per-bucket counts (bucket `i` covers `[2^i, 2^(i+1))` ns).
    pub fn counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Upper-bound estimate of the `q`-quantile (`0 ≤ q ≤ 1`) in ns:
    /// the upper edge of the first bucket whose cumulative count
    /// reaches `q·count`, clamped to the observed maximum. 0 when
    /// empty. At most 2× above the true quantile (bucket scheme).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_upper_ns(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn reset(&mut self) {
        *self = Histogram::new();
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Shared-writer variant for the serve tier: same buckets, relaxed
/// atomics. Recording takes `&self`, so per-op request histograms can
/// live behind the shared `ServerState` with no lock.
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl AtomicHistogram {
    pub const fn new() -> AtomicHistogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        AtomicHistogram {
            counts: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, ns: u64) {
        self.counts[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy for rendering. Relaxed loads: totals can
    /// momentarily lag bucket increments mid-record under concurrent
    /// writers, and are exact once writers are quiescent.
    pub fn snapshot(&self) -> Histogram {
        let mut h = Histogram::new();
        for (dst, src) in h.counts.iter_mut().zip(self.counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        h.count = self.count.load(Ordering::Relaxed);
        h.sum_ns = self.sum_ns.load(Ordering::Relaxed);
        h.max_ns = self.max_ns.load(Ordering::Relaxed);
        h
    }
}

impl Default for AtomicHistogram {
    fn default() -> AtomicHistogram {
        AtomicHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        // overflow clamps to the last bucket
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper_ns(0), 1);
        assert_eq!(bucket_upper_ns(9), 1023);
        assert_eq!(bucket_upper_ns(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn record_and_summaries() {
        let mut h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_ns(0.5), 0);
        for ns in [1u64, 3, 5, 100, 1000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum_ns(), 1109);
        assert_eq!(h.max_ns(), 1000);
        assert!((h.mean_ns() - 221.8).abs() < 1e-9);
        assert_eq!(h.counts()[bucket_of(100)], 1);
        // p50 lands in the bucket of the 3rd sample (5 ns → bucket 2,
        // upper edge 7)
        assert_eq!(h.quantile_ns(0.5), 7);
        // p100 clamps to the observed max, not the bucket edge
        assert_eq!(h.quantile_ns(1.0), 1000);
        h.reset();
        assert!(h.is_empty());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        b.record(2000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum_ns(), 3010);
        assert_eq!(a.max_ns(), 2000);
    }

    #[test]
    fn atomic_snapshot_matches_plain() {
        let ah = AtomicHistogram::new();
        let mut h = Histogram::new();
        for ns in [7u64, 70, 700, 7000] {
            ah.record(ns);
            h.record(ns);
        }
        assert_eq!(ah.count(), 4);
        assert_eq!(ah.snapshot(), h);
    }
}
