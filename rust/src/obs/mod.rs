//! `obs` — zero-allocation observability for the training and serve
//! tiers (ISSUE 6 tentpole).
//!
//! The paper's claim is a *tradeoff curve* — loss versus backward
//! computation saved by sub-sampling outer products — so the repo needs
//! first-class visibility into where step time actually goes and what
//! budget each layer realized, without perturbing a single curve bit or
//! allocating on the hot path. This module provides the primitives and
//! the step-level handle:
//!
//! * [`hist`] — pre-allocated, fixed-bucket (power-of-two ns) latency
//!   histograms, plain and atomic;
//! * [`telemetry`] — [`StepTelemetry`], the per-run handle owned by
//!   `GraphWorkspace`/`NativeTrainer`: per-phase timings (`fwd`,
//!   `score`, `select`, `apply`, shard `dispatch`/`reduce`) plus
//!   per-layer realized-K / backward-FLOP counters, and frozen
//!   [`PhaseRollup`] summaries for serve job views;
//! * [`trace`] — a bounded ring-buffer event trace rendered as Chrome
//!   trace-event JSON (`repro trace`, chrome://tracing);
//! * [`prom`] — Prometheus text-format rendering used by the serve
//!   tier's `metrics` op (protocol v5 `format: "prometheus"`); the
//!   serve handler also renders the v8 resilience families through it
//!   (`repro_health_status`, `repro_rejected_total{reason}`,
//!   `repro_connections_open`);
//! * [`audit`] — gradient-fidelity audit records and selection
//!   diagnostics (Jaccard overlap, score entropy) for the
//!   training-dynamics layer (ISSUE 7): measure how faithful the
//!   K-of-M update is to the exact gradient, without perturbing it.
//!
//! Design contract (asserted by tests and BENCH_6):
//! [`ObsConfig::off`] means **no timer reads** on the hot path;
//! enabled telemetry performs **zero heap allocations** in steady
//! state (everything is pre-sized at workspace construction); and
//! observability reads clocks but never feeds them back into
//! execution, so the exec determinism contract (bit-identical curves
//! at any thread count) holds with obs on and off.

pub mod audit;
pub mod hist;
pub mod prom;
pub mod telemetry;
pub mod trace;

pub use audit::{jaccard, score_entropy, AuditLayerRecord};
pub use hist::{AtomicHistogram, Histogram, BUCKETS};
pub use prom::PromBuf;
pub use telemetry::{LayerAudit, LayerStat, Phase, PhaseRollup, PhaseStat, StepTelemetry};
pub use trace::{TraceEvent, TraceRing};

/// Default trace-ring capacity when obs is enabled.
pub const DEFAULT_TRACE_CAPACITY: usize = 1024;

/// Observability configuration for one telemetry handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsConfig {
    /// Master switch: `false` ⇒ no clock reads, nothing recorded.
    pub enabled: bool,
    /// Ring-buffer slots for the event trace (0 ⇒ no trace retained;
    /// histograms and counters still record when enabled).
    pub trace_capacity: usize,
}

impl ObsConfig {
    /// Telemetry fully off — the hot path performs no timer reads.
    pub const fn off() -> ObsConfig {
        ObsConfig { enabled: false, trace_capacity: 0 }
    }

    /// Telemetry on with the default trace capacity.
    pub const fn on() -> ObsConfig {
        ObsConfig { enabled: true, trace_capacity: DEFAULT_TRACE_CAPACITY }
    }

    /// Telemetry on with an explicit trace-ring capacity.
    pub const fn with_trace_capacity(trace_capacity: usize) -> ObsConfig {
        ObsConfig { enabled: true, trace_capacity }
    }
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig::off()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_presets() {
        assert!(!ObsConfig::off().enabled);
        assert_eq!(ObsConfig::off().trace_capacity, 0);
        assert!(ObsConfig::on().enabled);
        assert_eq!(ObsConfig::on().trace_capacity, DEFAULT_TRACE_CAPACITY);
        let c = ObsConfig::with_trace_capacity(64);
        assert!(c.enabled);
        assert_eq!(c.trace_capacity, 64);
        assert_eq!(ObsConfig::default(), ObsConfig::off());
    }
}
