//! Gradient-fidelity audit records + selection diagnostics (ISSUE 7).
//!
//! The paper's whole argument is that the error-feedback memory makes
//! K-of-M outer-product subsampling *unbiased in the long run* — this
//! module holds the vocabulary for measuring that claim on a live run:
//!
//! * [`AuditLayerRecord`] — one layer's fidelity snapshot from the
//!   auditor in `train::step::audit_into` (cosine similarity and
//!   relative Frobenius error of the applied update against the exact
//!   K=M gradient, plus the memory-corrected-vs-raw bias), carried in
//!   `EpochMetrics` and streamed over the serve `watch` op;
//! * [`jaccard`] — consecutive-step selection-index overlap, the
//!   stability of the policy's choices;
//! * [`score_entropy`] — Shannon entropy (nats) of the normalized
//!   policy score distribution, the concentration of the selection
//!   signal.
//!
//! Everything here is pure arithmetic over caller-owned slices: no
//! allocation, no RNG, no clocks — safe to call from the observation
//! path without touching the determinism contract.

use crate::tensor::quant::TraceMode;
use crate::util::json::{self, Json};

/// One layer's gradient-fidelity audit: the applied Mem-AOP update
/// compared against the exact same-mini-batch K=M weight gradient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AuditLayerRecord {
    /// Layer index in the graph (0 = input layer).
    pub layer: usize,
    /// Cosine similarity of applied update vs exact (memory-folded)
    /// gradient; 1.0 means perfectly aligned.
    pub cosine: f64,
    /// Relative Frobenius error ‖approx − exact‖ / ‖exact‖.
    pub rel_err: f64,
    /// ‖exact(memory-folded) − exact(raw)‖ / ‖exact(raw)‖ — how much
    /// the banked residual bends the exact gradient this step.
    pub mem_bias: f64,
    /// Storage precision of the trace this layer's `X̂` was folded from
    /// (§Mixed precision) — the *input* trace, i.e. the previous layer's
    /// activation storage; `F32` for the first layer (raw input batch)
    /// and for all-f32 runs. When quantized, `rel_err`/`cosine` compare
    /// the applied update against the f32-trace exact gradient, so they
    /// include the quantization drift.
    pub trace: TraceMode,
}

impl AuditLayerRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("layer", json::num(self.layer as f64)),
            ("cosine", json::num(self.cosine)),
            ("rel_err", json::num(self.rel_err)),
            ("mem_bias", json::num(self.mem_bias)),
        ];
        // wire back-compat: all-f32 records serialize exactly as before
        if self.trace != TraceMode::F32 {
            fields.push(("trace", json::s(self.trace.name())));
        }
        json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<AuditLayerRecord> {
        let num = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("audit record missing numeric '{k}'"))
        };
        let trace = match j.get("trace").and_then(|v| v.as_str()) {
            Some(s) => TraceMode::parse_or_suggest(s).map_err(|e| anyhow::anyhow!(e))?,
            None => TraceMode::F32,
        };
        Ok(AuditLayerRecord {
            layer: num("layer")? as usize,
            cosine: num("cosine")?,
            rel_err: num("rel_err")?,
            mem_bias: num("mem_bias")?,
            trace,
        })
    }
}

/// Jaccard overlap |a ∩ b| / |a ∪ b| of two selection-index sets.
///
/// Inputs are the per-step `Selection::indices` slices — distinct
/// within each slice but in arbitrary order, and small (≤ M ≤ a few
/// hundred), so the quadratic scan beats sorting or hashing and
/// allocates nothing. Two empty selections count as identical (1.0).
pub fn jaccard(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let inter = a.iter().filter(|x| b.contains(x)).count();
    let union = a.len() + b.len() - inter;
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

/// Shannon entropy (nats) of the policy score distribution,
/// normalized to probabilities. Scores are the per-row importance
/// weights (non-negative); non-finite or non-positive mass — and the
/// empty slice the Exact policy produces — report 0.0 rather than
/// poisoning downstream means.
pub fn score_entropy(scores: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    for &s in scores {
        let s = s as f64;
        if !s.is_finite() || s < 0.0 {
            return 0.0;
        }
        sum += s;
    }
    if sum <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0f64;
    for &s in scores {
        let p = s as f64 / sum;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jaccard_overlap_cases() {
        assert_eq!(jaccard(&[], &[]), 1.0);
        assert_eq!(jaccard(&[1, 2, 3], &[]), 0.0);
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[3, 1, 2], &[2, 3, 1]), 1.0, "order-insensitive");
        assert_eq!(jaccard(&[1, 2], &[2, 3]), 1.0 / 3.0);
        assert_eq!(jaccard(&[0, 1], &[2, 3]), 0.0);
    }

    #[test]
    fn entropy_of_uniform_and_point_masses() {
        let h4 = score_entropy(&[1.0, 1.0, 1.0, 1.0]);
        assert!((h4 - (4.0f64).ln()).abs() < 1e-12, "uniform over 4 = ln 4, got {h4}");
        assert_eq!(score_entropy(&[0.0, 5.0, 0.0]), 0.0, "point mass has zero entropy");
        assert_eq!(score_entropy(&[]), 0.0, "exact policy produces no scores");
        assert_eq!(score_entropy(&[0.0, 0.0]), 0.0, "zero mass");
        assert_eq!(score_entropy(&[f32::NAN, 1.0]), 0.0, "non-finite scores report 0");
        assert_eq!(score_entropy(&[-1.0, 2.0]), 0.0, "negative mass reports 0");
    }

    #[test]
    fn audit_record_json_roundtrip() {
        let r = AuditLayerRecord {
            layer: 2,
            cosine: 0.987,
            rel_err: 0.125,
            mem_bias: 0.03,
            trace: TraceMode::F32,
        };
        let back = AuditLayerRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        // all-f32 records serialize without a trace key (wire back-compat)
        assert!(r.to_json().get("trace").is_none());
        let q = AuditLayerRecord { trace: TraceMode::Q8, ..r };
        assert_eq!(q.to_json().get("trace").and_then(|v| v.as_str()), Some("q8"));
        assert_eq!(AuditLayerRecord::from_json(&q.to_json()).unwrap(), q);
        assert!(AuditLayerRecord::from_json(&json::obj(vec![])).is_err());
    }
}
