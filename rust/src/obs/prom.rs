//! Minimal Prometheus text-format (exposition format 0.0.4) rendering
//! over `obs` counters, gauges and histograms.
//!
//! Render-at-scrape: these helpers allocate freely — they run on the
//! serve tier when a client asks for `{"op":"metrics",
//! "format":"prometheus"}`, never on the step hot path. Durations are
//! rendered in **seconds** (the Prometheus base-unit convention); the
//! power-of-two-ns buckets of [`Histogram`] become `le` edges of
//! `2^(i+1) / 1e9` seconds.
//!
//! Metric names emitted through this module are a **stable interface**
//! (see README "Observability"): names and label keys only ever get
//! added, never renamed or removed.

use crate::obs::hist::{Histogram, BUCKETS};

/// Registry of every exported `repro_*` Prometheus family (repro-lint
/// rule R5): `(name, kind, help)`. This table is the single source of
/// truth for metric-name stability — handlers render headers through
/// [`PromBuf::family`], which panics on an unregistered name, and the
/// linter statically rejects any `repro_*` string literal in the tree
/// that is not declared here (suffixes `_bucket`/`_sum`/`_count` derive
/// from the histogram family). Entries are only ever added, never
/// renamed or removed (README §Observability).
pub const METRIC_FAMILIES: &[(&str, &str, &str)] = &[
    ("repro_uptime_seconds", "gauge", "Server uptime in seconds."),
    ("repro_requests_total", "counter", "Protocol requests handled, all ops."),
    ("repro_queue_depth", "gauge", "Jobs accepted but not yet running."),
    ("repro_slots_total", "gauge", "Training-thread slot budget (--workers)."),
    ("repro_slots_busy", "gauge", "Slots held by running jobs (threads, not jobs)."),
    ("repro_slots_free", "gauge", "Slots not held by running jobs."),
    ("repro_utilization_ratio", "gauge", "Busy fraction of the slot budget."),
    ("repro_pool_workers_busy", "gauge", "Pool workers currently driving a job."),
    ("repro_pool_tasks_pending", "gauge", "Jobs queued in the worker pool."),
    (
        "repro_health_status",
        "gauge",
        "1 when the server is accepting submits and the queue has headroom, else 0.",
    ),
    ("repro_rejected_total", "counter", "Rejected submits by reason."),
    ("repro_connections_open", "gauge", "Open client connections."),
    ("repro_jobs_total", "gauge", "Jobs by lifecycle state."),
    ("repro_request_latency_seconds", "histogram", "Request handling latency by op."),
    ("repro_policy_jobs_total", "counter", "Completed jobs touching each policy."),
    (
        "repro_policy_backward_flops_total",
        "counter",
        "Backward weight-gradient FLOPs actually spent, by policy.",
    ),
    (
        "repro_policy_exact_flops_total",
        "counter",
        "What exact back-propagation would have spent, by policy.",
    ),
    ("repro_policy_saved_ratio", "gauge", "Fraction of exact backward FLOPs saved, by policy."),
    ("repro_audit_epoch", "gauge", "Epoch of the job's most recent gradient-fidelity audit."),
    (
        "repro_audit_cosine",
        "gauge",
        "Cosine similarity of the Mem-AOP update vs the exact same-batch gradient, per layer.",
    ),
    (
        "repro_audit_rel_err",
        "gauge",
        "Relative Frobenius error of the Mem-AOP update vs the exact gradient, per layer.",
    ),
    (
        "repro_audit_mem_bias",
        "gauge",
        "Relative deviation of the memory-corrected update from the raw outer product, per layer.",
    ),
    (
        "repro_trace_bytes",
        "gauge",
        "Backward-read forward-trace bytes per job (quantized-trace jobs only).",
    ),
];

/// Look up a registered family; `None` for names outside the table.
pub fn metric_family(name: &str) -> Option<(&'static str, &'static str)> {
    METRIC_FAMILIES
        .iter()
        .find(|(n, _, _)| *n == name)
        .map(|(_, kind, help)| (*kind, *help))
}

/// Incremental Prometheus text-format builder.
pub struct PromBuf {
    out: String,
}

impl PromBuf {
    pub fn new() -> PromBuf {
        PromBuf { out: String::new() }
    }

    /// `# HELP` + `# TYPE` header; `kind` ∈ `counter|gauge|histogram`.
    pub fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Header for a family registered in [`METRIC_FAMILIES`] — the only
    /// way serve handlers emit `repro_*` headers, so an unregistered
    /// name fails loudly at scrape time (and statically via repro-lint).
    pub fn family(&mut self, name: &str) {
        let (kind, help) = metric_family(name)
            .unwrap_or_else(|| panic!("metric family {name} is not in obs::prom::METRIC_FAMILIES"));
        self.header(name, kind, help);
    }

    /// One sample line `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(&render_name(name, labels));
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// A full histogram family (`_bucket`/`_sum`/`_count`) from a
    /// nanosecond histogram, rendered in seconds. Cumulative bucket
    /// counts; the overflow bucket maps to `le="+Inf"`.
    pub fn histogram_ns(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let mut cum = 0u64;
        for i in 0..BUCKETS - 1 {
            cum += h.counts()[i];
            let le = fmt_value((1u64 << (i + 1)) as f64 / 1e9);
            self.bucket_line(name, labels, &le, cum);
        }
        self.bucket_line(name, labels, "+Inf", h.count());
        self.sample(&format!("{name}_sum"), labels, h.sum_ns() as f64 / 1e9);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    fn bucket_line(&mut self, name: &str, labels: &[(&str, &str)], le: &str, cum: u64) {
        let mut all: Vec<(&str, &str)> = labels.to_vec();
        all.push(("le", le));
        self.out.push_str(&render_name(&format!("{name}_bucket"), &all));
        self.out.push(' ');
        self.out.push_str(&fmt_value(cum as f64));
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromBuf {
    fn default() -> PromBuf {
        PromBuf::new()
    }
}

fn render_name(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{name}{{{}}}", body.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Prometheus value formatting: integral values render without a
/// fraction, everything else as shortest-roundtrip decimal.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render() {
        let mut p = PromBuf::new();
        p.header("repro_requests_total", "counter", "Requests handled.");
        p.sample("repro_requests_total", &[], 42.0);
        p.sample("repro_jobs_total", &[("state", "done")], 7.0);
        let text = p.finish();
        assert!(text.contains("# TYPE repro_requests_total counter\n"));
        assert!(text.contains("\nrepro_requests_total 42\n"));
        assert!(text.contains("repro_jobs_total{state=\"done\"} 7\n"));
    }

    #[test]
    fn histogram_renders_cumulative_buckets_in_seconds() {
        let mut h = Histogram::new();
        h.record(1_000);   // 1 µs  → bucket 9, le 2^10 ns ≈ 1.024e-6 s
        h.record(1_000_000); // 1 ms
        let mut p = PromBuf::new();
        p.histogram_ns("req_seconds", &[("op", "ping")], &h);
        let text = p.finish();
        assert!(text.contains("req_seconds_bucket{op=\"ping\",le=\"+Inf\"} 2\n"));
        assert!(text.contains("req_seconds_count{op=\"ping\"} 2\n"));
        assert!(text.contains(&format!("req_seconds_sum{{op=\"ping\"}} {}", 1_001_000.0 / 1e9)));
        // cumulative: every bucket line's count is non-decreasing
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v as u64 >= last, "{line}");
            last = v as u64;
        }
        // 1 µs sample is included from its bucket's edge on
        let edge = fmt_value((1u64 << 10) as f64 / 1e9);
        assert!(text.contains(&format!("le=\"{edge}\"}} 1\n")), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromBuf::new();
        p.sample("x", &[("tag", "a\"b\\c\nd")], 1.0);
        assert_eq!(p.finish(), "x{tag=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn metric_family_registry_is_unique_and_well_kinded() {
        for (i, (name, kind, help)) in METRIC_FAMILIES.iter().enumerate() {
            assert!(name.starts_with("repro_"), "family {name} outside the repro_ namespace");
            assert!(
                matches!(*kind, "counter" | "gauge" | "histogram"),
                "family {name} has unknown kind {kind}"
            );
            assert!(!help.is_empty(), "family {name} has empty help");
            for (other, _, _) in &METRIC_FAMILIES[i + 1..] {
                assert_ne!(name, other, "duplicate metric family {name}");
            }
        }
    }

    #[test]
    fn family_renders_registered_headers() {
        let mut p = PromBuf::new();
        p.family("repro_requests_total");
        let text = p.finish();
        assert!(text.contains("# TYPE repro_requests_total counter\n"), "{text}");
        assert!(text.contains("# HELP repro_requests_total "), "{text}");
    }

    #[test]
    #[should_panic(expected = "not in obs::prom::METRIC_FAMILIES")]
    fn family_panics_on_unregistered_name() {
        // lint: allow(metric-name) deliberately unregistered: this test pins the panic path
        PromBuf::new().family("repro_not_a_family");
    }
}
