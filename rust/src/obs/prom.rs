//! Minimal Prometheus text-format (exposition format 0.0.4) rendering
//! over `obs` counters, gauges and histograms.
//!
//! Render-at-scrape: these helpers allocate freely — they run on the
//! serve tier when a client asks for `{"op":"metrics",
//! "format":"prometheus"}`, never on the step hot path. Durations are
//! rendered in **seconds** (the Prometheus base-unit convention); the
//! power-of-two-ns buckets of [`Histogram`] become `le` edges of
//! `2^(i+1) / 1e9` seconds.
//!
//! Metric names emitted through this module are a **stable interface**
//! (see README "Observability"): names and label keys only ever get
//! added, never renamed or removed.

use crate::obs::hist::{Histogram, BUCKETS};

/// Incremental Prometheus text-format builder.
pub struct PromBuf {
    out: String,
}

impl PromBuf {
    pub fn new() -> PromBuf {
        PromBuf { out: String::new() }
    }

    /// `# HELP` + `# TYPE` header; `kind` ∈ `counter|gauge|histogram`.
    pub fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// One sample line `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(&render_name(name, labels));
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// A full histogram family (`_bucket`/`_sum`/`_count`) from a
    /// nanosecond histogram, rendered in seconds. Cumulative bucket
    /// counts; the overflow bucket maps to `le="+Inf"`.
    pub fn histogram_ns(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let mut cum = 0u64;
        for i in 0..BUCKETS - 1 {
            cum += h.counts()[i];
            let le = fmt_value((1u64 << (i + 1)) as f64 / 1e9);
            self.bucket_line(name, labels, &le, cum);
        }
        self.bucket_line(name, labels, "+Inf", h.count());
        self.sample(&format!("{name}_sum"), labels, h.sum_ns() as f64 / 1e9);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    fn bucket_line(&mut self, name: &str, labels: &[(&str, &str)], le: &str, cum: u64) {
        let mut all: Vec<(&str, &str)> = labels.to_vec();
        all.push(("le", le));
        self.out.push_str(&render_name(&format!("{name}_bucket"), &all));
        self.out.push(' ');
        self.out.push_str(&fmt_value(cum as f64));
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromBuf {
    fn default() -> PromBuf {
        PromBuf::new()
    }
}

fn render_name(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{name}{{{}}}", body.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Prometheus value formatting: integral values render without a
/// fraction, everything else as shortest-roundtrip decimal.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render() {
        let mut p = PromBuf::new();
        p.header("repro_requests_total", "counter", "Requests handled.");
        p.sample("repro_requests_total", &[], 42.0);
        p.sample("repro_jobs_total", &[("state", "done")], 7.0);
        let text = p.finish();
        assert!(text.contains("# TYPE repro_requests_total counter\n"));
        assert!(text.contains("\nrepro_requests_total 42\n"));
        assert!(text.contains("repro_jobs_total{state=\"done\"} 7\n"));
    }

    #[test]
    fn histogram_renders_cumulative_buckets_in_seconds() {
        let mut h = Histogram::new();
        h.record(1_000);   // 1 µs  → bucket 9, le 2^10 ns ≈ 1.024e-6 s
        h.record(1_000_000); // 1 ms
        let mut p = PromBuf::new();
        p.histogram_ns("repro_req", &[("op", "ping")], &h);
        let text = p.finish();
        assert!(text.contains("repro_req_bucket{op=\"ping\",le=\"+Inf\"} 2\n"));
        assert!(text.contains("repro_req_count{op=\"ping\"} 2\n"));
        assert!(text.contains(&format!("repro_req_sum{{op=\"ping\"}} {}", 1_001_000.0 / 1e9)));
        // cumulative: every bucket line's count is non-decreasing
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v as u64 >= last, "{line}");
            last = v as u64;
        }
        // 1 µs sample is included from its bucket's edge on
        let edge = fmt_value((1u64 << 10) as f64 / 1e9);
        assert!(text.contains(&format!("le=\"{edge}\"}} 1\n")), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromBuf::new();
        p.sample("x", &[("tag", "a\"b\\c\nd")], 1.0);
        assert_eq!(p.finish(), "x{tag=\"a\\\"b\\\\c\\nd\"} 1\n");
    }
}
