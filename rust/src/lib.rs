//! # Mem-AOP-GD
//!
//! Production-quality reproduction of *"Speeding-Up Back-Propagation in
//! DNN: Approximate Outer Product with Memory"* (Hernandez, Rini, Duman,
//! 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the masked
//!   scaled outer-product accumulation (the AOP of eq. (4)/(5)), policy
//!   scores, and memory updates;
//! * **Layer 2** — JAX graphs (`python/compile/model.py`) AOT-lowered to
//!   HLO-text artifacts consumed by the Rust runtime;
//! * **Layer 3** — this crate: the training coordinator (config system,
//!   dataset substrates, selection policies, error-feedback memory,
//!   experiment scheduler, figure harness) plus a pure-Rust reference
//!   implementation of the whole algorithm used as the numerics oracle
//!   and baseline comparator.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! graphs once, and the `repro` binary is self-contained afterwards.
//!
//! See `examples/` for end-to-end drivers and `repro --help` for the CLI.

pub mod aop;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod util;
