//! # Mem-AOP-GD
//!
//! Production-quality reproduction of *"Speeding-Up Back-Propagation in
//! DNN: Approximate Outer Product with Memory"* (Hernandez, Rini, Duman,
//! 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the masked
//!   scaled outer-product accumulation (the AOP of eq. (4)/(5)), policy
//!   scores, and memory updates;
//! * **Layer 2** — JAX graphs (`python/compile/model.py`) AOT-lowered to
//!   HLO-text artifacts consumed by the Rust runtime;
//! * **Layer 3** — this crate: the training coordinator (config system,
//!   dataset substrates, selection policies, error-feedback memory,
//!   experiment scheduler, figure harness) plus a pure-Rust reference
//!   implementation of the whole algorithm used as the numerics oracle
//!   and baseline comparator.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! graphs once, and the `repro` binary is self-contained afterwards.
//!
//! On top of the coordinator sits the [`serve`] subsystem — a std-only
//! TCP/JSON training-job server (`repro serve`): submit any
//! `ExperimentConfig`, poll status, stream loss curves, cancel, and
//! scrape queue/throughput/FLOP-savings metrics, with completed runs
//! persisted through `coordinator::checkpoint` so the run registry
//! survives restarts. See README.md for the wire protocol.
//!
//! Underneath the native trainer sits the [`exec`] subsystem — a
//! deterministic data-parallel execution engine: batch rows are sharded
//! on a fixed grid across a persistent worker pool and reduced in fixed
//! shard order, so any `threads` setting (config field, `--threads`
//! flag, serve protocol) produces bit-identical curves and weights —
//! the thread count is a speed knob, never a hyperparameter.
//!
//! The algorithm itself lives in exactly one place: the [`train`] module
//! — a layer-graph model (`Dense` layers with pluggable activations)
//! with per-layer `{k, policy, memory}` configuration and a single
//! phase-split Mem-AOP-GD step built on the `exec` shard primitives.
//! `AopEngine` (1-layer identity graph), the MLP API, `NativeTrainer`
//! and the serve job path are all thin adapters over it.
//!
//! Observability is first-class but never intrusive: the [`obs`]
//! subsystem records per-phase step timings, per-layer realized
//! budgets, a bounded event trace (`repro trace` → chrome://tracing)
//! and the serve tier's Prometheus exposition — pre-allocated and
//! zero-allocation when enabled, free of clock reads when disabled,
//! and incapable of changing a curve bit either way.
//!
//! Builds are offline-first: the PJRT execution path is gated behind the
//! `hlo` cargo feature (default off), so `cargo build && cargo test`
//! needs no XLA toolchain — `--backend hlo` then reports a clear
//! "backend unavailable" error while `--backend native` runs everywhere.
//!
//! See `examples/` for end-to-end drivers and `repro --help` for the CLI.

pub mod aop;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
