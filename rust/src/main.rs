//! `repro` — the Mem-AOP-GD coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's evaluation section:
//!
//! * `train`              — one configured experiment (any policy/K/
//!                          memory/backend), prints the loss curve;
//! * `figure --fig 2|3`   — regenerate Fig. 2 / Fig. 3 (21 series each)
//!                          into `results/`;
//! * `table`              — print Tab. I from the config presets;
//! * `complexity`         — the Sec. I computational-reduction claim;
//! * `mlp`                — end-to-end multi-layer MLP training through
//!                          the monolithic AOT artifacts;
//! * `inspect-artifacts`  — compile every artifact and report compile
//!                          times + manifest contract;
//! * `serve`              — long-lived training-job server (TCP/JSON):
//!                          submit/status/result/list/cancel/metrics,
//!                          persistent run registry (see README.md);
//! * `trace`              — run a short native experiment with the obs
//!                          event ring enabled and dump a Chrome
//!                          trace-event JSON (chrome://tracing /
//!                          Perfetto) plus a per-phase latency rollup;
//! * `audit`              — run a native experiment with the gradient-
//!                          fidelity auditor enabled and print the
//!                          per-layer cosine / relative-error / memory-
//!                          bias table for every audited epoch.

use anyhow::{anyhow, bail, Result};

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, Task};
use mem_aop_gd::coordinator::figures::{self, FigureOptions};
use mem_aop_gd::coordinator::mlp_driver::{self, MlpVariant};
use mem_aop_gd::coordinator::experiment;
use mem_aop_gd::data::digits;
use mem_aop_gd::metrics::print_table;
use mem_aop_gd::runtime::Runtime;
use mem_aop_gd::util::cli::{App, Args, Command};

/// `--policy` help generated from [`Policy::all`] so the CLI can never
/// drift from the policies the crate actually implements. Leaked once at
/// startup (the option table wants `&'static str`).
fn policy_help() -> &'static str {
    Box::leak(Policy::names_joined(" | ").into_boxed_str())
}

fn app() -> App {
    App {
        name: "repro",
        about: "Mem-AOP-GD (Hernandez, Rini, Duman 2021) — training coordinator",
        commands: vec![
            Command::new("train", "run one experiment and print its curve")
                .opt("task", "energy", "energy | mnist")
                .opt("policy", "topk", policy_help())
                .opt(
                    "k",
                    "18",
                    "outer-product budget per update: <k> | step:<k0>:<every>:<gamma> | \
                     cosine:<k0>:<min-frac> | linear:<from>:<to> (resolved per epoch, \
                     clamped to [1, M])",
                )
                .opt("epochs", "0", "override Tab. I epochs (0 = preset)")
                .opt("lr", "0.01", "learning rate")
                .opt("schedule", "constant", "constant | step:<every>:<gamma> | cosine:<min-frac>")
                .opt("seed", "0", "RNG seed")
                .opt("backend", "hlo", "hlo (PJRT artifacts) | native (pure Rust)")
                .opt("data-scale", "1.0", "fraction of Tab. I dataset size (mnist)")
                .opt(
                    "threads",
                    "1",
                    "data-parallel training threads (native backend; bit-identical curves at any value)",
                )
                .opt(
                    "layers",
                    "",
                    "layer-graph spec `width[:activation[:ksched[:trace]]],...` ending at the \
                     task output width, e.g. `32:tanh:16,10` or `4096:relu:32:bf16,10` \
                     (native backend; empty = flat single layer)",
                )
                .opt(
                    "trace",
                    "f32",
                    "forward-trace storage: f32 | bf16 | q8 (native backend; default for \
                     every layer, per-layer override via --layers; head and exact-policy \
                     inputs stay f32)",
                )
                .opt(
                    "accum",
                    "f32",
                    "backward accumulation width: f32 | f64 | kahan (native backend)",
                )
                .opt("save", "", "write final weights+memories to this checkpoint path")
                .opt(
                    "audit",
                    "",
                    "gradient-fidelity audit cadence `every:<n>` (native backend; \
                     observation-only, empty = off)",
                )
                .flag("no-memory", "disable error-feedback memory")
                .flag("quiet", "suppress per-epoch output"),
            Command::new("figure", "regenerate a paper figure into results/")
                .opt("fig", "2", "2 (energy) | 3 (mnist)")
                .opt("backend", "native", "native | hlo")
                .opt("epochs", "0", "override epochs (0 = Tab. I)")
                .opt("data-scale", "1.0", "dataset scale (mnist)")
                .opt("seed", "0", "RNG seed")
                .opt("workers", "0", "parallel workers (0 = auto)")
                .opt("out", "results", "output directory"),
            Command::new("table", "print Tab. I (hyperparameters)"),
            Command::new("complexity", "FLOP/time reduction of the AOP gradient")
                .opt("out", "results", "output directory"),
            Command::new("mlp", "end-to-end multi-layer MLP via AOT artifacts")
                .opt("variant", "topk-mem", "exact | topk-mem | topk-nomem | randk-mem | weightedk-mem")
                .opt("steps", "300", "training steps")
                .opt("lr", "0.05", "learning rate")
                .opt("eval-every", "50", "steps between evaluations")
                .opt("train-samples", "12800", "synthetic digit training samples")
                .opt("val-samples", "1280", "synthetic digit validation samples")
                .opt("seed", "0", "RNG seed"),
            Command::new(
                "approx-error",
                "empirical AOP approximation-error analysis (DKM bound)",
            )
            .opt("m", "64", "batch rows (outer products)")
            .opt("n", "784", "input dim")
            .opt("p", "10", "output dim")
            .opt("skew", "2.0", "row-norm skew of the synthetic (X, G)")
            .opt("trials", "60", "policy draws per cell")
            .opt("seed", "0", "RNG seed")
            .opt("out", "results", "output directory"),
            Command::new("inspect-artifacts", "compile all artifacts, report stats"),
            Command::new("serve", "training-job server: TCP/JSON submit/status/result/metrics")
                .opt("addr", "127.0.0.1:7070", "listen address (host:port; port 0 = ephemeral)")
                .opt("workers", "0", "training worker threads (0 = auto)")
                .opt("queue-cap", "256", "max queued jobs before submissions are rejected")
                .opt("registry-dir", "", "persist completed runs here (empty = in-memory only)")
                .opt("max-conns", "256", "max simultaneous client connections")
                .opt("rate-limit", "0", "max submits/s per client IP (0 = unlimited)")
                .opt("rate-burst", "8", "submit burst allowed per client after idle")
                .opt("frame-timeout-s", "30", "close a connection stuck mid-frame this long (0 = never)")
                .opt("idle-timeout-s", "0", "close a connection idle this long (0 = never)")
                .opt("faults", "", "inject faults, e.g. seed=7,panic=50,torn=100,drop=25 (per-mille rates; chaos testing)"),
            Command::new("trace", "dump a Chrome trace of one native run (obs event ring)")
                .opt("task", "energy", "energy | mnist")
                .opt("policy", "topk", policy_help())
                .opt("k", "18", "outer-product budget per update (same grammar as train --k)")
                .opt("epochs", "1", "epochs to trace (0 = Tab. I preset)")
                .opt("threads", "1", "data-parallel training threads")
                .opt("data-scale", "1.0", "fraction of Tab. I dataset size (mnist)")
                .opt("seed", "0", "RNG seed")
                .opt("events", "4096", "trace-ring capacity (oldest events overwritten)")
                .opt("out", "results/trace.json", "Chrome trace-event JSON output path"),
            Command::new("audit", "gradient-fidelity audit of one native run")
                .opt("task", "energy", "energy | mnist")
                .opt("policy", "topk", policy_help())
                .opt("k", "18", "outer-product budget per update (same grammar as train --k)")
                .opt("epochs", "3", "epochs to run (0 = Tab. I preset)")
                .opt("every", "every:1", "audit cadence `every:<n>` (epoch 1, then every n-th)")
                .opt("threads", "1", "data-parallel training threads")
                .opt("data-scale", "1.0", "fraction of Tab. I dataset size (mnist)")
                .opt("seed", "0", "RNG seed")
                .opt("trace", "f32", "forward-trace storage: f32 | bf16 | q8")
                .opt("accum", "f32", "backward accumulation width: f32 | f64 | kahan")
                .flag("no-memory", "disable error-feedback memory"),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let code = match app.parse(&argv) {
        Err(e) => {
            eprintln!("{e}");
            2
        }
        Ok((cmd, args)) => match dispatch(cmd.name, &args) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
    };
    std::process::exit(code);
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "figure" => cmd_figure(args),
        "table" => {
            figures::table_one();
            Ok(())
        }
        "complexity" => {
            let out = std::path::PathBuf::from(args.get("out").unwrap_or("results"));
            figures::complexity(&out)
        }
        "mlp" => cmd_mlp(args),
        "approx-error" => cmd_approx_error(args),
        "inspect-artifacts" => cmd_inspect(),
        "serve" => cmd_serve(args),
        "trace" => cmd_trace(args),
        "audit" => cmd_audit(args),
        _ => bail!("unhandled command {cmd}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let task = Task::parse(args.get("task").unwrap_or("energy"))
        .ok_or_else(|| anyhow!("bad --task"))?;
    let mut cfg = ExperimentConfig::preset(task);
    cfg.policy = Policy::parse_or_suggest(args.get("policy").unwrap_or("topk"))
        .map_err(|e| anyhow!("--policy: {e}"))?;
    cfg.k = mem_aop_gd::coordinator::config::KSchedule::parse(args.get("k").unwrap_or("18"))
        .map_err(|e| anyhow!("--k: {e}"))?;
    if cfg.policy == Policy::Exact {
        cfg.k = mem_aop_gd::coordinator::config::KSchedule::constant(cfg.m());
    }
    let epochs: usize = args.get_parse("epochs")?;
    if epochs > 0 {
        cfg.epochs = epochs;
    }
    cfg.lr = args.get_parse("lr")?;
    cfg.schedule =
        mem_aop_gd::coordinator::config::LrSchedule::parse(args.get("schedule").unwrap_or("constant"))
            .map_err(|e| anyhow!("--schedule: {e}"))?;
    cfg.seed = args.get_parse("seed")?;
    cfg.backend = Backend::parse(args.get("backend").unwrap_or("hlo"))
        .ok_or_else(|| anyhow!("bad --backend"))?;
    cfg.data_scale = args.get_parse("data-scale")?;
    cfg.threads = args.get_parse("threads")?;
    cfg.trace = mem_aop_gd::tensor::quant::TraceMode::parse_or_suggest(
        args.get("trace").unwrap_or("f32"),
    )
    .map_err(|e| anyhow!("--trace: {e}"))?;
    cfg.accum = mem_aop_gd::tensor::quant::AccumMode::parse_or_suggest(
        args.get("accum").unwrap_or("f32"),
    )
    .map_err(|e| anyhow!("--accum: {e}"))?;
    cfg.memory = !args.flag("no-memory");
    if cfg.policy == Policy::Exact {
        cfg.memory = false;
    }
    if let Some(spec) = args.get("layers").filter(|s| !s.is_empty()) {
        use mem_aop_gd::coordinator::config::LayerSpec;
        cfg.layers = Some(LayerSpec::parse_list(spec).map_err(|e| anyhow!("--layers: {e}"))?);
    }
    if let Some(spec) = args.get("audit").filter(|s| !s.is_empty()) {
        cfg.audit = Some(
            mem_aop_gd::coordinator::config::parse_audit(spec)
                .map_err(|e| anyhow!("--audit: {e}"))?,
        );
    }
    cfg.validate()?;

    println!(
        "training {} / {} (K={}/{}, backend={}, {} epochs, lr={}, seed={}, threads={})",
        cfg.task.name(),
        cfg.label(),
        cfg.k.name(),
        cfg.m(),
        cfg.backend.name(),
        cfg.epochs,
        cfg.lr,
        cfg.seed,
        cfg.threads
    );
    if cfg.layers.is_some() {
        use mem_aop_gd::tensor::quant::{AccumMode, TraceMode};
        for (i, rl) in cfg.layer_plan().iter().enumerate() {
            // Precision suffix only when some knob left f32, so the
            // historical all-f32 echo stays byte-identical.
            let mut prec = String::new();
            if rl.trace != TraceMode::F32 {
                prec.push_str(&format!(", trace={}", rl.trace.name()));
            }
            if rl.accum != AccumMode::F32 {
                prec.push_str(&format!(", accum={}", rl.accum.name()));
            }
            println!(
                "  layer {i}: {}x{} {} (K={}, policy={}, memory={}{prec})",
                rl.fan_in,
                rl.fan_out,
                rl.activation.name(),
                rl.k.name(),
                rl.policy.name(),
                rl.memory
            );
        }
    }
    let r = experiment::run(&cfg)?;
    if !args.flag("quiet") {
        let mut rows = Vec::new();
        for m in &r.curve.epochs {
            rows.push(vec![
                format!("{}", m.epoch),
                format!("{:.5}", m.train_loss),
                format!("{:.5}", m.val_loss),
                format!("{:.4}", m.val_acc),
                format!("{:.4}", m.mem_fro),
                format!("{:.2}", m.wall_s),
            ]);
        }
        print_table(&["epoch", "train", "val", "acc", "mem_fro", "s"], &rows);
        print_audit_table(&r.curve.epochs);
    }
    println!(
        "final val loss {:.6} (best {:.6}); backward FLOPs {:.3e} ({:.3e}/s); {:.0} rows/s",
        r.final_val_loss(),
        r.curve.best_val_loss(),
        r.curve.total_backward_flops() as f64,
        r.curve.backward_flops_per_sec(),
        r.curve.mean_rows_per_sec()
    );
    if let Some(path) = args.get("save").filter(|s| !s.is_empty()) {
        use mem_aop_gd::coordinator::checkpoint::Checkpoint;
        let mut cp = Checkpoint::new();
        cp.put_scalar("n_layers", r.final_layers.len() as f32);
        for (i, (w, b)) in r.final_layers.iter().enumerate() {
            cp.put_matrix(&format!("w{i}"), w);
            cp.put_vector(&format!("b{i}"), b);
        }
        cp.put_scalar("epochs", cfg.epochs as f32);
        cp.save(std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let fig: usize = args.get_parse("fig")?;
    let task = match fig {
        2 => Task::Energy,
        3 => Task::Mnist,
        _ => bail!("--fig must be 2 or 3"),
    };
    let epochs: usize = args.get_parse("epochs")?;
    let workers: usize = args.get_parse("workers")?;
    let opts = FigureOptions {
        out_dir: args.get("out").unwrap_or("results").into(),
        backend: Backend::parse(args.get("backend").unwrap_or("native"))
            .ok_or_else(|| anyhow!("bad --backend"))?,
        epochs: if epochs == 0 { None } else { Some(epochs) },
        data_scale: args.get_parse("data-scale")?,
        seed: args.get_parse("seed")?,
        workers: if workers == 0 {
            mem_aop_gd::util::pool::default_workers()
        } else {
            workers
        },
    };
    figures::figure(task, &opts)?;
    Ok(())
}

fn cmd_mlp(args: &Args) -> Result<()> {
    let variant = MlpVariant::parse(args.get("variant").unwrap_or("topk-mem"))
        .ok_or_else(|| anyhow!("bad --variant"))?;
    let steps: usize = args.get_parse("steps")?;
    let lr: f32 = args.get_parse("lr")?;
    let eval_every: usize = args.get_parse("eval-every")?;
    let ntr: usize = args.get_parse("train-samples")?;
    let nva: usize = args.get_parse("val-samples")?;
    let seed: u64 = args.get_parse("seed")?;

    let rt = Runtime::from_default_artifacts()?;
    let meta = rt.manifest.mlp.clone();
    println!(
        "MLP {} on {} (layers {:?}, batch {}, K {} per layer)",
        variant.label(),
        rt.platform(),
        meta.layers,
        meta.batch,
        meta.k
    );
    let train = digits::digits_dataset(ntr, seed ^ 0xDA7A);
    let val = digits::digits_dataset(nva, seed ^ 0xDA7A ^ 1);
    let (driver, curve) =
        mlp_driver::train_mlp(&rt, variant, &train, &val, steps, lr, eval_every, seed)?;
    println!("{} parameters", driver.num_params());
    let mut rows = Vec::new();
    for m in &curve.epochs {
        rows.push(vec![
            format!("{}", m.epoch),
            format!("{:.4}", m.train_loss),
            format!("{:.4}", m.val_loss),
            format!("{:.4}", m.val_acc),
            format!("{:.1}", m.mem_fro),
            format!("{:.2}", m.wall_s),
        ]);
    }
    print_table(&["step", "train", "val", "acc", "mem_fro", "s"], &rows);
    Ok(())
}

fn cmd_approx_error(args: &Args) -> Result<()> {
    use mem_aop_gd::aop::analysis;
    use mem_aop_gd::tensor::rng::Rng;
    use mem_aop_gd::tensor::Matrix;

    let m: usize = args.get_parse("m")?;
    let n: usize = args.get_parse("n")?;
    let p: usize = args.get_parse("p")?;
    let skew: f32 = args.get_parse("skew")?;
    let trials: usize = args.get_parse("trials")?;
    let seed: u64 = args.get_parse("seed")?;
    let out_dir = std::path::PathBuf::from(args.get("out").unwrap_or("results"));

    let ks: Vec<usize> = [m / 16, m / 8, m / 4, m / 2, 3 * m / 4]
        .iter()
        .copied()
        .filter(|&k| k >= 1)
        .collect();
    println!(
        "one-shot relative error ‖Ŵ*−W*‖_F/‖W*‖_F  (M={m}, N={n}, P={p}, skew={skew})\n"
    );
    let pts = analysis::error_sweep(m, n, p, &ks, skew, trials, seed);
    let mut rows = Vec::new();
    let mut csv = String::from("policy,k,m,rel_error,sd\n");
    for pt in &pts {
        rows.push(vec![
            pt.policy.name().to_string(),
            format!("{}/{}", pt.k, pt.m),
            format!("{:.4}", pt.rel_error),
            format!("{:.4}", pt.sd),
            format!("{:.3}", pt.rel_error * (pt.k as f64).sqrt()),
        ]);
        csv.push_str(&format!(
            "{},{},{},{:.6},{:.6}\n",
            pt.policy.name(),
            pt.k,
            pt.m,
            pt.rel_error,
            pt.sd
        ));
    }
    print_table(&["policy", "K/M", "rel err", "sd", "err·√K"], &rows);
    println!("\n(DKM ref.[8]: err·√K ≈ const for weighted sampling — check the last column)");

    // deferred-flush identity demo on the same shapes
    let mut rng = Rng::new(seed ^ 0xFEED);
    let x = Matrix::from_fn(m, n, |_, _| rng.normal());
    let g = Matrix::from_fn(m, p, |_, _| rng.normal());
    let k = (m / 8).max(1);
    let mut r1 = Rng::new(seed ^ 1);
    let mut r2 = Rng::new(seed ^ 1);
    let with_mem =
        analysis::deferred_flush_error(&x, &g, mem_aop_gd::aop::Policy::TopK, k, true, &mut r1);
    let without =
        analysis::deferred_flush_error(&x, &g, mem_aop_gd::aop::Policy::TopK, k, false, &mut r2);
    println!(
        "\ndeferred-flush identity (topK, K={k}/{m}): select-then-flush vs exact\n  \
         rel err WITH memory    {with_mem:.2e}  (memory recovers the unselected mass exactly)\n  \
         rel err WITHOUT memory {without:.4}   (the one-shot approximation error persists)"
    );
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("approx_error.csv"), csv)?;
    println!("\nwrote {}", out_dir.join("approx_error.csv").display());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use mem_aop_gd::serve::{FaultPlan, ServeOptions, Server};
    use std::time::Duration;
    let faults = match args.get("faults").filter(|s| !s.is_empty()) {
        Some(spec) => FaultPlan::parse(spec).map_err(|e| anyhow!("--faults: {e}"))?,
        None => FaultPlan::off(),
    };
    let opts = ServeOptions {
        addr: args.get("addr").unwrap_or("127.0.0.1:7070").to_string(),
        workers: args.get_parse("workers")?,
        queue_capacity: args.get_parse("queue-cap")?,
        registry_dir: args
            .get("registry-dir")
            .filter(|s| !s.is_empty())
            .map(std::path::PathBuf::from),
        max_connections: args.get_parse("max-conns")?,
        rate_limit_per_sec: args.get_parse("rate-limit")?,
        rate_limit_burst: args.get_parse("rate-burst")?,
        frame_timeout: Duration::from_secs_f64(args.get_parse::<f64>("frame-timeout-s")?),
        idle_timeout: Duration::from_secs_f64(args.get_parse::<f64>("idle-timeout-s")?),
        faults,
    };
    let server = Server::bind(&opts)?;
    let state = server.state();
    let restored = state.registry.counts().done;
    println!(
        "repro serve listening on {} ({} workers, queue capacity {}, max conns {}, registry {}{})",
        server.local_addr()?,
        state.scheduler.worker_count(),
        opts.queue_capacity,
        opts.max_connections,
        match &opts.registry_dir {
            Some(d) => d.display().to_string(),
            None => "in-memory".to_string(),
        },
        if restored > 0 {
            format!(", {restored} runs restored")
        } else {
            String::new()
        }
    );
    if opts.rate_limit_per_sec > 0.0 {
        println!(
            "rate limit: {} submits/s per client (burst {})",
            opts.rate_limit_per_sec, opts.rate_limit_burst
        );
    }
    if !opts.faults.is_off() {
        println!("fault injection ACTIVE: {} (chaos mode — expect failures)", opts.faults);
    }
    println!("protocol: one JSON object per line; try {{\"op\":\"ping\"}} — see README.md");
    server.run()
}

fn cmd_trace(args: &Args) -> Result<()> {
    use mem_aop_gd::coordinator::config::KSchedule;
    use mem_aop_gd::coordinator::native_trainer::NativeTrainer;
    use mem_aop_gd::obs::ObsConfig;

    let task = Task::parse(args.get("task").unwrap_or("energy"))
        .ok_or_else(|| anyhow!("bad --task"))?;
    let mut cfg = ExperimentConfig::preset(task);
    cfg.policy = Policy::parse_or_suggest(args.get("policy").unwrap_or("topk"))
        .map_err(|e| anyhow!("--policy: {e}"))?;
    cfg.k = KSchedule::parse(args.get("k").unwrap_or("18")).map_err(|e| anyhow!("--k: {e}"))?;
    if cfg.policy == Policy::Exact {
        cfg.k = KSchedule::constant(cfg.m());
        cfg.memory = false;
    }
    let epochs: usize = args.get_parse("epochs")?;
    if epochs > 0 {
        cfg.epochs = epochs;
    }
    cfg.seed = args.get_parse("seed")?;
    cfg.threads = args.get_parse("threads")?;
    cfg.data_scale = args.get_parse("data-scale")?;
    cfg.backend = Backend::Native;
    cfg.validate()?;

    let events: usize = args.get_parse("events")?;
    let out = std::path::PathBuf::from(args.get("out").unwrap_or("results/trace.json"));

    // Keep the trainer after the run: the event ring and histograms live
    // in its workspace, and `run_with_trainer_ref` borrows instead of
    // consuming exactly so post-run telemetry can be dumped here.
    let mut trainer = NativeTrainer::new(&cfg)?;
    trainer.set_obs(ObsConfig::with_trace_capacity(events));
    let r = experiment::run_with_trainer_ref(&cfg, &mut trainer, &mut |_| true)?;

    let tele = trainer.telemetry();
    let rollup = tele.rollup();
    let mut rows = Vec::new();
    for ps in &rollup.phases {
        if ps.count == 0 {
            continue;
        }
        rows.push(vec![
            ps.phase.name().to_string(),
            format!("{}", ps.count),
            fmt_ns(ps.total_ns),
            fmt_ns(ps.p50_ns),
            fmt_ns(ps.p99_ns),
        ]);
    }
    println!(
        "traced {} steps ({} / {}, K={}/{}, {} epochs, threads={})",
        rollup.steps,
        cfg.task.name(),
        cfg.label(),
        cfg.k.name(),
        cfg.m(),
        cfg.epochs,
        cfg.threads
    );
    print_table(&["phase", "count", "total", "p50", "p99"], &rows);
    let mut lrows = Vec::new();
    for (i, ls) in rollup.layers.iter().enumerate() {
        lrows.push(vec![
            format!("{i}"),
            format!("{}", ls.k_sum),
            format!("{:.3e}", ls.backward_flops as f64),
        ]);
    }
    print_table(&["layer", "K realized", "bwd FLOPs"], &lrows);

    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, tele.chrome_trace_json().dump())?;
    let ring = tele.trace();
    println!(
        "wrote {} trace events ({} recorded, ring capacity {}) to {} — open in \
         chrome://tracing or Perfetto",
        ring.total().min(ring.capacity() as u64),
        ring.total(),
        ring.capacity(),
        out.display()
    );
    println!("final val loss {:.6}", r.final_val_loss());
    Ok(())
}

fn cmd_audit(args: &Args) -> Result<()> {
    use mem_aop_gd::coordinator::config::{self, KSchedule};

    let task = Task::parse(args.get("task").unwrap_or("energy"))
        .ok_or_else(|| anyhow!("bad --task"))?;
    let mut cfg = ExperimentConfig::preset(task);
    cfg.policy = Policy::parse_or_suggest(args.get("policy").unwrap_or("topk"))
        .map_err(|e| anyhow!("--policy: {e}"))?;
    cfg.k = KSchedule::parse(args.get("k").unwrap_or("18")).map_err(|e| anyhow!("--k: {e}"))?;
    if cfg.policy == Policy::Exact {
        cfg.k = KSchedule::constant(cfg.m());
        cfg.memory = false;
    }
    let epochs: usize = args.get_parse("epochs")?;
    if epochs > 0 {
        cfg.epochs = epochs;
    }
    cfg.seed = args.get_parse("seed")?;
    cfg.threads = args.get_parse("threads")?;
    cfg.data_scale = args.get_parse("data-scale")?;
    cfg.trace = mem_aop_gd::tensor::quant::TraceMode::parse_or_suggest(
        args.get("trace").unwrap_or("f32"),
    )
    .map_err(|e| anyhow!("--trace: {e}"))?;
    cfg.accum = mem_aop_gd::tensor::quant::AccumMode::parse_or_suggest(
        args.get("accum").unwrap_or("f32"),
    )
    .map_err(|e| anyhow!("--accum: {e}"))?;
    if args.flag("no-memory") {
        cfg.memory = false;
    }
    cfg.backend = Backend::Native;
    cfg.audit = Some(
        config::parse_audit(args.get("every").unwrap_or("every:1"))
            .map_err(|e| anyhow!("--every: {e}"))?,
    );
    cfg.validate()?;

    println!(
        "auditing {} / {} (K={}/{}, {} epochs, cadence every:{}, seed={}, threads={})",
        cfg.task.name(),
        cfg.label(),
        cfg.k.name(),
        cfg.m(),
        cfg.epochs,
        cfg.audit.unwrap(),
        cfg.seed,
        cfg.threads
    );
    let r = experiment::run(&cfg)?;
    print_audit_table(&r.curve.epochs);
    println!(
        "final val loss {:.6} (best {:.6})",
        r.final_val_loss(),
        r.curve.best_val_loss()
    );
    Ok(())
}

/// Per-layer fidelity table for every audited epoch in a curve. No-op
/// when the run carried no auditor (keeps `train` output unchanged for
/// audit-off runs).
fn print_audit_table(epochs: &[mem_aop_gd::metrics::EpochMetrics]) {
    let mut rows = Vec::new();
    for m in epochs {
        for a in &m.audit {
            rows.push(vec![
                format!("{}", m.epoch),
                format!("{}", a.layer),
                a.trace.name().to_string(),
                format!("{:.6}", a.cosine),
                format!("{:.3e}", a.rel_err),
                format!("{:.3e}", a.mem_bias),
            ]);
        }
    }
    if rows.is_empty() {
        return;
    }
    println!("\ngradient fidelity (exact same-batch gradient vs applied Mem-AOP update):");
    print_table(
        &["epoch", "layer", "trace", "cosine", "rel err", "mem bias"],
        &rows,
    );
}

/// Human-readable nanosecond duration for the rollup table.
fn fmt_ns(ns: u64) -> String {
    if ns < 10_000 {
        format!("{ns} ns")
    } else if ns < 10_000_000 {
        format!("{:.1} µs", ns as f64 / 1e3)
    } else if ns < 10_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

fn cmd_inspect() -> Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    println!("platform: {}", rt.platform());
    let stats = rt.load_all()?;
    let mut rows = Vec::new();
    for (name, st) in &stats {
        let spec = rt.manifest.artifact(name)?;
        rows.push(vec![
            name.clone(),
            format!("{}", spec.inputs.len()),
            format!("{}", spec.outputs.len()),
            format!("{:.1} ms", st.compile_ns as f64 / 1e6),
        ]);
    }
    print_table(&["artifact", "inputs", "outputs", "compile"], &rows);
    Ok(())
}
