//! End-to-end multi-layer MLP training through the monolithic AOT
//! artifacts (`mlp_exact`, `mlp_topk_mem`, ...).
//!
//! This is the extension beyond the paper's single-layer models: per-layer
//! Mem-AOP-GD inside one compiled train-step graph (selection baked
//! in-graph with the manifest's K), with the Rust coordinator supplying
//! data, per-layer uniform noise (for the stochastic policies), the
//! learning-rate schedule, and metric logging. Used by
//! `examples/e2e_train.rs` and the e2e integration tests.

// Clock reads are deliberate here (wall-clock run duration reporting) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::metrics::{EpochMetrics, RunCurve};
use crate::runtime::{ArgRef, Executable, Runtime};
use crate::tensor::{init, rng::Rng, Matrix};

/// Which compiled MLP variant to train.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MlpVariant {
    Exact,
    TopKMem,
    TopKNoMem,
    RandKMem,
    WeightedKMem,
}

impl MlpVariant {
    pub fn artifact(&self) -> &'static str {
        match self {
            MlpVariant::Exact => "mlp_exact",
            MlpVariant::TopKMem => "mlp_topk_mem",
            MlpVariant::TopKNoMem => "mlp_topk_nomem",
            MlpVariant::RandKMem => "mlp_randk_mem",
            MlpVariant::WeightedKMem => "mlp_weightedk_mem",
        }
    }

    pub fn parse(s: &str) -> Option<MlpVariant> {
        Some(match s {
            "exact" => MlpVariant::Exact,
            "topk-mem" | "topk_mem" => MlpVariant::TopKMem,
            "topk-nomem" | "topk_nomem" => MlpVariant::TopKNoMem,
            "randk-mem" | "randk_mem" => MlpVariant::RandKMem,
            "weightedk-mem" | "weightedk_mem" => MlpVariant::WeightedKMem,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            MlpVariant::Exact => "exact",
            MlpVariant::TopKMem => "topk-mem",
            MlpVariant::TopKNoMem => "topk-nomem",
            MlpVariant::RandKMem => "randk-mem",
            MlpVariant::WeightedKMem => "weightedk-mem",
        }
    }

    pub fn all() -> [MlpVariant; 5] {
        [
            MlpVariant::Exact,
            MlpVariant::TopKMem,
            MlpVariant::TopKNoMem,
            MlpVariant::RandKMem,
            MlpVariant::WeightedKMem,
        ]
    }
}

/// Host-side MLP training state driven through the monolithic artifact.
pub struct MlpDriver {
    step_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    pub layers: Vec<usize>,
    pub batch: usize,
    pub k: usize,
    ws: Vec<Matrix>,
    bs: Vec<Vec<f32>>,
    mxs: Vec<Matrix>,
    mgs: Vec<Matrix>,
    noise_rng: Rng,
    variant: MlpVariant,
}

/// One step's outputs.
#[derive(Debug, Clone, Copy)]
pub struct MlpStep {
    pub loss: f32,
    pub acc: f32,
}

impl MlpDriver {
    pub fn new(rt: &Runtime, variant: MlpVariant, seed: u64) -> Result<MlpDriver> {
        let meta = rt.manifest.mlp.clone();
        let nl = meta.layers.len() - 1;
        let mut wrng = Rng::new(seed ^ 0x317ED);
        let ws: Vec<Matrix> = (0..nl)
            .map(|i| init::glorot_uniform(&mut wrng, meta.layers[i], meta.layers[i + 1]))
            .collect();
        let bs: Vec<Vec<f32>> = (0..nl).map(|i| vec![0.0; meta.layers[i + 1]]).collect();
        let mxs: Vec<Matrix> = (0..nl)
            .map(|i| Matrix::zeros(meta.batch, meta.layers[i]))
            .collect();
        let mgs: Vec<Matrix> = (0..nl)
            .map(|i| Matrix::zeros(meta.batch, meta.layers[i + 1]))
            .collect();
        Ok(MlpDriver {
            step_exe: rt
                .load(variant.artifact())
                .with_context(|| format!("loading {}", variant.artifact()))?,
            eval_exe: rt.load("mlp_eval")?,
            layers: meta.layers,
            batch: meta.batch,
            k: meta.k,
            ws,
            bs,
            mxs,
            mgs,
            noise_rng: Rng::new(seed ^ 0x90153),
            variant,
        })
    }

    pub fn num_params(&self) -> usize {
        self.ws
            .iter()
            .map(|w| w.rows() * w.cols())
            .sum::<usize>()
            + self.bs.iter().map(|b| b.len()).sum::<usize>()
    }

    pub fn variant(&self) -> MlpVariant {
        self.variant
    }

    fn n_layers(&self) -> usize {
        self.ws.len()
    }

    /// One compiled train step on a batch.
    pub fn step(&mut self, x: &Matrix, y: &Matrix, eta: f32) -> Result<MlpStep> {
        let nl = self.n_layers();
        if x.rows() != self.batch {
            bail!("batch {} != compiled batch {}", x.rows(), self.batch);
        }
        let noises: Vec<Vec<f32>> = (0..nl)
            .map(|_| (0..self.batch).map(|_| self.noise_rng.uniform()).collect())
            .collect();
        let mut args: Vec<ArgRef<'_>> = Vec::with_capacity(2 + 5 * nl + 1);
        args.push(ArgRef::from(x));
        args.push(ArgRef::from(y));
        for w in &self.ws {
            args.push(ArgRef::from(w));
        }
        for b in &self.bs {
            args.push(ArgRef::from(b));
        }
        for m in &self.mxs {
            args.push(ArgRef::from(m));
        }
        for m in &self.mgs {
            args.push(ArgRef::from(m));
        }
        for n in &noises {
            args.push(ArgRef::from(n));
        }
        args.push(ArgRef::Scalar(eta));

        let out = self.step_exe.run_ref(&args)?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().as_scalar()?;
        let acc = it.next().unwrap().as_scalar()?;
        for w in self.ws.iter_mut() {
            *w = it.next().unwrap().into_matrix()?;
        }
        for b in self.bs.iter_mut() {
            *b = it.next().unwrap().into_vector()?;
        }
        for m in self.mxs.iter_mut() {
            *m = it.next().unwrap().into_matrix()?;
        }
        for m in self.mgs.iter_mut() {
            *m = it.next().unwrap().into_matrix()?;
        }
        Ok(MlpStep { loss, acc })
    }

    /// Chunked validation over the compiled eval artifact.
    pub fn evaluate(&self, val: &Dataset) -> Result<(f32, f32)> {
        let n_chunks = val.len() / self.batch;
        anyhow::ensure!(n_chunks > 0, "val set smaller than batch");
        let (mut loss, mut acc) = (0.0f64, 0.0f64);
        for c in 0..n_chunks {
            let idx: Vec<usize> = (c * self.batch..(c + 1) * self.batch).collect();
            let part = val.gather(&idx);
            let mut args: Vec<ArgRef<'_>> = vec![ArgRef::from(&part.x), ArgRef::from(&part.y)];
            for w in &self.ws {
                args.push(ArgRef::from(w));
            }
            for b in &self.bs {
                args.push(ArgRef::from(b));
            }
            let out = self.eval_exe.run_ref(&args)?;
            loss += out[0].as_scalar()? as f64;
            acc += out[1].as_scalar()? as f64;
        }
        Ok((
            (loss / n_chunks as f64) as f32,
            (acc / n_chunks as f64) as f32,
        ))
    }

    /// Memory mass across layers (0 for no-mem variants).
    pub fn mem_fro(&self) -> f32 {
        let sq: f32 = self
            .mxs
            .iter()
            .chain(self.mgs.iter())
            .map(|m| m.frobenius().powi(2))
            .sum();
        sq.sqrt()
    }
}

/// Train an MLP variant for `steps` steps over `train`, evaluating every
/// `eval_every` steps; returns the recorded curve (one entry per eval).
pub fn train_mlp(
    rt: &Runtime,
    variant: MlpVariant,
    train: &Dataset,
    val: &Dataset,
    steps: usize,
    eta: f32,
    eval_every: usize,
    seed: u64,
) -> Result<(MlpDriver, RunCurve)> {
    use crate::data::batcher::Batcher;
    use std::time::Instant;

    let mut driver = MlpDriver::new(rt, variant, seed)?;
    let mut batcher = Batcher::new(train.len(), driver.batch);
    let mut shuffle_rng = Rng::new(seed ^ 0x5A0FF);
    let mut curve = RunCurve::new(variant.label());
    let mut done = 0usize;
    let mut t0 = Instant::now();
    let mut loss_acc = 0.0f64;
    let mut loss_n = 0usize;
    'outer: loop {
        let batches = batcher.epoch_batches(train, &mut shuffle_rng);
        for b in &batches {
            let st = driver.step(&b.x, &b.y, eta)?;
            loss_acc += st.loss as f64;
            loss_n += 1;
            done += 1;
            if done % eval_every == 0 || done == steps {
                let (vl, va) = driver.evaluate(val)?;
                curve.push(EpochMetrics {
                    epoch: done,
                    train_loss: (loss_acc / loss_n as f64) as f32,
                    val_loss: vl,
                    val_acc: va,
                    wstar_fro: 0.0,
                    mem_fro: driver.mem_fro(),
                    backward_flops: 0,
                    rows_per_sec: 0.0, // HLO driver: not instrumented
                    wall_s: t0.elapsed().as_secs_f64(),
                    layers: Vec::new(), // in-graph selection: not observable
                    audit: Vec::new(),  // no auditor on the HLO path
                });
                t0 = Instant::now();
                loss_acc = 0.0;
                loss_n = 0;
            }
            if done >= steps {
                break 'outer;
            }
        }
    }
    Ok((driver, curve))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for v in MlpVariant::all() {
            assert_eq!(MlpVariant::parse(v.label()), Some(v));
        }
        assert!(MlpVariant::parse("bogus").is_none());
    }

    #[test]
    fn artifact_names_match_aot() {
        assert_eq!(MlpVariant::Exact.artifact(), "mlp_exact");
        assert_eq!(MlpVariant::WeightedKMem.artifact(), "mlp_weightedk_mem");
    }
}
