//! Experiment configuration and the paper's Tab. I presets.
//!
//! Beyond the paper's flat single-layer setup, a config may carry a
//! `layers` spec: a chain of dense layers (width + activation), each
//! with its own optional `{k, policy, memory}` override — heterogeneous
//! per-layer approximation budgets, resolved by
//! [`ExperimentConfig::layer_plan`] into per-layer [`ResolvedLayer`]s
//! and per epoch (via [`ResolvedLayer::cfg_at`]) into the `train`
//! core's [`AopLayerConfig`]s. A flat config (no `layers`) resolves to
//! a single identity-activation layer with the flat knobs — exactly the
//! historical behavior, preserved bit-for-bit.
//!
//! Every K is a [`KSchedule`] — the paper's outer-product budget as a
//! per-layer, per-epoch annealing knob (constants behave, serialize,
//! and train exactly like the historical plain integers).
//!
//! Protocol v7 adds the mixed-precision knobs: a flat `trace`/`accum`
//! pair plus an optional per-layer trace override in the layer grammar
//! (`w[:act[:ksched[:trace]]]`), resolved with f32 pins for the head
//! layer and exact-policy inputs. All-f32 configs serialize without
//! the new keys — pre-v7 frames and run files keep their exact shape.

use anyhow::{anyhow, bail, Result};

use crate::aop::Policy;
use crate::model::activations::Activation;
use crate::model::LossKind;
use crate::tensor::quant::{AccumMode, LayerPrecision, TraceMode};
use crate::train::AopLayerConfig;
use crate::util::json::{self, Json};

/// Which of the paper's two workloads (plus dataset substitution scale).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Building-energy regression (16 → 1, MSE). Tab. I column 1.
    Energy,
    /// Digit classification (784 → 10 + softmax, CCE). Tab. I column 2.
    Mnist,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        Some(match s {
            "energy" => Task::Energy,
            "mnist" => Task::Mnist,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Energy => "energy",
            Task::Mnist => "mnist",
        }
    }

    pub fn loss(&self) -> LossKind {
        match self {
            Task::Energy => LossKind::Mse,
            Task::Mnist => LossKind::SoftmaxCrossEntropy,
        }
    }

    /// (n_in, n_out) of the paper's single dense layer.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Task::Energy => (16, 1),
            Task::Mnist => (784, 10),
        }
    }

    /// Tab. I mini-batch size — this is the paper's M (outer products per
    /// update).
    pub fn batch(&self) -> usize {
        match self {
            Task::Energy => 144,
            Task::Mnist => 64,
        }
    }

    /// Tab. I epochs.
    pub fn epochs(&self) -> usize {
        match self {
            Task::Energy => 100,
            Task::Mnist => 30,
        }
    }

    /// The K sweep of Figs. 2/3.
    pub fn figure_ks(&self) -> [usize; 3] {
        match self {
            Task::Energy => [18, 9, 3],
            Task::Mnist => [32, 16, 8],
        }
    }

    /// Validation batch used by the `*_eval` artifacts.
    pub fn eval_batch(&self) -> usize {
        match self {
            Task::Energy => 192, // the whole Tab. I validation split
            Task::Mnist => 64,
        }
    }
}

/// Execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust reference implementation (oracle / comparator).
    Native,
    /// AOT HLO artifacts executed via PJRT (the production path).
    Hlo,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "native" => Backend::Native,
            "hlo" | "pjrt" => Backend::Hlo,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Hlo => "hlo",
        }
    }
}

// ---------------------------------------------------------------------
// Schedule parameter validation, shared by LrSchedule and KSchedule so
// the two grammars can never drift on what counts as degenerate. All
// checks run at parse time (a bad spec is rejected with a clear error
// before anything trains) and again in `validate()` for structs built
// programmatically.
// ---------------------------------------------------------------------

/// A step-decay period must advance: `step:0:<γ>` would decay at every
/// epoch only by grace of a use-site `max(1)` guard.
fn check_every(every: usize) -> Result<()> {
    if every == 0 {
        bail!("step period must be >= 1 (got 0)");
    }
    Ok(())
}

/// A decay factor outside (0, 1] either grows the quantity it is meant
/// to anneal or zeroes/negates it.
fn check_gamma(gamma: f32) -> Result<()> {
    if !(gamma.is_finite() && gamma > 0.0 && gamma <= 1.0) {
        bail!("decay gamma {gamma} out of (0, 1]");
    }
    Ok(())
}

/// A cosine floor fraction must be a fraction.
fn check_frac(min_frac: f32) -> Result<()> {
    if !(min_frac.is_finite() && (0.0..=1.0).contains(&min_frac)) {
        bail!("min_frac {min_frac} out of [0, 1]");
    }
    Ok(())
}

/// 1-based epoch with an out-of-contract zero saturated — the epoch-0
/// totality fix, defined once for every schedule resolver.
fn sched_epoch(epoch: usize) -> usize {
    epoch.max(1)
}

/// Completed decay periods at `epoch` — the shared step-decay exponent
/// (integer, so the lr and K grammars cannot drift on it). Clamped to
/// the run like [`run_frac`], so epochs beyond `total_epochs` hold the
/// final value instead of decaying forever.
fn decay_steps(epoch: usize, every: usize, total_epochs: usize) -> i32 {
    let e = sched_epoch(epoch).min(total_epochs.max(1));
    ((e - 1) / every.max(1)) as i32
}

/// Fraction of the run completed at a 1-based epoch, clamped to `[0, 1]`
/// so epochs beyond the run hold the schedule's final value — THE
/// definition of schedule time shared by the lr and K grammars.
fn run_frac(epoch: usize, total_epochs: usize) -> f64 {
    (((sched_epoch(epoch) - 1) as f64) / ((total_epochs.max(2) - 1) as f64)).min(1.0)
}

/// The one K-vs-M range rule, shared by the flat and per-layer checks in
/// [`ExperimentConfig::validate`]: constants keep the historical strict
/// `1..=M`; annealed shapes may clamp partially during the run, but a
/// schedule above M at *every* realized epoch would silently train as
/// constant K=M and is rejected like an oversized constant.
fn check_k_range(k: &KSchedule, m: usize, epochs: usize, ctx: &str) -> Result<()> {
    if let KSchedule::Constant(kc) = *k {
        if kc == 0 || kc > m {
            bail!("{ctx}k={kc} out of range 1..={m}");
        }
    } else if k.min_k(epochs) > m {
        bail!(
            "{ctx}k schedule '{}' exceeds M={m} at every epoch (it would clamp to a constant)",
            k.name()
        );
    }
    Ok(())
}

/// Learning-rate schedule (extension beyond the paper's constant η; the
/// algorithm natively supports time-varying η_t — it enters the memory
/// folding as √η_t — and the HLO artifacts take η as a runtime input, so
/// schedules need no recompilation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// η_t = lr (the paper's setting).
    Constant,
    /// η_t = lr · gamma^(epoch / every)   (integer division).
    StepDecay { every: usize, gamma: f32 },
    /// Cosine anneal from lr to lr·min_frac over the run.
    Cosine { min_frac: f32 },
}

impl LrSchedule {
    /// η for a 1-based epoch index. Total: an out-of-contract `epoch = 0`
    /// saturates to epoch 1 instead of underflowing the `usize`
    /// subtraction (a panic in debug builds, a 2^64-epoch decay in
    /// release — both wrong).
    pub fn lr_at(&self, base: f32, epoch: usize, total_epochs: usize) -> f32 {
        match *self {
            LrSchedule::Constant => base,
            LrSchedule::StepDecay { every, gamma } => {
                base * gamma.powi(decay_steps(epoch, every, total_epochs))
            }
            LrSchedule::Cosine { min_frac } => {
                let t = run_frac(epoch, total_epochs) as f32;
                let floor = base * min_frac;
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// Parse a schedule spec, rejecting degenerate parameters (zero step
    /// period, gamma outside (0, 1], min_frac outside [0, 1]) at parse
    /// time — a bad spec must error, not silently train nonsense.
    pub fn parse(s: &str) -> Result<LrSchedule> {
        let t = s.trim();
        if t == "constant" {
            return Ok(LrSchedule::Constant);
        }
        if let Some(rest) = t.strip_prefix("step:") {
            // step:<every>:<gamma>
            let mut it = rest.split(':');
            let every = it
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("schedule '{s}': bad step period"))?;
            let gamma = it
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("schedule '{s}': bad gamma"))?;
            if let Some(extra) = it.next() {
                bail!("schedule '{s}': unexpected trailing ':{extra}'");
            }
            let sch = LrSchedule::StepDecay { every, gamma };
            sch.validate().map_err(|e| anyhow!("schedule '{s}': {e}"))?;
            return Ok(sch);
        }
        if let Some(rest) = t.strip_prefix("cosine:") {
            let min_frac = rest
                .parse()
                .map_err(|_| anyhow!("schedule '{s}': bad min_frac"))?;
            let sch = LrSchedule::Cosine { min_frac };
            sch.validate().map_err(|e| anyhow!("schedule '{s}': {e}"))?;
            return Ok(sch);
        }
        bail!("unknown schedule '{s}' (expected constant | step:<every>:<gamma> | cosine:<min-frac>)")
    }

    /// Parameter validity (the parse-time checks, re-runnable on structs
    /// built in code — `ExperimentConfig::validate` calls this).
    pub fn validate(&self) -> Result<()> {
        match *self {
            LrSchedule::Constant => Ok(()),
            LrSchedule::StepDecay { every, gamma } => {
                check_every(every)?;
                check_gamma(gamma)
            }
            LrSchedule::Cosine { min_frac } => check_frac(min_frac),
        }
    }

    pub fn name(&self) -> String {
        match self {
            LrSchedule::Constant => "constant".into(),
            LrSchedule::StepDecay { every, gamma } => format!("step:{every}:{gamma}"),
            LrSchedule::Cosine { min_frac } => format!("cosine:{min_frac}"),
        }
    }
}

/// Per-epoch outer-product budget schedule — the paper's K as a
/// first-class, annealable knob (ROADMAP: per-layer K schedules).
///
/// Related work motivates both directions: approximation error is most
/// tolerable early in training (grow K with `linear`), and sampling
/// budgets trade compute for curve fidelity non-uniformly over training
/// (shrink K with `step`/`cosine`). The spec grammar:
///
/// * `<k>` — constant budget (the paper's setting; serializes as a plain
///   number, so flat constant configs stay bit-for-bit wire-identical);
/// * `step:<k0>:<every>:<gamma>` — start at k0, multiply by gamma every
///   `every` epochs (rounded);
/// * `cosine:<k0>:<min-frac>` — cosine-anneal from k0 down to
///   k0·min_frac over the run;
/// * `linear:<from>:<to>` — linear from `from` (epoch 1) to `to` (last
///   epoch), either direction.
///
/// Resolution ([`KSchedule::k_at`]) is per 1-based epoch and always
/// clamps to `[1, batch]` — an annealed budget can approach but never
/// exceed the paper's M or hit zero. Parameters are validated at parse
/// time with the same shared checks as [`LrSchedule`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KSchedule {
    /// k_t = k.
    Constant(usize),
    /// k_t = round(k0 · gamma^((epoch-1)/every)).
    Step { k0: usize, every: usize, gamma: f32 },
    /// Cosine anneal from k0 to round(k0 · min_frac) over the run.
    Cosine { k0: usize, min_frac: f32 },
    /// Linear from `from` at epoch 1 to `to` at the last epoch.
    Linear { from: usize, to: usize },
}

impl KSchedule {
    /// The constant schedule — the historical `k: usize` in type form.
    pub fn constant(k: usize) -> KSchedule {
        KSchedule::Constant(k)
    }

    /// The largest budget any epoch can resolve to (before the batch
    /// clamp) — what workspace-style consumers size for. Monotone decay
    /// (step/cosine with gamma, min_frac ≤ 1) peaks at epoch 1; linear
    /// peaks at whichever endpoint is larger.
    pub fn max_k(&self) -> usize {
        match *self {
            KSchedule::Constant(k) => k,
            KSchedule::Step { k0, .. } | KSchedule::Cosine { k0, .. } => k0,
            KSchedule::Linear { from, to } => from.max(to),
        }
    }

    /// Concrete K for a 1-based epoch, clamped to `[1, batch]`. Total on
    /// out-of-contract inputs: `epoch = 0` saturates to epoch 1 and
    /// epochs beyond `total_epochs` hold the schedule's final value.
    pub fn k_at(&self, epoch: usize, total_epochs: usize, batch: usize) -> usize {
        // schedule time and decay exponents come from the same shared
        // helpers as LrSchedule::lr_at, so the two grammars cannot drift
        // on saturation or extrapolation semantics
        let t = run_frac(epoch, total_epochs);
        let raw = match *self {
            KSchedule::Constant(k) => k as f64,
            KSchedule::Step { k0, every, gamma } => {
                k0 as f64 * (gamma as f64).powi(decay_steps(epoch, every, total_epochs))
            }
            KSchedule::Cosine { k0, min_frac } => {
                let floor = k0 as f64 * min_frac as f64;
                floor + 0.5 * (k0 as f64 - floor) * (1.0 + (std::f64::consts::PI * t).cos())
            }
            KSchedule::Linear { from, to } => from as f64 + (to as f64 - from as f64) * t,
        };
        (raw.round() as usize).clamp(1, batch.max(1))
    }

    /// The smallest budget any epoch of a `total_epochs`-long run can
    /// resolve to, before the batch clamp: monotone-decay shapes bottom
    /// out at the last epoch, linear at its smaller endpoint. Lets
    /// `ExperimentConfig::validate` reject schedules that would clamp at
    /// *every* epoch (almost certainly a typo) while still allowing
    /// intentional partial clamping.
    pub fn min_k(&self, total_epochs: usize) -> usize {
        match *self {
            KSchedule::Constant(k) => k,
            KSchedule::Step { .. } | KSchedule::Cosine { .. } => {
                self.k_at(total_epochs, total_epochs, usize::MAX)
            }
            KSchedule::Linear { from, to } => {
                // a 1-epoch run only ever resolves epoch 1 = `from`; the
                // `to` endpoint is unreachable and must not mask an
                // out-of-range start
                if total_epochs <= 1 {
                    from
                } else {
                    from.min(to)
                }
            }
        }
    }

    /// Parse a K-schedule spec (see the type docs for the grammar),
    /// rejecting degenerate parameters at parse time with the same
    /// shared checks as [`LrSchedule::parse`].
    pub fn parse(s: &str) -> Result<KSchedule> {
        let t = s.trim();
        let int = |v: &str, what: &str| -> Result<usize> {
            let k: usize = v
                .parse()
                .map_err(|_| anyhow!("k schedule '{s}': bad {what} '{v}'"))?;
            if k == 0 {
                bail!("k schedule '{s}': {what} must be >= 1");
            }
            Ok(k)
        };
        if t.chars().all(|c| c.is_ascii_digit()) && !t.is_empty() {
            return Ok(KSchedule::Constant(int(t, "k")?));
        }
        let (kind, rest) = match t.split_once(':') {
            Some(pair) => pair,
            None => bail!(
                "bad k schedule '{s}' (expected <k> | step:<k0>:<every>:<gamma> | \
                 cosine:<k0>:<min-frac> | linear:<from>:<to>)"
            ),
        };
        let mut it = rest.split(':');
        let sch = match kind {
            "constant" => KSchedule::Constant(int(rest, "k")?),
            "step" => {
                let k0 = int(it.next().unwrap_or(""), "k0")?;
                let every = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow!("k schedule '{s}': bad step period"))?;
                let gamma = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow!("k schedule '{s}': bad gamma"))?;
                KSchedule::Step { k0, every, gamma }
            }
            "cosine" => {
                let k0 = int(it.next().unwrap_or(""), "k0")?;
                let min_frac = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow!("k schedule '{s}': bad min_frac"))?;
                KSchedule::Cosine { k0, min_frac }
            }
            "linear" => {
                let from = int(it.next().unwrap_or(""), "from")?;
                let to = int(it.next().unwrap_or(""), "to")?;
                KSchedule::Linear { from, to }
            }
            other => bail!(
                "unknown k schedule kind '{other}' in '{s}' (expected <k> | \
                 step:<k0>:<every>:<gamma> | cosine:<k0>:<min-frac> | linear:<from>:<to>)"
            ),
        };
        if !matches!(sch, KSchedule::Constant(_)) {
            if let Some(extra) = it.next() {
                bail!("k schedule '{s}': unexpected trailing ':{extra}'");
            }
        }
        sch.validate().map_err(|e| anyhow!("k schedule '{s}': {e}"))?;
        Ok(sch)
    }

    /// Parameter validity (shared checks with [`LrSchedule`]); range
    /// against a batch size is the caller's concern
    /// (`ExperimentConfig::validate` pins constants to `1..=M`, annealed
    /// shapes rely on the resolve-time clamp).
    pub fn validate(&self) -> Result<()> {
        match *self {
            KSchedule::Constant(k) => {
                if k == 0 {
                    bail!("k must be >= 1");
                }
                Ok(())
            }
            KSchedule::Step { k0, every, gamma } => {
                if k0 == 0 {
                    bail!("k0 must be >= 1");
                }
                check_every(every)?;
                check_gamma(gamma)
            }
            KSchedule::Cosine { k0, min_frac } => {
                if k0 == 0 {
                    bail!("k0 must be >= 1");
                }
                check_frac(min_frac)
            }
            KSchedule::Linear { from, to } => {
                if from == 0 || to == 0 {
                    bail!("linear endpoints must be >= 1");
                }
                Ok(())
            }
        }
    }

    /// Canonical spec string; constants print as the bare integer.
    pub fn name(&self) -> String {
        match *self {
            KSchedule::Constant(k) => k.to_string(),
            KSchedule::Step { k0, every, gamma } => format!("step:{k0}:{every}:{gamma}"),
            KSchedule::Cosine { k0, min_frac } => format!("cosine:{k0}:{min_frac}"),
            KSchedule::Linear { from, to } => format!("linear:{from}:{to}"),
        }
    }

    /// Wire form (protocol v4): constants stay plain numbers — exactly
    /// the v1-v3 frame shape — and annealed schedules go as spec strings.
    pub fn to_json(&self) -> Json {
        match *self {
            KSchedule::Constant(k) => json::num(k as f64),
            _ => json::s(&self.name()),
        }
    }

    /// Inverse of [`KSchedule::to_json`]: accepts a number (v1-v3 frames
    /// and constant schedules) or a spec string.
    pub fn from_json(v: &Json) -> Result<KSchedule> {
        if let Some(k) = v.as_usize() {
            if k == 0 {
                bail!("k must be >= 1");
            }
            return Ok(KSchedule::Constant(k));
        }
        if let Some(s) = v.as_str() {
            return KSchedule::parse(s);
        }
        bail!("k must be an integer or a schedule string")
    }
}

/// One layer of a `layers` spec: output width, activation, and optional
/// per-layer Mem-AOP-GD overrides (absent fields fall back to the flat
/// config's `k`/`policy`/`memory`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// Output width of this layer. The last layer's width must equal the
    /// task's output dim.
    pub width: usize,
    /// Elementwise activation; `None` resolves positionally (relu for
    /// hidden layers, identity for the head).
    pub activation: Option<Activation>,
    /// Per-layer K-schedule override (constants stay ≤ M; annealed
    /// shapes clamp per epoch).
    pub k: Option<KSchedule>,
    /// Per-layer selection-policy override.
    pub policy: Option<Policy>,
    /// Per-layer memory override.
    pub memory: Option<bool>,
    /// Per-layer forward-trace storage override (§Mixed precision):
    /// how this layer's *output* activations are stored for the
    /// backward pass. Absent falls back to the flat config's `trace`;
    /// the head layer and traces feeding an exact-policy layer are
    /// pinned to f32 at resolution regardless of the request.
    pub trace: Option<TraceMode>,
}

impl LayerSpec {
    /// A bare layer: width only, everything else inherited.
    pub fn plain(width: usize) -> LayerSpec {
        LayerSpec {
            width,
            activation: None,
            k: None,
            policy: None,
            memory: None,
            trace: None,
        }
    }

    /// Parse one CLI layer item `width[:activation[:ksched[:trace]]]`,
    /// e.g. `32`, `32:relu`, `32:tanh:16`, `32:relu:linear:8:32`,
    /// `4096:relu:32:bf16` — everything after the second `:` is one
    /// [`KSchedule`] spec (schedules contain `:` themselves), except
    /// that a *recognized* trailing trace token (`f32`/`bf16`/`q8`) is
    /// split off first. The trace token is unambiguous: no valid
    /// K-schedule segment spells a trace mode, and `32:relu:q8` (trace
    /// override with an inherited K) parses because a bare trace token
    /// is accepted where a K-schedule would be.
    pub fn parse(s: &str) -> Result<LayerSpec> {
        let mut it = s.trim().splitn(3, ':');
        let width: usize = it
            .next()
            .filter(|w| !w.is_empty())
            .and_then(|w| w.parse().ok())
            .ok_or_else(|| {
                anyhow!("layer '{s}': expected width[:activation[:ksched[:trace]]]")
            })?;
        let activation = match it.next() {
            None | Some("") => None,
            Some(a) => Some(
                Activation::parse(a)
                    .ok_or_else(|| anyhow!("layer '{s}': unknown activation '{a}'"))?,
            ),
        };
        let (k, trace) = match it.next() {
            None | Some("") => (None, None),
            // the whole tail is a bare trace token: trace-only override
            Some(tail) if TraceMode::parse(tail).is_some() => {
                (None, Some(TraceMode::parse(tail).unwrap()))
            }
            Some(tail) => {
                // split a recognized `:trace` suffix off the K-schedule
                let (kv, trace) = match tail.rsplit_once(':') {
                    Some((head, last)) if TraceMode::parse(last).is_some() => {
                        (head, Some(TraceMode::parse(last).unwrap()))
                    }
                    _ => (tail, None),
                };
                let k = KSchedule::parse(kv).map_err(|e| anyhow!("layer '{s}': {e}"))?;
                (Some(k), trace)
            }
        };
        Ok(LayerSpec {
            width,
            activation,
            k,
            policy: None,
            memory: None,
            trace,
        })
    }

    /// Parse a comma-separated CLI list, e.g. `"32:relu,10"`. Empty
    /// segments (stray `,,` or a trailing comma) are errors, not silently
    /// dropped — a typo must not train a different network.
    pub fn parse_list(s: &str) -> Result<Vec<LayerSpec>> {
        s.split(',').map(LayerSpec::parse).collect()
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![("width", json::num(self.width as f64))];
        if let Some(a) = self.activation {
            pairs.push(("activation", json::s(a.name())));
        }
        if let Some(k) = self.k {
            // constants stay numbers (v3-shaped frames), schedules are
            // spec strings (protocol v4)
            pairs.push(("k", k.to_json()));
        }
        if let Some(p) = self.policy {
            pairs.push(("policy", json::s(p.name())));
        }
        if let Some(m) = self.memory {
            pairs.push(("memory", Json::Bool(m)));
        }
        if let Some(t) = self.trace {
            // emitted only when overridden, so pre-v7 frames keep shape
            pairs.push(("trace", json::s(t.name())));
        }
        json::obj(pairs)
    }

    fn from_json(v: &Json, i: usize) -> Result<LayerSpec> {
        let width = v
            .get("width")
            .and_then(|n| n.as_usize())
            .ok_or_else(|| anyhow!("layers[{i}]: missing integer 'width'"))?;
        let activation = match v.get("activation").and_then(|a| a.as_str()) {
            Some(a) => Some(
                Activation::parse(a)
                    .ok_or_else(|| anyhow!("layers[{i}]: unknown activation '{a}'"))?,
            ),
            None => None,
        };
        let k = match v.get("k") {
            Some(n) => Some(
                KSchedule::from_json(n).map_err(|e| anyhow!("layers[{i}]: {e}"))?,
            ),
            None => None,
        };
        let policy = match v.get("policy").and_then(|p| p.as_str()) {
            Some(p) => {
                Some(Policy::parse_or_suggest(p).map_err(|e| anyhow!("layers[{i}]: {e}"))?)
            }
            None => None,
        };
        let memory = match v.get("memory") {
            Some(b) => Some(
                b.as_bool()
                    .ok_or_else(|| anyhow!("layers[{i}]: bad memory"))?,
            ),
            None => None,
        };
        let trace = match v.get("trace").and_then(|t| t.as_str()) {
            Some(t) => Some(
                TraceMode::parse_or_suggest(t).map_err(|e| anyhow!("layers[{i}]: {e}"))?,
            ),
            None => None,
        };
        Ok(LayerSpec {
            width,
            activation,
            k,
            policy,
            memory,
            trace,
        })
    }
}

/// One fully-resolved layer of a run: dims, activation, and the
/// effective per-layer Mem-AOP-GD knobs — with K as a [`KSchedule`]
/// resolved to a concrete budget per epoch by [`ResolvedLayer::cfg_at`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolvedLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    pub activation: Activation,
    /// Per-epoch outer-product budget at this layer.
    pub k: KSchedule,
    pub policy: Policy,
    pub memory: bool,
    /// Effective forward-trace storage for this layer's output
    /// activations (§Mixed precision) — the requested mode after the
    /// resolution pins: the head layer's output (loss-head input) and
    /// any trace feeding an exact-policy layer stay `F32` so exact
    /// means bit-exact.
    pub trace: TraceMode,
    /// Effective accumulation width for this layer's backward
    /// reductions (flat knob, uniform across layers).
    pub accum: AccumMode,
}

impl ResolvedLayer {
    /// The concrete train-core config for a 1-based epoch: the schedule
    /// resolved and clamped to `[1, batch]`. Constant schedules yield
    /// the same config at every epoch — the historical behavior,
    /// bit-for-bit.
    pub fn cfg_at(&self, epoch: usize, total_epochs: usize, batch: usize) -> AopLayerConfig {
        AopLayerConfig {
            k: self.k.k_at(epoch, total_epochs, batch),
            policy: self.policy,
            memory: self.memory,
        }
    }

    /// The workspace-facing precision pair for this layer.
    pub fn precision(&self) -> LayerPrecision {
        LayerPrecision {
            trace: self.trace,
            accum: self.accum,
        }
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub task: Task,
    pub policy: Policy,
    /// Outer products kept per update, as a per-epoch schedule (resolved
    /// values clamp to `[1, M]`; constants must sit in `1..=M`). Ignored
    /// by `Exact`.
    pub k: KSchedule,
    /// Error-feedback memory on/off (continuous vs dashed curves).
    pub memory: bool,
    pub epochs: usize,
    pub lr: f32,
    /// Per-epoch η schedule (Constant reproduces the paper).
    pub schedule: LrSchedule,
    pub seed: u64,
    pub backend: Backend,
    /// Fraction of the Tab. I dataset size to generate (1.0 = paper
    /// scale). Only affects mnist (60k/10k is expensive on CPU).
    pub data_scale: f32,
    /// Data-parallel execution threads for the native backend (the
    /// `exec` subsystem). Deterministic: every value produces
    /// bit-identical curves and weights; it only changes wall-clock. The
    /// serve scheduler accounts `threads` pool slots per job.
    pub threads: usize,
    /// Optional layer-graph spec (protocol v3). `None` = the paper's
    /// flat single dense layer with the flat `k`/`policy`/`memory` —
    /// the historical behavior. `Some` = a chain of dense layers ending
    /// at the task's output width, each optionally overriding the flat
    /// selection knobs (native backend only).
    pub layers: Option<Vec<LayerSpec>>,
    /// Forward-trace storage precision for backward-pass activations
    /// (§Mixed precision, protocol v7): `F32` reproduces the historical
    /// bit-exact path; `Bf16`/`Q8` store the traces compressed (2×/~4×
    /// less backward memory traffic), dequantized block-wise inside the
    /// shard kernels. Per-layer `LayerSpec::trace` overrides this;
    /// the head layer and exact-policy inputs are pinned to f32 at
    /// resolution. Native backend only.
    pub trace: TraceMode,
    /// Accumulation width for backward reductions (score dots, bias
    /// column sums, cross-shard gradient reduction): `F32` is the
    /// historical bit-exact path; `F64`/`Kahan` widen or compensate the
    /// persistent accumulator chains in the same 8-lane kernel shape.
    /// Native backend only.
    pub accum: AccumMode,
    /// Gradient-fidelity audit cadence in epochs (protocol v6, the
    /// `every:<n>` grammar on the wire): `Some(n)` audits epoch 1 and
    /// then every `n`-th epoch after it, re-reducing the last step's
    /// mini-batch exactly (K=M, memory folded) and recording per-layer
    /// cosine/relative-error/memory-bias. Strictly observation-only —
    /// auditing never changes a curve (native backend; the HLO path
    /// reports nothing).
    pub audit: Option<usize>,
    /// Per-job wall-clock budget in seconds (protocol v8). `Some(s)`
    /// lets the serve tier finalize a run exceeding `s` seconds as
    /// `failed: timeout` at the next epoch boundary instead of letting
    /// it occupy worker slots indefinitely; `None` (the default) keeps
    /// the historical unlimited behavior. Purely a lifecycle bound —
    /// it is checked *between* epochs and never alters the math of the
    /// epochs that do run.
    pub timeout_s: Option<f64>,
}

/// Upper bound on [`ExperimentConfig::threads`] (sanity cap, far above
/// any useful parallelism for the paper's shapes).
pub const MAX_THREADS: usize = 256;

impl ExperimentConfig {
    /// Tab. I column 1: energy regression baseline configuration.
    pub fn energy_preset() -> Self {
        ExperimentConfig {
            task: Task::Energy,
            policy: Policy::Exact,
            k: KSchedule::Constant(144),
            memory: false,
            epochs: Task::Energy.epochs(),
            lr: 0.01,
            schedule: LrSchedule::Constant,
            seed: 0,
            backend: Backend::Native,
            data_scale: 1.0,
            threads: 1,
            layers: None,
            trace: TraceMode::F32,
            accum: AccumMode::F32,
            audit: None,
            timeout_s: None,
        }
    }

    /// Tab. I column 2: mnist classification baseline configuration.
    pub fn mnist_preset() -> Self {
        ExperimentConfig {
            task: Task::Mnist,
            policy: Policy::Exact,
            k: KSchedule::Constant(64),
            memory: false,
            epochs: Task::Mnist.epochs(),
            lr: 0.01,
            schedule: LrSchedule::Constant,
            seed: 0,
            backend: Backend::Native,
            data_scale: 1.0,
            threads: 1,
            layers: None,
            trace: TraceMode::F32,
            accum: AccumMode::F32,
            audit: None,
            timeout_s: None,
        }
    }

    /// Preset for a task name.
    pub fn preset(task: Task) -> Self {
        match task {
            Task::Energy => Self::energy_preset(),
            Task::Mnist => Self::mnist_preset(),
        }
    }

    /// Series label in the paper's legend vocabulary, e.g. `baseline`,
    /// `topk-mem`, `randk-nomem`.
    pub fn label(&self) -> String {
        if self.policy == Policy::Exact {
            "baseline".to_string()
        } else {
            format!(
                "{}-{}",
                self.policy.name(),
                if self.memory { "mem" } else { "nomem" }
            )
        }
    }

    /// M = mini-batch size (Tab. I).
    pub fn m(&self) -> usize {
        self.task.batch()
    }

    /// Resolve the run's layer graph: dims, activation, and the
    /// effective `{k, policy, memory}` per layer. A flat config (no
    /// `layers`) is one identity-activation dense layer with the flat
    /// knobs; a `layers` spec chains `n_in → widths... → n_out` with
    /// positional activation defaults (relu hidden, identity head) and
    /// per-layer overrides falling back to the flat values.
    pub fn layer_plan(&self) -> Vec<ResolvedLayer> {
        let (n_in, n_out) = self.task.dims();
        let Some(specs) = &self.layers else {
            // a flat single layer IS the head: its output feeds the loss
            // head directly, so its trace is always pinned f32 (the
            // backward input is the raw f32 batch — nothing to compress)
            return vec![ResolvedLayer {
                fan_in: n_in,
                fan_out: n_out,
                activation: Activation::Identity,
                k: self.k,
                policy: self.policy,
                memory: self.memory,
                trace: TraceMode::F32,
                accum: self.accum,
            }];
        };
        let nl = specs.len();
        // policies resolved up front: layer i's stored trace feeds the
        // X̂ fold of layer i+1's backward, so an exact-policy consumer
        // pins its *input* trace (layer i's output) to f32 — `exact`
        // must keep meaning bit-exact K=M
        let policies: Vec<Policy> = specs
            .iter()
            .map(|s| s.policy.unwrap_or(self.policy))
            .collect();
        let mut fan_in = n_in;
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let last = i + 1 == nl;
                let pinned = last || policies[i + 1] == Policy::Exact;
                let rl = ResolvedLayer {
                    fan_in,
                    fan_out: s.width,
                    activation: s.activation.unwrap_or(if last {
                        Activation::Identity
                    } else {
                        Activation::Relu
                    }),
                    k: s.k.unwrap_or(self.k),
                    policy: policies[i],
                    memory: s.memory.unwrap_or(self.memory),
                    trace: if pinned {
                        TraceMode::F32
                    } else {
                        s.trace.unwrap_or(self.trace)
                    },
                    accum: self.accum,
                };
                fan_in = s.width;
                rl
            })
            .collect()
    }

    /// The per-layer workspace precision pairs of [`Self::layer_plan`] —
    /// what `GraphWorkspace::set_precision` takes.
    pub fn precision_plan(&self) -> Vec<LayerPrecision> {
        self.layer_plan().iter().map(|rl| rl.precision()).collect()
    }

    /// `(fan_in, fan_out)` of every resolved layer.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        self.layer_plan()
            .iter()
            .map(|rl| (rl.fan_in, rl.fan_out))
            .collect()
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        self.k.validate().map_err(|e| anyhow!("k: {e}"))?;
        check_k_range(&self.k, self.m(), self.epochs, "")?;
        self.schedule
            .validate()
            .map_err(|e| anyhow!("schedule: {e}"))?;
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("bad learning rate {}", self.lr);
        }
        if self.epochs == 0 {
            bail!("epochs must be > 0");
        }
        if !(0.001..=1.0).contains(&self.data_scale) {
            bail!("data_scale {} out of (0.001, 1.0]", self.data_scale);
        }
        if self.threads == 0 || self.threads > MAX_THREADS {
            bail!("threads={} out of 1..={MAX_THREADS}", self.threads);
        }
        if self.backend == Backend::Hlo && self.threads > 1 {
            // the PJRT path is single-threaded per job; accepting
            // threads>1 would reserve scheduler slots it never uses
            bail!(
                "threads={} requires the native backend (the hlo path runs one thread per job)",
                self.threads
            );
        }
        if self.backend == Backend::Hlo
            && (self.trace != TraceMode::F32 || self.accum != AccumMode::F32)
        {
            // the compiled artifacts are all-f32; a precision knob the
            // backend would silently ignore must be rejected, not echoed
            bail!(
                "trace={}/accum={} require the native backend (the hlo artifacts are f32-only)",
                self.trace.name(),
                self.accum.name()
            );
        }
        if let Some(specs) = &self.layers {
            if specs.is_empty() {
                bail!("layers spec must not be empty (omit it for the flat single layer)");
            }
            if self.backend == Backend::Hlo {
                // the compiled two-phase artifacts are the fixed
                // single-layer models; layer graphs are native-only
                bail!("a layers spec requires the native backend");
            }
            let n_out = self.task.dims().1;
            let last = specs.last().unwrap();
            if last.width != n_out {
                bail!(
                    "last layer width {} must equal the task output dim {n_out}",
                    last.width
                );
            }
            for (i, rl) in self.layer_plan().iter().enumerate() {
                if rl.fan_out == 0 {
                    bail!("layers[{i}]: width must be > 0");
                }
                rl.k.validate().map_err(|e| anyhow!("layers[{i}]: {e}"))?;
                check_k_range(&rl.k, self.m(), self.epochs, &format!("layers[{i}]: "))?;
            }
        }
        if self.audit == Some(0) {
            bail!("audit cadence every:0 is invalid (want every:<n> with n >= 1)");
        }
        if let Some(t) = self.timeout_s {
            if !t.is_finite() || t <= 0.0 {
                bail!("timeout_s must be a finite number > 0 (got {t})");
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", json::s(self.task.name())),
            ("policy", json::s(self.policy.name())),
            // constants emit as plain numbers, so flat constant frames
            // stay bit-for-bit identical to v1-v3; schedules are strings
            ("k", self.k.to_json()),
            ("memory", Json::Bool(self.memory)),
            ("epochs", json::num(self.epochs as f64)),
            ("lr", json::num(self.lr as f64)),
            ("schedule", json::s(&self.schedule.name())),
            ("seed", json::num(self.seed as f64)),
            ("backend", json::s(self.backend.name())),
            ("data_scale", json::num(self.data_scale as f64)),
            ("threads", json::num(self.threads as f64)),
        ];
        if let Some(specs) = &self.layers {
            // emitted only when present, so flat frames stay v1/v2-shaped
            pairs.push(("layers", Json::Arr(specs.iter().map(|s| s.to_json()).collect())));
        }
        // emitted only when non-default, so all-f32 frames and run files
        // keep their pre-v7 shape bit-for-bit
        if self.trace != TraceMode::F32 {
            pairs.push(("trace", json::s(self.trace.name())));
        }
        if self.accum != AccumMode::F32 {
            pairs.push(("accum", json::s(self.accum.name())));
        }
        if let Some(n) = self.audit {
            // emitted only when auditing is on, so pre-v6 frames and run
            // files keep their historical shape
            pairs.push(("audit", json::s(&format!("every:{n}"))));
        }
        if let Some(t) = self.timeout_s {
            // emitted only when a wall-clock budget is set, so untimed
            // frames keep their pre-v8 shape
            pairs.push(("timeout_s", json::num(t)));
        }
        json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let gs = |k: &str| -> Result<&str> {
            v.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("config: {k} not a string"))
        };
        let gn = |k: &str| -> Result<f64> {
            v.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_f64()
                .ok_or_else(|| anyhow!("config: {k} not a number"))
        };
        let cfg = ExperimentConfig {
            task: Task::parse(gs("task")?).ok_or_else(|| anyhow!("bad task"))?,
            policy: Policy::parse(gs("policy")?).ok_or_else(|| anyhow!("bad policy"))?,
            // number (v1-v3 / constant) or schedule string (v4)
            k: KSchedule::from_json(v.req("k").map_err(|e| anyhow!("{e}"))?)
                .map_err(|e| anyhow!("config: {e}"))?,
            memory: v
                .req("memory")
                .map_err(|e| anyhow!("{e}"))?
                .as_bool()
                .ok_or_else(|| anyhow!("bad memory"))?,
            epochs: gn("epochs")? as usize,
            lr: gn("lr")? as f32,
            schedule: match v.get("schedule").and_then(|s| s.as_str()) {
                Some(s) => LrSchedule::parse(s).map_err(|e| anyhow!("config: {e}"))?,
                None => LrSchedule::Constant,
            },
            seed: gn("seed")? as u64,
            backend: Backend::parse(gs("backend")?).ok_or_else(|| anyhow!("bad backend"))?,
            data_scale: gn("data_scale")? as f32,
            // optional for wire/persistence compatibility with
            // protocol-v1 clients and pre-exec run files
            threads: match v.get("threads") {
                Some(t) => t
                    .as_f64()
                    .filter(|n| *n >= 1.0 && n.fract() == 0.0)
                    .ok_or_else(|| anyhow!("bad threads (integer >= 1)"))?
                    as usize,
                None => 1,
            },
            // optional (protocol v3): v1/v2 frames and flat run files
            // carry no layer spec
            layers: match v.get("layers") {
                Some(l) => {
                    let arr = l
                        .as_arr()
                        .ok_or_else(|| anyhow!("config: layers not an array"))?;
                    Some(
                        arr.iter()
                            .enumerate()
                            .map(|(i, e)| LayerSpec::from_json(e, i))
                            .collect::<Result<Vec<_>>>()?,
                    )
                }
                None => None,
            },
            // optional (protocol v7): pre-precision frames are all-f32;
            // unknown mode strings are rejected with a suggestion
            trace: match v.get("trace").and_then(|t| t.as_str()) {
                Some(t) => TraceMode::parse_or_suggest(t)
                    .map_err(|e| anyhow!("config: {e}"))?,
                None => TraceMode::F32,
            },
            accum: match v.get("accum").and_then(|a| a.as_str()) {
                Some(a) => AccumMode::parse_or_suggest(a)
                    .map_err(|e| anyhow!("config: {e}"))?,
                None => AccumMode::F32,
            },
            // optional (protocol v6): pre-audit frames carry no cadence
            audit: match v.get("audit") {
                Some(a) => {
                    let s = a
                        .as_str()
                        .ok_or_else(|| anyhow!("config: audit not a string"))?;
                    Some(parse_audit(s)?)
                }
                None => None,
            },
            // optional (protocol v8): pre-resilience frames carry no
            // wall-clock budget; validate() bounds it below
            timeout_s: match v.get("timeout_s") {
                Some(t) => Some(
                    t.as_f64()
                        .ok_or_else(|| anyhow!("config: timeout_s not a number"))?,
                ),
                None => None,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse the audit cadence grammar `every:<n>` (epochs, `n >= 1`) used
/// by the config wire field and the `--audit` CLI flag.
pub fn parse_audit(s: &str) -> Result<usize> {
    let n = s
        .strip_prefix("every:")
        .and_then(|n| n.parse::<usize>().ok())
        .ok_or_else(|| anyhow!("bad audit cadence {s:?} (want every:<n>)"))?;
    if n == 0 {
        bail!("bad audit cadence {s:?} (n must be >= 1)");
    }
    Ok(n)
}

/// Print Tab. I (the paper's hyperparameter table) from the presets.
pub fn table_one_rows() -> Vec<Vec<String>> {
    let e = ExperimentConfig::energy_preset();
    let m = ExperimentConfig::mnist_preset();
    let row = |name: &str, ev: String, mv: String| vec![name.to_string(), ev, mv];
    vec![
        row("Training Samples", "576".into(), "60k".into()),
        row("Validation Samples", "192".into(), "10k".into()),
        row("Optimizer", "SGD".into(), "SGD".into()),
        row("Learning Rate", format!("{}", e.lr), format!("{}", m.lr)),
        row("Loss", "MSE".into(), "Categorical Cross Entropy".into()),
        row("Epochs", format!("{}", e.epochs), format!("{}", m.epochs)),
        row("Mini-Batch Sizes", format!("{}", e.m()), format!("{}", m.m())),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_tab_1() {
        let e = ExperimentConfig::energy_preset();
        assert_eq!(e.m(), 144);
        assert_eq!(e.epochs, 100);
        assert_eq!(e.lr, 0.01);
        assert_eq!(e.task.dims(), (16, 1));
        let m = ExperimentConfig::mnist_preset();
        assert_eq!(m.m(), 64);
        assert_eq!(m.epochs, 30);
        assert_eq!(m.task.dims(), (784, 10));
        assert_eq!(m.task.figure_ks(), [32, 16, 8]);
        assert_eq!(e.task.figure_ks(), [18, 9, 3]);
    }

    #[test]
    fn labels() {
        let mut c = ExperimentConfig::energy_preset();
        assert_eq!(c.label(), "baseline");
        c.policy = Policy::TopK;
        c.memory = true;
        assert_eq!(c.label(), "topk-mem");
        c.memory = false;
        assert_eq!(c.label(), "topk-nomem");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::mnist_preset();
        c.policy = Policy::WeightedK;
        c.k = KSchedule::Constant(16);
        c.memory = true;
        c.seed = 42;
        c.data_scale = 0.25;
        c.threads = 4;
        let j = c.to_json();
        // constant k stays a plain number on the wire (v1-v3 shape)
        assert!(j.get("k").unwrap().as_usize().is_some());
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.label(), c.label());
        assert_eq!(c2.k, KSchedule::Constant(16));
        assert_eq!(c2.seed, 42);
        assert_eq!(c2.data_scale, 0.25);
        assert_eq!(c2.threads, 4);
        assert_eq!(c2.task, Task::Mnist);
    }

    #[test]
    fn threads_field_is_optional_and_validated() {
        // protocol-v1 frames / pre-exec run files omit `threads`
        let mut j = ExperimentConfig::energy_preset().to_json();
        if let crate::util::json::Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != "threads");
        }
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.threads, 1);
        // out-of-range values are rejected
        let mut bad = ExperimentConfig::energy_preset();
        bad.threads = 0;
        assert!(bad.validate().is_err());
        bad.threads = MAX_THREADS + 1;
        assert!(bad.validate().is_err());
        bad.threads = MAX_THREADS;
        assert!(bad.validate().is_ok());
        // threads is a native-backend knob: the hlo path is
        // single-threaded per job and must not reserve unused slots
        bad.backend = Backend::Hlo;
        bad.threads = 2;
        assert!(bad.validate().is_err());
        bad.threads = 1;
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn audit_field_roundtrips_and_is_optional() {
        // off by default, and omitted from the frame when off (pre-v6
        // shape preserved)
        let mut c = ExperimentConfig::energy_preset();
        assert_eq!(c.audit, None);
        assert!(c.to_json().get("audit").is_none());
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.audit, None);
        // on: emitted as the every:<n> grammar and parsed back
        c.audit = Some(3);
        let j = c.to_json();
        assert_eq!(j.get("audit").and_then(|a| a.as_str()), Some("every:3"));
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().audit, Some(3));
    }

    #[test]
    fn timeout_field_roundtrips_and_is_optional() {
        // off by default, and omitted from the frame when off (pre-v8
        // shape preserved)
        let mut c = ExperimentConfig::energy_preset();
        assert_eq!(c.timeout_s, None);
        assert!(c.to_json().get("timeout_s").is_none());
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.timeout_s, None);
        // on: emitted as a plain number and parsed back
        c.timeout_s = Some(2.5);
        let j = c.to_json();
        assert_eq!(j.get("timeout_s").and_then(|t| t.as_f64()), Some(2.5));
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().timeout_s, Some(2.5));
        // degenerate budgets are rejected at validation
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            c.timeout_s = Some(bad);
            assert!(c.validate().is_err(), "timeout_s = {bad}");
        }
        c.timeout_s = Some(0.001);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn audit_grammar_rejects_malformed_cadences() {
        assert_eq!(parse_audit("every:1").unwrap(), 1);
        assert_eq!(parse_audit("every:12").unwrap(), 12);
        for bad in ["every:0", "every:", "every:x", "3", "each:3", ""] {
            assert!(parse_audit(bad).is_err(), "{bad:?}");
        }
        let mut c = ExperimentConfig::energy_preset();
        c.audit = Some(0);
        assert!(c.validate().is_err());
        c.audit = Some(1);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = ExperimentConfig::energy_preset();
        c.k = KSchedule::Constant(0);
        assert!(c.validate().is_err());
        c.k = KSchedule::Constant(200); // > M=144
        assert!(c.validate().is_err());
        c.k = KSchedule::Constant(18);
        c.lr = -1.0;
        assert!(c.validate().is_err());
        c.lr = 0.01;
        c.epochs = 0;
        assert!(c.validate().is_err());
        // degenerate schedule params are caught even when the structs
        // were built in code (not parsed)
        c.epochs = 10;
        c.schedule = LrSchedule::StepDecay { every: 0, gamma: 0.5 };
        assert!(c.validate().is_err());
        c.schedule = LrSchedule::Constant;
        c.k = KSchedule::Step { k0: 18, every: 3, gamma: -0.5 };
        assert!(c.validate().is_err());
        c.k = KSchedule::Constant(18);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn schedules() {
        let c = LrSchedule::Constant;
        assert_eq!(c.lr_at(0.01, 1, 100), 0.01);
        assert_eq!(c.lr_at(0.01, 100, 100), 0.01);

        let s = LrSchedule::StepDecay { every: 10, gamma: 0.5 };
        assert_eq!(s.lr_at(1.0, 1, 100), 1.0);
        assert_eq!(s.lr_at(1.0, 10, 100), 1.0);
        assert_eq!(s.lr_at(1.0, 11, 100), 0.5);
        assert_eq!(s.lr_at(1.0, 21, 100), 0.25);

        let cos = LrSchedule::Cosine { min_frac: 0.1 };
        assert!((cos.lr_at(1.0, 1, 50) - 1.0).abs() < 1e-6);
        assert!((cos.lr_at(1.0, 50, 50) - 0.1).abs() < 1e-6);
        let mid = cos.lr_at(1.0, 25, 50);
        assert!(mid > 0.1 && mid < 1.0);

        // parse round-trips
        for sch in [c, s, cos] {
            assert_eq!(LrSchedule::parse(&sch.name()).unwrap(), sch);
        }
        assert!(LrSchedule::parse("bogus").is_err());
        assert!(LrSchedule::parse("step:10").is_err());
    }

    #[test]
    fn lr_at_is_total_at_epoch_zero_and_beyond_the_run() {
        // epoch is documented 1-based, but nothing upstream enforces it:
        // epoch 0 must saturate to epoch 1, never underflow the usize
        let variants = [
            LrSchedule::Constant,
            LrSchedule::StepDecay { every: 10, gamma: 0.5 },
            LrSchedule::Cosine { min_frac: 0.1 },
        ];
        for sch in variants {
            let at0 = sch.lr_at(1.0, 0, 50);
            let at1 = sch.lr_at(1.0, 1, 50);
            assert_eq!(at0.to_bits(), at1.to_bits(), "{sch:?}: epoch 0 vs 1");
            let last = sch.lr_at(1.0, 50, 50);
            assert!(last.is_finite() && last > 0.0, "{sch:?}: last epoch");
            // past the run the cosine holds its floor instead of rising
            let beyond = sch.lr_at(1.0, 60, 50);
            assert!(beyond.is_finite() && beyond <= at1, "{sch:?}: beyond");
        }
    }

    #[test]
    fn schedule_parse_rejects_degenerate_params() {
        // zero step period (previously only saved by a use-site max(1))
        assert!(LrSchedule::parse("step:0:0.5").is_err());
        // gamma out of (0, 1]
        assert!(LrSchedule::parse("step:10:-0.5").is_err());
        assert!(LrSchedule::parse("step:10:0").is_err());
        assert!(LrSchedule::parse("step:10:1.5").is_err());
        assert!(LrSchedule::parse("step:10:1").is_ok());
        // min_frac out of [0, 1]
        assert!(LrSchedule::parse("cosine:-0.1").is_err());
        assert!(LrSchedule::parse("cosine:2").is_err());
        assert!(LrSchedule::parse("cosine:0").is_ok());
        assert!(LrSchedule::parse("cosine:1").is_ok());
        // trailing junk
        assert!(LrSchedule::parse("step:10:0.5:zzz").is_err());

        // the K grammar shares the same validation
        assert!(KSchedule::parse("step:18:0:0.5").is_err());
        assert!(KSchedule::parse("step:18:3:-0.5").is_err());
        assert!(KSchedule::parse("step:18:3:1.5").is_err());
        assert!(KSchedule::parse("cosine:18:2").is_err());
        assert!(KSchedule::parse("cosine:0:0.5").is_err());
        assert!(KSchedule::parse("linear:0:10").is_err());
        assert!(KSchedule::parse("linear:10:0").is_err());
        assert!(KSchedule::parse("0").is_err());
        assert!(KSchedule::parse("step:18:3:0.5:zzz").is_err());
        assert!(KSchedule::parse("ramp:1:2").is_err());
        assert!(KSchedule::parse("4:zzz").is_err());
    }

    #[test]
    fn k_schedule_resolution() {
        let m = 144;
        // constant: every epoch identical (the historical behavior)
        let c = KSchedule::Constant(18);
        for e in [0usize, 1, 50, 100] {
            assert_eq!(c.k_at(e, 100, m), 18);
        }
        // linear: exact endpoints, monotone ramp, clamped to the batch
        let lin = KSchedule::parse("linear:3:18").unwrap();
        assert_eq!(lin, KSchedule::Linear { from: 3, to: 18 });
        let ks: Vec<usize> = (1..=6).map(|e| lin.k_at(e, 6, m)).collect();
        assert_eq!(ks, vec![3, 6, 9, 12, 15, 18]);
        assert_eq!(lin.k_at(0, 6, m), 3); // total at epoch 0
        assert_eq!(lin.k_at(9, 6, m), 18); // holds the final value
        assert_eq!(KSchedule::Linear { from: 10, to: 500 }.k_at(6, 6, m), 144); // clamp to M
        assert_eq!(KSchedule::Linear { from: 2, to: 1 }.k_at(1, 2, 1), 1); // clamp floor
        // step: decays at the period boundary, never below 1
        let st = KSchedule::parse("step:36:2:0.5").unwrap();
        let ks: Vec<usize> = (1..=6).map(|e| st.k_at(e, 6, m)).collect();
        assert_eq!(ks, vec![36, 36, 18, 18, 9, 9]);
        // beyond the run the step holds its final value, like cosine
        // and linear (the shared decay exponent is clamped to the run)
        assert_eq!(st.k_at(40, 6, m), 9);
        // ...and with a long enough run it decays toward the clamp floor
        assert_eq!(st.k_at(40, 40, m), 1);
        // cosine: starts at k0, ends at round(k0·min_frac)
        let cos = KSchedule::parse("cosine:32:0.25").unwrap();
        assert_eq!(cos.k_at(1, 10, m), 32);
        assert_eq!(cos.k_at(10, 10, m), 8);
        let mid = cos.k_at(5, 10, m);
        assert!(mid > 8 && mid < 32, "{mid}");
        // max_k sizes buffers for the peak budget
        assert_eq!(lin.max_k(), 18);
        assert_eq!(st.max_k(), 36);
        assert_eq!(cos.max_k(), 32);
        assert_eq!(KSchedule::Linear { from: 30, to: 4 }.max_k(), 30);
    }

    #[test]
    fn fully_out_of_range_schedules_are_rejected_like_oversized_constants() {
        // a schedule above M at every epoch would silently train as a
        // constant K=M — reject it exactly like `--k 200` on M=144
        let mut c = ExperimentConfig::energy_preset(); // M=144
        c.k = KSchedule::Linear { from: 200, to: 400 };
        assert!(c.validate().is_err());
        c.k = KSchedule::Cosine { k0: 300, min_frac: 1.0 };
        assert!(c.validate().is_err());
        // partial clamping stays intentional and allowed: these come
        // into range during the run
        c.k = KSchedule::Linear { from: 10, to: 500 };
        assert!(c.validate().is_ok());
        c.k = KSchedule::Step { k0: 300, every: 2, gamma: 0.5 };
        assert!(c.validate().is_ok()); // decays into range well before ep 100
        // per-layer overrides get the same check
        let mut c = layered_cfg();
        if let Some(specs) = &mut c.layers {
            specs[0].k = Some(KSchedule::Linear { from: 200, to: 400 });
        }
        assert!(c.validate().is_err());
        // min_k: decay shapes bottom out at the last epoch, linear at
        // its smaller endpoint
        assert_eq!(KSchedule::Linear { from: 200, to: 4 }.min_k(10), 4);
        assert_eq!(KSchedule::Cosine { k0: 40, min_frac: 0.5 }.min_k(10), 20);
        assert_eq!(KSchedule::Step { k0: 32, every: 1, gamma: 0.5 }.min_k(4), 4);
        // a 1-epoch run only ever realizes `from`: an out-of-range start
        // cannot hide behind an unreachable `to`
        assert_eq!(KSchedule::Linear { from: 200, to: 4 }.min_k(1), 200);
        let mut c = ExperimentConfig::energy_preset();
        c.epochs = 1;
        c.k = KSchedule::Linear { from: 200, to: 4 };
        assert!(c.validate().is_err());
        c.epochs = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn lr_and_k_schedules_agree_on_shape() {
        // the two grammars share sched_epoch/decay_steps/run_frac; pin
        // the remaining (precision-split) cosine/step formulas against
        // drift by comparing the K resolution to the lr curve scaled to
        // the same base
        let total = 40;
        let k0 = 100_000usize; // large base so integer rounding is ≪ tol
        let cos_k = KSchedule::Cosine { k0, min_frac: 0.25 };
        let cos_lr = LrSchedule::Cosine { min_frac: 0.25 };
        let st_k = KSchedule::Step { k0, every: 7, gamma: 0.5 };
        let st_lr = LrSchedule::StepDecay { every: 7, gamma: 0.5 };
        for epoch in [0usize, 1, 2, 13, 20, 39, 40, 55] {
            let kc = cos_k.k_at(epoch, total, usize::MAX) as f64 / k0 as f64;
            let lc = cos_lr.lr_at(1.0, epoch, total) as f64;
            assert!((kc - lc).abs() < 1e-4, "cosine epoch {epoch}: {kc} vs {lc}");
            let ks = st_k.k_at(epoch, total, usize::MAX) as f64 / k0 as f64;
            let ls = st_lr.lr_at(1.0, epoch, total) as f64;
            assert!((ks - ls).abs() < 1e-4, "step epoch {epoch}: {ks} vs {ls}");
        }
    }

    #[test]
    fn k_schedule_name_parse_and_json_roundtrip() {
        let scheds = [
            KSchedule::Constant(18),
            KSchedule::Step { k0: 36, every: 2, gamma: 0.5 },
            KSchedule::Cosine { k0: 32, min_frac: 0.25 },
            KSchedule::Linear { from: 3, to: 18 },
        ];
        for sch in scheds {
            assert_eq!(KSchedule::parse(&sch.name()).unwrap(), sch, "{sch:?}");
            assert_eq!(KSchedule::from_json(&sch.to_json()).unwrap(), sch, "{sch:?}");
        }
        // constants serialize as numbers, schedules as strings
        assert!(KSchedule::Constant(18).to_json().as_usize().is_some());
        assert!(KSchedule::Linear { from: 3, to: 18 }.to_json().as_str().is_some());
        // `constant:` prefix accepted as an alias for the bare integer
        assert_eq!(
            KSchedule::parse("constant:7").unwrap(),
            KSchedule::Constant(7)
        );
        assert!(KSchedule::from_json(&json::num(0.0)).is_err());
        assert!(KSchedule::from_json(&Json::Bool(true)).is_err());
    }

    #[test]
    fn annealed_config_json_roundtrip_surfaces_schedule_strings() {
        let mut c = ExperimentConfig::energy_preset();
        c.policy = Policy::TopK;
        c.k = KSchedule::parse("linear:3:18").unwrap();
        c.layers = Some(vec![
            LayerSpec {
                width: 8,
                activation: Some(Activation::Tanh),
                k: Some(KSchedule::parse("step:36:2:0.5").unwrap()),
                policy: None,
                memory: None,
            },
            LayerSpec::plain(1),
        ]);
        c.validate().unwrap();
        let j = c.to_json();
        assert_eq!(j.get("k").unwrap().as_str(), Some("linear:3:18"));
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.k, c.k);
        assert_eq!(c2.layers, c.layers);
        assert_eq!(c2.layer_plan(), c.layer_plan());
        // a degenerate schedule string on the wire is a decode error —
        // this is what the serve submit path surfaces as a protocol error
        let mut bad = c.to_json();
        if let Json::Obj(pairs) = &mut bad {
            pairs.retain(|(k, _)| k != "k");
            pairs.push(("k".to_string(), json::s("step:18:0:0.5")));
        }
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn json_roundtrip_with_schedule() {
        let mut c = ExperimentConfig::energy_preset();
        c.schedule = LrSchedule::StepDecay { every: 25, gamma: 0.3 };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.schedule, c.schedule);
    }

    fn layered_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::energy_preset();
        c.backend = Backend::Native;
        c.policy = Policy::TopK;
        c.k = KSchedule::Constant(18);
        c.memory = true;
        c.layers = Some(vec![
            LayerSpec {
                width: 8,
                activation: Some(Activation::Tanh),
                k: Some(KSchedule::Constant(36)),
                policy: Some(Policy::RandK),
                memory: Some(false),
            },
            LayerSpec::plain(1),
        ]);
        c
    }

    #[test]
    fn flat_config_resolves_to_one_identity_layer() {
        let c = ExperimentConfig::mnist_preset();
        let plan = c.layer_plan();
        assert_eq!(plan.len(), 1);
        assert_eq!((plan[0].fan_in, plan[0].fan_out), (784, 10));
        assert_eq!(plan[0].activation, Activation::Identity);
        assert_eq!(plan[0].k, c.k);
        assert_eq!(plan[0].policy, c.policy);
        assert_eq!(plan[0].memory, c.memory);
        // the epoch-resolved config carries the constant K verbatim
        let cfg1 = plan[0].cfg_at(1, c.epochs, c.m());
        assert_eq!(cfg1.k, 64);
        assert_eq!(cfg1.policy, c.policy);
        assert_eq!(c.layer_dims(), vec![(784, 10)]);
    }

    #[test]
    fn layer_plan_resolves_overrides_and_defaults() {
        let c = layered_cfg();
        assert!(c.validate().is_ok());
        let plan = c.layer_plan();
        assert_eq!(plan.len(), 2);
        // explicit overrides on layer 0
        assert_eq!((plan[0].fan_in, plan[0].fan_out), (16, 8));
        assert_eq!(plan[0].activation, Activation::Tanh);
        assert_eq!(plan[0].k, KSchedule::Constant(36));
        assert_eq!(plan[0].policy, Policy::RandK);
        assert!(!plan[0].memory);
        // bare head layer inherits the flat knobs + identity default
        assert_eq!((plan[1].fan_in, plan[1].fan_out), (8, 1));
        assert_eq!(plan[1].activation, Activation::Identity);
        assert_eq!(plan[1].k, KSchedule::Constant(18));
        assert_eq!(plan[1].policy, Policy::TopK);
        assert!(plan[1].memory);
    }

    #[test]
    fn layer_plan_resolves_annealed_budgets_per_epoch() {
        let mut c = layered_cfg();
        if let Some(specs) = &mut c.layers {
            specs[0].k = Some(KSchedule::parse("step:36:2:0.5").unwrap());
        }
        c.k = KSchedule::parse("linear:3:18").unwrap();
        c.epochs = 6;
        assert!(c.validate().is_ok());
        let plan = c.layer_plan();
        // layer 0 follows its own step schedule
        assert_eq!(plan[0].cfg_at(1, 6, 144).k, 36);
        assert_eq!(plan[0].cfg_at(3, 6, 144).k, 18);
        assert_eq!(plan[0].cfg_at(6, 6, 144).k, 9);
        // the bare head inherits the flat linear schedule
        assert_eq!(plan[1].cfg_at(1, 6, 144).k, 3);
        assert_eq!(plan[1].cfg_at(6, 6, 144).k, 18);
        // policy/memory ride along unchanged at every epoch
        assert_eq!(plan[0].cfg_at(4, 6, 144).policy, Policy::RandK);
        assert!(plan[1].cfg_at(4, 6, 144).memory);
    }

    #[test]
    fn layers_json_roundtrip() {
        let c = layered_cfg();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.layers, c.layers);
        assert_eq!(c2.layer_plan(), c.layer_plan());
        // flat configs emit no `layers` key at all (v1/v2-shaped frames)
        let flat = ExperimentConfig::energy_preset().to_json();
        assert!(flat.get("layers").is_none());
        let f2 = ExperimentConfig::from_json(&flat).unwrap();
        assert!(f2.layers.is_none());
    }

    #[test]
    fn layers_validation_rejects_bad_specs() {
        // wrong head width
        let mut c = layered_cfg();
        c.layers = Some(vec![LayerSpec::plain(8), LayerSpec::plain(3)]);
        assert!(c.validate().is_err());
        // empty spec
        c.layers = Some(vec![]);
        assert!(c.validate().is_err());
        // per-layer constant k out of range
        let mut c = layered_cfg();
        if let Some(specs) = &mut c.layers {
            specs[0].k = Some(KSchedule::Constant(200)); // > M=144
        }
        assert!(c.validate().is_err());
        // per-layer degenerate schedule params
        let mut c = layered_cfg();
        if let Some(specs) = &mut c.layers {
            specs[0].k = Some(KSchedule::Step { k0: 36, every: 0, gamma: 0.5 });
        }
        assert!(c.validate().is_err());
        // layer graphs are native-only
        let mut c = layered_cfg();
        c.backend = Backend::Hlo;
        assert!(c.validate().is_err());
    }

    #[test]
    fn layer_spec_cli_parse() {
        let specs = LayerSpec::parse_list("32:relu,8:tanh:9,1").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].width, 32);
        assert_eq!(specs[0].activation, Some(Activation::Relu));
        assert_eq!(specs[0].k, None);
        assert_eq!(specs[1].k, Some(KSchedule::Constant(9)));
        assert_eq!(specs[2], LayerSpec::plain(1));
        // everything after the second ':' is one K-schedule spec
        let annealed = LayerSpec::parse("32:relu:linear:8:32").unwrap();
        assert_eq!(annealed.k, Some(KSchedule::Linear { from: 8, to: 32 }));
        let stepped = LayerSpec::parse("8:tanh:step:36:2:0.5").unwrap();
        assert_eq!(
            stepped.k,
            Some(KSchedule::Step { k0: 36, every: 2, gamma: 0.5 })
        );
        assert!(LayerSpec::parse("x:relu").is_err());
        assert!(LayerSpec::parse("8:gelu").is_err());
        assert!(LayerSpec::parse("8:relu:4:zzz").is_err());
        assert!(LayerSpec::parse("8:relu:step:36:0:0.5").is_err());
        // empty segments are rejected, never silently dropped
        assert!(LayerSpec::parse_list("128:relu,,10").is_err());
        assert!(LayerSpec::parse_list("128:relu,10,").is_err());
    }

    #[test]
    fn layer_spec_trace_grammar() {
        // trace suffix after a K-schedule
        let s = LayerSpec::parse("4096:relu:32:bf16").unwrap();
        assert_eq!(s.k, Some(KSchedule::Constant(32)));
        assert_eq!(s.trace, Some(TraceMode::Bf16));
        // ...including annealed schedules (the suffix is split first)
        let s = LayerSpec::parse("8:tanh:step:36:2:0.5:q8").unwrap();
        assert_eq!(s.k, Some(KSchedule::Step { k0: 36, every: 2, gamma: 0.5 }));
        assert_eq!(s.trace, Some(TraceMode::Q8));
        // bare trace token where a K-schedule would be: trace-only
        let s = LayerSpec::parse("128:relu:q8").unwrap();
        assert_eq!(s.k, None);
        assert_eq!(s.trace, Some(TraceMode::Q8));
        // explicit f32 round-trips too
        assert_eq!(LayerSpec::parse("128:relu:f32").unwrap().trace, Some(TraceMode::F32));
        // no trace: unchanged historical grammar
        let s = LayerSpec::parse("32:tanh:16").unwrap();
        assert_eq!(s.k, Some(KSchedule::Constant(16)));
        assert_eq!(s.trace, None);
        // an unknown tail is still a K-schedule error, not a trace
        assert!(LayerSpec::parse("32:relu:bf17").is_err());
    }

    #[test]
    fn precision_knobs_roundtrip_and_default_to_f32() {
        // defaults emit no keys at all (pre-v7 frame shape preserved)
        let c = ExperimentConfig::energy_preset();
        assert_eq!((c.trace, c.accum), (TraceMode::F32, AccumMode::F32));
        let j = c.to_json();
        assert!(j.get("trace").is_none() && j.get("accum").is_none());
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!((back.trace, back.accum), (TraceMode::F32, AccumMode::F32));
        // non-defaults round-trip as strings
        let mut c = layered_cfg();
        c.trace = TraceMode::Bf16;
        c.accum = AccumMode::Kahan;
        if let Some(specs) = &mut c.layers {
            specs[0].trace = Some(TraceMode::Q8);
        }
        c.validate().unwrap();
        let j = c.to_json();
        assert_eq!(j.get("trace").and_then(|v| v.as_str()), Some("bf16"));
        assert_eq!(j.get("accum").and_then(|v| v.as_str()), Some("kahan"));
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.trace, TraceMode::Bf16);
        assert_eq!(back.accum, AccumMode::Kahan);
        assert_eq!(back.layers, c.layers);
        // unknown strings are rejected with a suggestion
        let mut bad = c.to_json();
        if let Json::Obj(pairs) = &mut bad {
            pairs.retain(|(k, _)| k != "trace");
            pairs.push(("trace".to_string(), json::s("bf166")));
        }
        let err = ExperimentConfig::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("bf16"), "suggestion missing from: {err}");
    }

    #[test]
    fn layer_plan_resolves_and_pins_precision() {
        // flat config: the single layer is the head — trace pinned f32
        // even if the flat knob asks for q8; accum passes through
        let mut flat = ExperimentConfig::energy_preset();
        flat.trace = TraceMode::Q8;
        flat.accum = AccumMode::F64;
        let plan = flat.layer_plan();
        assert_eq!(plan[0].trace, TraceMode::F32);
        assert_eq!(plan[0].accum, AccumMode::F64);
        assert_eq!(
            flat.precision_plan(),
            vec![LayerPrecision { trace: TraceMode::F32, accum: AccumMode::F64 }]
        );
        // layered: hidden layers inherit the flat trace, per-layer
        // overrides win, head stays pinned
        let mut c = ExperimentConfig::mnist_preset();
        c.policy = Policy::TopK;
        c.k = KSchedule::Constant(16);
        c.trace = TraceMode::Bf16;
        c.accum = AccumMode::Kahan;
        c.layers = Some(vec![
            LayerSpec::plain(128),
            LayerSpec { trace: Some(TraceMode::Q8), ..LayerSpec::plain(64) },
            LayerSpec::plain(10),
        ]);
        c.validate().unwrap();
        let plan = c.layer_plan();
        assert_eq!(plan[0].trace, TraceMode::Bf16, "inherits the flat knob");
        assert_eq!(plan[1].trace, TraceMode::Q8, "per-layer override wins");
        assert_eq!(plan[2].trace, TraceMode::F32, "head output pinned");
        assert!(plan.iter().all(|rl| rl.accum == AccumMode::Kahan));
        // an exact-policy consumer pins its *input* trace: layer 1
        // exact → layer 0's stored output must stay f32
        if let Some(specs) = &mut c.layers {
            specs[1].policy = Some(Policy::Exact);
        }
        let plan = c.layer_plan();
        assert_eq!(plan[0].trace, TraceMode::F32, "exact consumer pins input");
        assert_eq!(plan[1].trace, TraceMode::Q8, "layer 1's own output untouched");
    }

    #[test]
    fn precision_knobs_are_native_only() {
        let mut c = ExperimentConfig::energy_preset();
        c.backend = Backend::Hlo;
        c.trace = TraceMode::Bf16;
        assert!(c.validate().is_err());
        c.trace = TraceMode::F32;
        c.accum = AccumMode::F64;
        assert!(c.validate().is_err());
        c.accum = AccumMode::F32;
        assert!(c.validate().is_ok());
        c.backend = Backend::Native;
        c.trace = TraceMode::Q8;
        c.accum = AccumMode::Kahan;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn table_one_shape() {
        let rows = table_one_rows();
        assert_eq!(rows.len(), 7);
        assert!(rows.iter().all(|r| r.len() == 3));
        assert_eq!(rows[6][1], "144");
        assert_eq!(rows[6][2], "64");
    }
}
