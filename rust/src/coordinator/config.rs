//! Experiment configuration and the paper's Tab. I presets.
//!
//! Beyond the paper's flat single-layer setup, a config may carry a
//! `layers` spec: a chain of dense layers (width + activation), each
//! with its own optional `{k, policy, memory}` override — heterogeneous
//! per-layer approximation budgets, resolved by
//! [`ExperimentConfig::layer_plan`] into the `train` core's
//! [`AopLayerConfig`]s. A flat config (no `layers`) resolves to a
//! single identity-activation layer with the flat knobs — exactly the
//! historical behavior, preserved bit-for-bit.

use anyhow::{anyhow, bail, Result};

use crate::aop::Policy;
use crate::model::activations::Activation;
use crate::model::LossKind;
use crate::train::AopLayerConfig;
use crate::util::json::{self, Json};

/// Which of the paper's two workloads (plus dataset substitution scale).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Building-energy regression (16 → 1, MSE). Tab. I column 1.
    Energy,
    /// Digit classification (784 → 10 + softmax, CCE). Tab. I column 2.
    Mnist,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        Some(match s {
            "energy" => Task::Energy,
            "mnist" => Task::Mnist,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Energy => "energy",
            Task::Mnist => "mnist",
        }
    }

    pub fn loss(&self) -> LossKind {
        match self {
            Task::Energy => LossKind::Mse,
            Task::Mnist => LossKind::SoftmaxCrossEntropy,
        }
    }

    /// (n_in, n_out) of the paper's single dense layer.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Task::Energy => (16, 1),
            Task::Mnist => (784, 10),
        }
    }

    /// Tab. I mini-batch size — this is the paper's M (outer products per
    /// update).
    pub fn batch(&self) -> usize {
        match self {
            Task::Energy => 144,
            Task::Mnist => 64,
        }
    }

    /// Tab. I epochs.
    pub fn epochs(&self) -> usize {
        match self {
            Task::Energy => 100,
            Task::Mnist => 30,
        }
    }

    /// The K sweep of Figs. 2/3.
    pub fn figure_ks(&self) -> [usize; 3] {
        match self {
            Task::Energy => [18, 9, 3],
            Task::Mnist => [32, 16, 8],
        }
    }

    /// Validation batch used by the `*_eval` artifacts.
    pub fn eval_batch(&self) -> usize {
        match self {
            Task::Energy => 192, // the whole Tab. I validation split
            Task::Mnist => 64,
        }
    }
}

/// Execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust reference implementation (oracle / comparator).
    Native,
    /// AOT HLO artifacts executed via PJRT (the production path).
    Hlo,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "native" => Backend::Native,
            "hlo" | "pjrt" => Backend::Hlo,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Hlo => "hlo",
        }
    }
}

/// Learning-rate schedule (extension beyond the paper's constant η; the
/// algorithm natively supports time-varying η_t — it enters the memory
/// folding as √η_t — and the HLO artifacts take η as a runtime input, so
/// schedules need no recompilation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// η_t = lr (the paper's setting).
    Constant,
    /// η_t = lr · gamma^(epoch / every)   (integer division).
    StepDecay { every: usize, gamma: f32 },
    /// Cosine anneal from lr to lr·min_frac over the run.
    Cosine { min_frac: f32 },
}

impl LrSchedule {
    /// η for a 1-based epoch index.
    pub fn lr_at(&self, base: f32, epoch: usize, total_epochs: usize) -> f32 {
        match self {
            LrSchedule::Constant => base,
            LrSchedule::StepDecay { every, gamma } => {
                base * gamma.powi(((epoch - 1) / every.max(&1)) as i32)
            }
            LrSchedule::Cosine { min_frac } => {
                let t = (epoch - 1) as f32 / (total_epochs.max(2) - 1) as f32;
                let floor = base * min_frac;
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    pub fn parse(s: &str) -> Option<LrSchedule> {
        if s == "constant" {
            return Some(LrSchedule::Constant);
        }
        if let Some(rest) = s.strip_prefix("step:") {
            // step:<every>:<gamma>
            let mut it = rest.split(':');
            let every = it.next()?.parse().ok()?;
            let gamma = it.next()?.parse().ok()?;
            return Some(LrSchedule::StepDecay { every, gamma });
        }
        if let Some(rest) = s.strip_prefix("cosine:") {
            return Some(LrSchedule::Cosine {
                min_frac: rest.parse().ok()?,
            });
        }
        None
    }

    pub fn name(&self) -> String {
        match self {
            LrSchedule::Constant => "constant".into(),
            LrSchedule::StepDecay { every, gamma } => format!("step:{every}:{gamma}"),
            LrSchedule::Cosine { min_frac } => format!("cosine:{min_frac}"),
        }
    }
}

/// One layer of a `layers` spec: output width, activation, and optional
/// per-layer Mem-AOP-GD overrides (absent fields fall back to the flat
/// config's `k`/`policy`/`memory`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// Output width of this layer. The last layer's width must equal the
    /// task's output dim.
    pub width: usize,
    /// Elementwise activation; `None` resolves positionally (relu for
    /// hidden layers, identity for the head).
    pub activation: Option<Activation>,
    /// Per-layer K override (≤ M).
    pub k: Option<usize>,
    /// Per-layer selection-policy override.
    pub policy: Option<Policy>,
    /// Per-layer memory override.
    pub memory: Option<bool>,
}

impl LayerSpec {
    /// A bare layer: width only, everything else inherited.
    pub fn plain(width: usize) -> LayerSpec {
        LayerSpec {
            width,
            activation: None,
            k: None,
            policy: None,
            memory: None,
        }
    }

    /// Parse one CLI layer item `width[:activation[:k]]`, e.g. `32`,
    /// `32:relu`, `32:tanh:16`.
    pub fn parse(s: &str) -> Result<LayerSpec> {
        let mut it = s.trim().split(':');
        let width: usize = it
            .next()
            .filter(|w| !w.is_empty())
            .and_then(|w| w.parse().ok())
            .ok_or_else(|| anyhow!("layer '{s}': expected width[:activation[:k]]"))?;
        let activation = match it.next() {
            None | Some("") => None,
            Some(a) => Some(
                Activation::parse(a)
                    .ok_or_else(|| anyhow!("layer '{s}': unknown activation '{a}'"))?,
            ),
        };
        let k = match it.next() {
            None | Some("") => None,
            Some(kv) => Some(
                kv.parse()
                    .map_err(|_| anyhow!("layer '{s}': bad k '{kv}'"))?,
            ),
        };
        if let Some(extra) = it.next() {
            bail!("layer '{s}': unexpected trailing ':{extra}'");
        }
        Ok(LayerSpec {
            width,
            activation,
            k,
            policy: None,
            memory: None,
        })
    }

    /// Parse a comma-separated CLI list, e.g. `"32:relu,10"`. Empty
    /// segments (stray `,,` or a trailing comma) are errors, not silently
    /// dropped — a typo must not train a different network.
    pub fn parse_list(s: &str) -> Result<Vec<LayerSpec>> {
        s.split(',').map(LayerSpec::parse).collect()
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![("width", json::num(self.width as f64))];
        if let Some(a) = self.activation {
            pairs.push(("activation", json::s(a.name())));
        }
        if let Some(k) = self.k {
            pairs.push(("k", json::num(k as f64)));
        }
        if let Some(p) = self.policy {
            pairs.push(("policy", json::s(p.name())));
        }
        if let Some(m) = self.memory {
            pairs.push(("memory", Json::Bool(m)));
        }
        json::obj(pairs)
    }

    fn from_json(v: &Json, i: usize) -> Result<LayerSpec> {
        let width = v
            .get("width")
            .and_then(|n| n.as_usize())
            .ok_or_else(|| anyhow!("layers[{i}]: missing integer 'width'"))?;
        let activation = match v.get("activation").and_then(|a| a.as_str()) {
            Some(a) => Some(
                Activation::parse(a)
                    .ok_or_else(|| anyhow!("layers[{i}]: unknown activation '{a}'"))?,
            ),
            None => None,
        };
        let k = match v.get("k") {
            Some(n) => Some(
                n.as_usize()
                    .ok_or_else(|| anyhow!("layers[{i}]: bad k"))?,
            ),
            None => None,
        };
        let policy = match v.get("policy").and_then(|p| p.as_str()) {
            Some(p) => {
                Some(Policy::parse_or_suggest(p).map_err(|e| anyhow!("layers[{i}]: {e}"))?)
            }
            None => None,
        };
        let memory = match v.get("memory") {
            Some(b) => Some(
                b.as_bool()
                    .ok_or_else(|| anyhow!("layers[{i}]: bad memory"))?,
            ),
            None => None,
        };
        Ok(LayerSpec {
            width,
            activation,
            k,
            policy,
            memory,
        })
    }
}

/// One fully-resolved layer of a run: dims, activation, and the
/// effective per-layer Mem-AOP-GD config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolvedLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    pub activation: Activation,
    pub cfg: AopLayerConfig,
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub task: Task,
    pub policy: Policy,
    /// Outer products kept per update (K ≤ M). Ignored by `Exact`.
    pub k: usize,
    /// Error-feedback memory on/off (continuous vs dashed curves).
    pub memory: bool,
    pub epochs: usize,
    pub lr: f32,
    /// Per-epoch η schedule (Constant reproduces the paper).
    pub schedule: LrSchedule,
    pub seed: u64,
    pub backend: Backend,
    /// Fraction of the Tab. I dataset size to generate (1.0 = paper
    /// scale). Only affects mnist (60k/10k is expensive on CPU).
    pub data_scale: f32,
    /// Data-parallel execution threads for the native backend (the
    /// `exec` subsystem). Deterministic: every value produces
    /// bit-identical curves and weights; it only changes wall-clock. The
    /// serve scheduler accounts `threads` pool slots per job.
    pub threads: usize,
    /// Optional layer-graph spec (protocol v3). `None` = the paper's
    /// flat single dense layer with the flat `k`/`policy`/`memory` —
    /// the historical behavior. `Some` = a chain of dense layers ending
    /// at the task's output width, each optionally overriding the flat
    /// selection knobs (native backend only).
    pub layers: Option<Vec<LayerSpec>>,
}

/// Upper bound on [`ExperimentConfig::threads`] (sanity cap, far above
/// any useful parallelism for the paper's shapes).
pub const MAX_THREADS: usize = 256;

impl ExperimentConfig {
    /// Tab. I column 1: energy regression baseline configuration.
    pub fn energy_preset() -> Self {
        ExperimentConfig {
            task: Task::Energy,
            policy: Policy::Exact,
            k: 144,
            memory: false,
            epochs: Task::Energy.epochs(),
            lr: 0.01,
            schedule: LrSchedule::Constant,
            seed: 0,
            backend: Backend::Native,
            data_scale: 1.0,
            threads: 1,
            layers: None,
        }
    }

    /// Tab. I column 2: mnist classification baseline configuration.
    pub fn mnist_preset() -> Self {
        ExperimentConfig {
            task: Task::Mnist,
            policy: Policy::Exact,
            k: 64,
            memory: false,
            epochs: Task::Mnist.epochs(),
            lr: 0.01,
            schedule: LrSchedule::Constant,
            seed: 0,
            backend: Backend::Native,
            data_scale: 1.0,
            threads: 1,
            layers: None,
        }
    }

    /// Preset for a task name.
    pub fn preset(task: Task) -> Self {
        match task {
            Task::Energy => Self::energy_preset(),
            Task::Mnist => Self::mnist_preset(),
        }
    }

    /// Series label in the paper's legend vocabulary, e.g. `baseline`,
    /// `topk-mem`, `randk-nomem`.
    pub fn label(&self) -> String {
        if self.policy == Policy::Exact {
            "baseline".to_string()
        } else {
            format!(
                "{}-{}",
                self.policy.name(),
                if self.memory { "mem" } else { "nomem" }
            )
        }
    }

    /// M = mini-batch size (Tab. I).
    pub fn m(&self) -> usize {
        self.task.batch()
    }

    /// Resolve the run's layer graph: dims, activation, and the
    /// effective `{k, policy, memory}` per layer. A flat config (no
    /// `layers`) is one identity-activation dense layer with the flat
    /// knobs; a `layers` spec chains `n_in → widths... → n_out` with
    /// positional activation defaults (relu hidden, identity head) and
    /// per-layer overrides falling back to the flat values.
    pub fn layer_plan(&self) -> Vec<ResolvedLayer> {
        let (n_in, n_out) = self.task.dims();
        let Some(specs) = &self.layers else {
            return vec![ResolvedLayer {
                fan_in: n_in,
                fan_out: n_out,
                activation: Activation::Identity,
                cfg: AopLayerConfig {
                    k: self.k,
                    policy: self.policy,
                    memory: self.memory,
                },
            }];
        };
        let nl = specs.len();
        let mut fan_in = n_in;
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let last = i + 1 == nl;
                let rl = ResolvedLayer {
                    fan_in,
                    fan_out: s.width,
                    activation: s.activation.unwrap_or(if last {
                        Activation::Identity
                    } else {
                        Activation::Relu
                    }),
                    cfg: AopLayerConfig {
                        k: s.k.unwrap_or(self.k),
                        policy: s.policy.unwrap_or(self.policy),
                        memory: s.memory.unwrap_or(self.memory),
                    },
                };
                fan_in = s.width;
                rl
            })
            .collect()
    }

    /// `(fan_in, fan_out)` of every resolved layer.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        self.layer_plan()
            .iter()
            .map(|rl| (rl.fan_in, rl.fan_out))
            .collect()
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 || self.k > self.m() {
            bail!("k={} out of range 1..={}", self.k, self.m());
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("bad learning rate {}", self.lr);
        }
        if self.epochs == 0 {
            bail!("epochs must be > 0");
        }
        if !(0.001..=1.0).contains(&self.data_scale) {
            bail!("data_scale {} out of (0.001, 1.0]", self.data_scale);
        }
        if self.threads == 0 || self.threads > MAX_THREADS {
            bail!("threads={} out of 1..={MAX_THREADS}", self.threads);
        }
        if self.backend == Backend::Hlo && self.threads > 1 {
            // the PJRT path is single-threaded per job; accepting
            // threads>1 would reserve scheduler slots it never uses
            bail!(
                "threads={} requires the native backend (the hlo path runs one thread per job)",
                self.threads
            );
        }
        if let Some(specs) = &self.layers {
            if specs.is_empty() {
                bail!("layers spec must not be empty (omit it for the flat single layer)");
            }
            if self.backend == Backend::Hlo {
                // the compiled two-phase artifacts are the fixed
                // single-layer models; layer graphs are native-only
                bail!("a layers spec requires the native backend");
            }
            let n_out = self.task.dims().1;
            let last = specs.last().unwrap();
            if last.width != n_out {
                bail!(
                    "last layer width {} must equal the task output dim {n_out}",
                    last.width
                );
            }
            for (i, rl) in self.layer_plan().iter().enumerate() {
                if rl.fan_out == 0 {
                    bail!("layers[{i}]: width must be > 0");
                }
                if rl.cfg.k == 0 || rl.cfg.k > self.m() {
                    bail!(
                        "layers[{i}]: k={} out of range 1..={}",
                        rl.cfg.k,
                        self.m()
                    );
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", json::s(self.task.name())),
            ("policy", json::s(self.policy.name())),
            ("k", json::num(self.k as f64)),
            ("memory", Json::Bool(self.memory)),
            ("epochs", json::num(self.epochs as f64)),
            ("lr", json::num(self.lr as f64)),
            ("schedule", json::s(&self.schedule.name())),
            ("seed", json::num(self.seed as f64)),
            ("backend", json::s(self.backend.name())),
            ("data_scale", json::num(self.data_scale as f64)),
            ("threads", json::num(self.threads as f64)),
        ];
        if let Some(specs) = &self.layers {
            // emitted only when present, so flat frames stay v1/v2-shaped
            pairs.push(("layers", Json::Arr(specs.iter().map(|s| s.to_json()).collect())));
        }
        json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let gs = |k: &str| -> Result<&str> {
            v.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("config: {k} not a string"))
        };
        let gn = |k: &str| -> Result<f64> {
            v.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_f64()
                .ok_or_else(|| anyhow!("config: {k} not a number"))
        };
        let cfg = ExperimentConfig {
            task: Task::parse(gs("task")?).ok_or_else(|| anyhow!("bad task"))?,
            policy: Policy::parse(gs("policy")?).ok_or_else(|| anyhow!("bad policy"))?,
            k: gn("k")? as usize,
            memory: v
                .req("memory")
                .map_err(|e| anyhow!("{e}"))?
                .as_bool()
                .ok_or_else(|| anyhow!("bad memory"))?,
            epochs: gn("epochs")? as usize,
            lr: gn("lr")? as f32,
            schedule: match v.get("schedule").and_then(|s| s.as_str()) {
                Some(s) => LrSchedule::parse(s).ok_or_else(|| anyhow!("bad schedule"))?,
                None => LrSchedule::Constant,
            },
            seed: gn("seed")? as u64,
            backend: Backend::parse(gs("backend")?).ok_or_else(|| anyhow!("bad backend"))?,
            data_scale: gn("data_scale")? as f32,
            // optional for wire/persistence compatibility with
            // protocol-v1 clients and pre-exec run files
            threads: match v.get("threads") {
                Some(t) => t
                    .as_f64()
                    .filter(|n| *n >= 1.0 && n.fract() == 0.0)
                    .ok_or_else(|| anyhow!("bad threads (integer >= 1)"))?
                    as usize,
                None => 1,
            },
            // optional (protocol v3): v1/v2 frames and flat run files
            // carry no layer spec
            layers: match v.get("layers") {
                Some(l) => {
                    let arr = l
                        .as_arr()
                        .ok_or_else(|| anyhow!("config: layers not an array"))?;
                    Some(
                        arr.iter()
                            .enumerate()
                            .map(|(i, e)| LayerSpec::from_json(e, i))
                            .collect::<Result<Vec<_>>>()?,
                    )
                }
                None => None,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Print Tab. I (the paper's hyperparameter table) from the presets.
pub fn table_one_rows() -> Vec<Vec<String>> {
    let e = ExperimentConfig::energy_preset();
    let m = ExperimentConfig::mnist_preset();
    let row = |name: &str, ev: String, mv: String| vec![name.to_string(), ev, mv];
    vec![
        row("Training Samples", "576".into(), "60k".into()),
        row("Validation Samples", "192".into(), "10k".into()),
        row("Optimizer", "SGD".into(), "SGD".into()),
        row("Learning Rate", format!("{}", e.lr), format!("{}", m.lr)),
        row("Loss", "MSE".into(), "Categorical Cross Entropy".into()),
        row("Epochs", format!("{}", e.epochs), format!("{}", m.epochs)),
        row("Mini-Batch Sizes", format!("{}", e.m()), format!("{}", m.m())),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_tab_1() {
        let e = ExperimentConfig::energy_preset();
        assert_eq!(e.m(), 144);
        assert_eq!(e.epochs, 100);
        assert_eq!(e.lr, 0.01);
        assert_eq!(e.task.dims(), (16, 1));
        let m = ExperimentConfig::mnist_preset();
        assert_eq!(m.m(), 64);
        assert_eq!(m.epochs, 30);
        assert_eq!(m.task.dims(), (784, 10));
        assert_eq!(m.task.figure_ks(), [32, 16, 8]);
        assert_eq!(e.task.figure_ks(), [18, 9, 3]);
    }

    #[test]
    fn labels() {
        let mut c = ExperimentConfig::energy_preset();
        assert_eq!(c.label(), "baseline");
        c.policy = Policy::TopK;
        c.memory = true;
        assert_eq!(c.label(), "topk-mem");
        c.memory = false;
        assert_eq!(c.label(), "topk-nomem");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::mnist_preset();
        c.policy = Policy::WeightedK;
        c.k = 16;
        c.memory = true;
        c.seed = 42;
        c.data_scale = 0.25;
        c.threads = 4;
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.label(), c.label());
        assert_eq!(c2.k, 16);
        assert_eq!(c2.seed, 42);
        assert_eq!(c2.data_scale, 0.25);
        assert_eq!(c2.threads, 4);
        assert_eq!(c2.task, Task::Mnist);
    }

    #[test]
    fn threads_field_is_optional_and_validated() {
        // protocol-v1 frames / pre-exec run files omit `threads`
        let mut j = ExperimentConfig::energy_preset().to_json();
        if let crate::util::json::Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != "threads");
        }
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.threads, 1);
        // out-of-range values are rejected
        let mut bad = ExperimentConfig::energy_preset();
        bad.threads = 0;
        assert!(bad.validate().is_err());
        bad.threads = MAX_THREADS + 1;
        assert!(bad.validate().is_err());
        bad.threads = MAX_THREADS;
        assert!(bad.validate().is_ok());
        // threads is a native-backend knob: the hlo path is
        // single-threaded per job and must not reserve unused slots
        bad.backend = Backend::Hlo;
        bad.threads = 2;
        assert!(bad.validate().is_err());
        bad.threads = 1;
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = ExperimentConfig::energy_preset();
        c.k = 0;
        assert!(c.validate().is_err());
        c.k = 200; // > M=144
        assert!(c.validate().is_err());
        c.k = 18;
        c.lr = -1.0;
        assert!(c.validate().is_err());
        c.lr = 0.01;
        c.epochs = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn schedules() {
        let c = LrSchedule::Constant;
        assert_eq!(c.lr_at(0.01, 1, 100), 0.01);
        assert_eq!(c.lr_at(0.01, 100, 100), 0.01);

        let s = LrSchedule::StepDecay { every: 10, gamma: 0.5 };
        assert_eq!(s.lr_at(1.0, 1, 100), 1.0);
        assert_eq!(s.lr_at(1.0, 10, 100), 1.0);
        assert_eq!(s.lr_at(1.0, 11, 100), 0.5);
        assert_eq!(s.lr_at(1.0, 21, 100), 0.25);

        let cos = LrSchedule::Cosine { min_frac: 0.1 };
        assert!((cos.lr_at(1.0, 1, 50) - 1.0).abs() < 1e-6);
        assert!((cos.lr_at(1.0, 50, 50) - 0.1).abs() < 1e-6);
        let mid = cos.lr_at(1.0, 25, 50);
        assert!(mid > 0.1 && mid < 1.0);

        // parse round-trips
        for sch in [c, s, cos] {
            assert_eq!(LrSchedule::parse(&sch.name()), Some(sch));
        }
        assert_eq!(LrSchedule::parse("bogus"), None);
        assert_eq!(LrSchedule::parse("step:10"), None);
    }

    #[test]
    fn json_roundtrip_with_schedule() {
        let mut c = ExperimentConfig::energy_preset();
        c.schedule = LrSchedule::StepDecay { every: 25, gamma: 0.3 };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.schedule, c.schedule);
    }

    fn layered_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::energy_preset();
        c.backend = Backend::Native;
        c.policy = Policy::TopK;
        c.k = 18;
        c.memory = true;
        c.layers = Some(vec![
            LayerSpec {
                width: 8,
                activation: Some(Activation::Tanh),
                k: Some(36),
                policy: Some(Policy::RandK),
                memory: Some(false),
            },
            LayerSpec::plain(1),
        ]);
        c
    }

    #[test]
    fn flat_config_resolves_to_one_identity_layer() {
        let c = ExperimentConfig::mnist_preset();
        let plan = c.layer_plan();
        assert_eq!(plan.len(), 1);
        assert_eq!((plan[0].fan_in, plan[0].fan_out), (784, 10));
        assert_eq!(plan[0].activation, Activation::Identity);
        assert_eq!(plan[0].cfg.k, c.k);
        assert_eq!(plan[0].cfg.policy, c.policy);
        assert_eq!(plan[0].cfg.memory, c.memory);
        assert_eq!(c.layer_dims(), vec![(784, 10)]);
    }

    #[test]
    fn layer_plan_resolves_overrides_and_defaults() {
        let c = layered_cfg();
        assert!(c.validate().is_ok());
        let plan = c.layer_plan();
        assert_eq!(plan.len(), 2);
        // explicit overrides on layer 0
        assert_eq!((plan[0].fan_in, plan[0].fan_out), (16, 8));
        assert_eq!(plan[0].activation, Activation::Tanh);
        assert_eq!(plan[0].cfg.k, 36);
        assert_eq!(plan[0].cfg.policy, Policy::RandK);
        assert!(!plan[0].cfg.memory);
        // bare head layer inherits the flat knobs + identity default
        assert_eq!((plan[1].fan_in, plan[1].fan_out), (8, 1));
        assert_eq!(plan[1].activation, Activation::Identity);
        assert_eq!(plan[1].cfg.k, 18);
        assert_eq!(plan[1].cfg.policy, Policy::TopK);
        assert!(plan[1].cfg.memory);
    }

    #[test]
    fn layers_json_roundtrip() {
        let c = layered_cfg();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.layers, c.layers);
        assert_eq!(c2.layer_plan(), c.layer_plan());
        // flat configs emit no `layers` key at all (v1/v2-shaped frames)
        let flat = ExperimentConfig::energy_preset().to_json();
        assert!(flat.get("layers").is_none());
        let f2 = ExperimentConfig::from_json(&flat).unwrap();
        assert!(f2.layers.is_none());
    }

    #[test]
    fn layers_validation_rejects_bad_specs() {
        // wrong head width
        let mut c = layered_cfg();
        c.layers = Some(vec![LayerSpec::plain(8), LayerSpec::plain(3)]);
        assert!(c.validate().is_err());
        // empty spec
        c.layers = Some(vec![]);
        assert!(c.validate().is_err());
        // per-layer k out of range
        let mut c = layered_cfg();
        if let Some(specs) = &mut c.layers {
            specs[0].k = Some(200); // > M=144
        }
        assert!(c.validate().is_err());
        // layer graphs are native-only
        let mut c = layered_cfg();
        c.backend = Backend::Hlo;
        assert!(c.validate().is_err());
    }

    #[test]
    fn layer_spec_cli_parse() {
        let specs = LayerSpec::parse_list("32:relu,8:tanh:9,1").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].width, 32);
        assert_eq!(specs[0].activation, Some(Activation::Relu));
        assert_eq!(specs[0].k, None);
        assert_eq!(specs[1].k, Some(9));
        assert_eq!(specs[2], LayerSpec::plain(1));
        assert!(LayerSpec::parse("x:relu").is_err());
        assert!(LayerSpec::parse("8:gelu").is_err());
        assert!(LayerSpec::parse("8:relu:4:zzz").is_err());
        // empty segments are rejected, never silently dropped
        assert!(LayerSpec::parse_list("128:relu,,10").is_err());
        assert!(LayerSpec::parse_list("128:relu,10,").is_err());
    }

    #[test]
    fn table_one_shape() {
        let rows = table_one_rows();
        assert_eq!(rows.len(), 7);
        assert!(rows.iter().all(|r| r.len() == 3));
        assert_eq!(rows[6][1], "144");
        assert_eq!(rows[6][2], "64");
    }
}
