//! Checkpointing: durable snapshots of training state (weights, bias,
//! error-feedback memories, step counter) in a self-describing binary
//! format, so long sweeps can be resumed and final models shipped.
//!
//! Format (`MAOP1`, little-endian):
//!
//! ```text
//! magic  b"MAOP1\n"
//! u32    number of named entries
//! per entry:
//!   u32        name length, then name bytes (utf-8)
//!   u32 u32    rows, cols   (vectors: rows=len, cols=1; bytes: rows=len, cols=1)
//!   u8         rank (1 = vector, 2 = matrix, 3 = raw bytes)
//!   payload    rank 1/2: f32 * rows*cols row-major; rank 3: rows raw bytes
//! ```
//!
//! Rank-3 entries carry opaque metadata (UTF-8 JSON in practice) so
//! higher layers — the serve run registry — can persist configs and
//! curves next to the tensors without a second file format.
//!
//! Integrity: a trailing u64 FNV-1a checksum over everything before it.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Matrix;

const MAGIC: &[u8; 6] = b"MAOP1\n";

/// A named collection of tensors (weights, biases, memories).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    entries: BTreeMap<String, Entry>,
}

#[derive(Debug, Clone, PartialEq)]
enum Entry {
    Vector(Vec<f32>),
    Matrix(Matrix),
    Bytes(Vec<u8>),
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_matrix(&mut self, name: &str, m: &Matrix) {
        self.entries
            .insert(name.to_string(), Entry::Matrix(m.clone()));
    }

    pub fn put_vector(&mut self, name: &str, v: &[f32]) {
        self.entries
            .insert(name.to_string(), Entry::Vector(v.to_vec()));
    }

    /// Scalars ride as 1-element vectors (e.g. the step counter).
    pub fn put_scalar(&mut self, name: &str, v: f32) {
        self.put_vector(name, &[v]);
    }

    /// Opaque byte payload (rank-3 entry).
    pub fn put_bytes(&mut self, name: &str, data: &[u8]) {
        self.entries
            .insert(name.to_string(), Entry::Bytes(data.to_vec()));
    }

    /// UTF-8 string payload (stored as a rank-3 bytes entry).
    pub fn put_str(&mut self, name: &str, s: &str) {
        self.put_bytes(name, s.as_bytes());
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn matrix(&self, name: &str) -> Result<&Matrix> {
        match self.entries.get(name) {
            Some(Entry::Matrix(m)) => Ok(m),
            Some(_) => bail!("'{name}' is not a matrix"),
            None => bail!("checkpoint has no entry '{name}'"),
        }
    }

    pub fn vector(&self, name: &str) -> Result<&[f32]> {
        match self.entries.get(name) {
            Some(Entry::Vector(v)) => Ok(v),
            Some(_) => bail!("'{name}' is not a vector"),
            None => bail!("checkpoint has no entry '{name}'"),
        }
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let v = self.vector(name)?;
        anyhow::ensure!(v.len() == 1, "'{name}' is not a scalar");
        Ok(v[0])
    }

    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        match self.entries.get(name) {
            Some(Entry::Bytes(b)) => Ok(b),
            Some(_) => bail!("'{name}' is a tensor, not a bytes entry"),
            None => bail!("checkpoint has no entry '{name}'"),
        }
    }

    pub fn str_entry(&self, name: &str) -> Result<&str> {
        std::str::from_utf8(self.bytes(name)?)
            .map_err(|e| anyhow!("'{name}' is not valid utf-8: {e}"))
    }

    /// Serialize to bytes (MAOP1 + checksum).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, e) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            match e {
                Entry::Vector(v) => {
                    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                    out.extend_from_slice(&1u32.to_le_bytes());
                    out.push(1);
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Entry::Matrix(m) => {
                    out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
                    out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
                    out.push(2);
                    for x in m.data() {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Entry::Bytes(b) => {
                    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
                    out.extend_from_slice(&1u32.to_le_bytes());
                    out.push(3);
                    out.extend_from_slice(b);
                }
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse from bytes, verifying magic and checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 12 {
            bail!("checkpoint truncated");
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv1a(body) != stored {
            bail!("checkpoint checksum mismatch (corrupt file)");
        }
        let mut r = body;
        let mut magic = [0u8; 6];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut u32buf = [0u8; 4];
        let mut read_u32 = |r: &mut &[u8]| -> Result<u32> {
            r.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        let count = read_u32(&mut r)?;
        let mut cp = Checkpoint::new();
        for _ in 0..count {
            let nlen = read_u32(&mut r)? as usize;
            let mut nbuf = vec![0u8; nlen];
            r.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf).map_err(|e| anyhow!("bad name: {e}"))?;
            let rows = read_u32(&mut r)? as usize;
            let cols = read_u32(&mut r)? as usize;
            let mut rank = [0u8; 1];
            r.read_exact(&mut rank)?;
            if rank[0] == 3 {
                let mut raw = vec![0u8; rows];
                r.read_exact(&mut raw)?;
                cp.entries.insert(name, Entry::Bytes(raw));
                continue;
            }
            let n = rows
                .checked_mul(cols)
                .ok_or_else(|| anyhow!("tensor too large"))?;
            let mut data = vec![0f32; n];
            let mut fbuf = [0u8; 4];
            for d in data.iter_mut() {
                r.read_exact(&mut fbuf)?;
                *d = f32::from_le_bytes(fbuf);
            }
            match rank[0] {
                1 => {
                    cp.entries.insert(name, Entry::Vector(data));
                }
                2 => {
                    cp.entries
                        .insert(name, Entry::Matrix(Matrix::from_vec(rows, cols, data)));
                }
                k => bail!("bad rank tag {k}"),
            }
        }
        Ok(cp)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(0);
        let mut cp = Checkpoint::new();
        cp.put_matrix("w", &Matrix::from_fn(16, 4, |_, _| rng.normal()));
        cp.put_matrix("mem_x", &Matrix::from_fn(8, 16, |_, _| rng.normal()));
        cp.put_vector("b", &[0.1, -0.2, 0.3, 0.0]);
        cp.put_scalar("step", 1234.0);
        cp
    }

    #[test]
    fn roundtrip_bytes() {
        let cp = sample();
        let parsed = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(cp, parsed);
        assert_eq!(parsed.scalar("step").unwrap(), 1234.0);
        assert_eq!(parsed.vector("b").unwrap().len(), 4);
        assert_eq!(parsed.matrix("w").unwrap().shape(), (16, 4));
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join(format!("memaop_ckpt_{}", std::process::id()));
        let path = dir.join("model.maop");
        let cp = sample();
        cp.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(cp, loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bytes_entries_roundtrip() {
        let mut cp = sample();
        cp.put_str("config_json", r#"{"task":"energy","k":18}"#);
        cp.put_bytes("blob", &[0u8, 1, 2, 255, 128]);
        let parsed = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(parsed, cp);
        assert_eq!(
            parsed.str_entry("config_json").unwrap(),
            r#"{"task":"energy","k":18}"#
        );
        assert_eq!(parsed.bytes("blob").unwrap(), &[0u8, 1, 2, 255, 128]);
        // tensors still intact next to bytes entries
        assert_eq!(parsed.matrix("w").unwrap().shape(), (16, 4));
        // type confusion between bytes and tensors rejected
        assert!(parsed.matrix("blob").is_err());
        assert!(parsed.vector("config_json").is_err());
        assert!(parsed.bytes("w").is_err());
        assert!(parsed.str_entry("nope").is_err());
    }

    #[test]
    fn corruption_detected() {
        let cp = sample();
        let mut bytes = cp.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("checksum"));
    }

    #[test]
    fn truncation_detected() {
        let cp = sample();
        let bytes = cp.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn type_confusion_rejected() {
        let cp = sample();
        assert!(cp.matrix("b").is_err());
        assert!(cp.vector("w").is_err());
        assert!(cp.scalar("b").is_err());
        assert!(cp.matrix("nope").is_err());
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let cp = Checkpoint::new();
        let parsed = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert!(parsed.names().is_empty());
    }
}
