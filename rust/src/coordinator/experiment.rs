//! Run one configured experiment end-to-end and record its curve.
//!
//! Owns everything stochastic above the trainer so that the native and
//! HLO backends make *identical* decisions for a given seed: dataset
//! generation, epoch shuffling, and the selection-policy draws all come
//! from seeded streams derived from `cfg.seed`. The backends then differ
//! only in where the math runs — which is exactly what the
//! `native_vs_hlo` cross-check integration test asserts.
//!
//! Policy draws use *counter-based* streams (`Rng::for_stream` keyed by
//! `(seed, epoch, step)`) rather than one sequentially-consumed
//! generator: each step's selection is a pure function of its position,
//! so it cannot drift with the draw history of any other component —
//! one of the invariants behind the `exec` subsystem's guarantee that
//! `cfg.threads` never changes a curve (`rust/tests/exec.rs`).

// Clock reads are deliberate here (wall-clock run duration reporting) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use anyhow::{Context, Result};

use crate::aop::{flops, policy, Policy};
use crate::coordinator::config::{Backend, ExperimentConfig, Task};
use crate::coordinator::hlo_trainer::HloTrainer;
use crate::coordinator::native_trainer::NativeTrainer;
use crate::data::{batcher::Batcher, digits, energy, Dataset};
use crate::metrics::{EpochMetrics, LayerEpochMetrics, RunCurve};
use crate::obs::{jaccard, score_entropy, AuditLayerRecord, PhaseRollup};
use crate::runtime::Runtime;
use crate::tensor::rng::domains::STREAM_POLICY;
use crate::tensor::{rng::Rng, Matrix};
use crate::train::{self, AopLayerConfig};

/// Backend-agnostic layer-graph training interface.
///
/// The step is split in two so the *caller* owns the per-layer policy
/// decisions (mirroring the two compiled phases of the HLO path). Score
/// vectors and selections are indexed by layer; the single-layer HLO
/// path is simply the length-1 case.
pub trait Trainer {
    /// Update the learning rate (η_t enters the memory folding as √η_t;
    /// on the HLO path η is a runtime input — no recompilation).
    fn set_lr(&mut self, eta: f32);
    /// Phase 1: returns (train loss, per-layer policy scores).
    fn fwd_score(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, Vec<Vec<f32>>)>;
    /// Phase 2: apply the per-layer selections (same indexing as the
    /// scores); returns the total ||Ŵ*||_F across layers.
    fn apply(&mut self, sels: &[policy::Selection]) -> Result<f32>;
    /// Validation loss and accuracy on one batch.
    fn evaluate(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, f32)>;
    /// Frobenius mass currently deferred across all layer memories.
    fn mem_fro(&self) -> f32;
    /// Copy of every layer's (W, b) for cross-checks, input-to-output.
    fn weight_snapshot(&self) -> Vec<(Matrix, Vec<f32>)>;

    /// Whether this trainer records step telemetry (`obs`, ISSUE 6).
    /// When `false` the experiment loop reads no clocks on its behalf.
    fn obs_enabled(&self) -> bool {
        false
    }

    /// Record the duration of one selection draw, timed by the
    /// experiment loop (the caller owns selection on the trait path, so
    /// the trainer cannot time it itself). Only called when
    /// [`Trainer::obs_enabled`] returns true; never influences the math.
    fn record_select_ns(&mut self, _ns: u64) {}

    /// Frozen per-phase/per-layer telemetry summary for the run, if the
    /// backend records one (native path: the workspace's
    /// `StepTelemetry`). `None` when telemetry is off or unsupported.
    fn phase_rollup(&self) -> Option<PhaseRollup> {
        None
    }

    /// Per-layer deferred-memory Frobenius norms, input-to-output. The
    /// epoch loop records them alongside the global [`Trainer::mem_fro`]
    /// (which stays the quadrature sum `sqrt(Σ layer²)`). Backends
    /// without per-layer access return empty (the loop fills zeros).
    fn layer_mem_fro(&self) -> Vec<f32> {
        Vec::new()
    }

    /// Gradient-fidelity audit hook (ISSUE 7): called immediately after
    /// the **last** `apply` of an audited epoch, with that step's
    /// mini-batch input, while the step's buffers are still resident.
    /// Implementations must be strictly observation-only — no RNG
    /// consumption, no state writes (see `train::audit_into`). The
    /// default reports nothing (HLO path, test doubles).
    fn audit(&mut self, _x: &Matrix) -> Result<Vec<AuditLayerRecord>> {
        Ok(Vec::new())
    }
}

/// Result of one experiment.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub config: ExperimentConfig,
    pub curve: RunCurve,
    /// Final per-layer weights `(W, b)`, input-to-output (for
    /// cross-checking backends; one entry for flat configs).
    pub final_layers: Vec<(Matrix, Vec<f32>)>,
    /// Per-phase/per-layer telemetry summary (`None` when the backend
    /// records none). Describes wall time only — never part of any
    /// bit-identity comparison.
    pub phases: Option<PhaseRollup>,
}

impl RunResult {
    pub fn final_val_loss(&self) -> f32 {
        self.curve.final_val_loss()
    }

    /// First layer's final weights — for flat (single-layer) configs,
    /// *the* weights.
    pub fn final_w(&self) -> &Matrix {
        &self.final_layers[0].0
    }

    /// First layer's final bias.
    pub fn final_b(&self) -> &[f32] {
        &self.final_layers[0].1
    }
}

/// Generate the task's datasets (train, val) for a config.
pub fn load_data(cfg: &ExperimentConfig) -> (Dataset, Dataset) {
    match cfg.task {
        Task::Energy => energy::energy_dataset(cfg.seed ^ 0xDA7A),
        Task::Mnist => digits::mnist_like(cfg.data_scale, cfg.seed ^ 0xDA7A),
    }
}

/// Per-epoch observer for incremental progress reporting. Receives each
/// epoch's metrics as soon as they are recorded; returning `false` stops
/// the run early (the partial `RunResult` is still returned `Ok`) — this
/// is how the serve subsystem streams progress and honours cancellation.
///
/// Observers never influence the math: the RNG streams, data and policy
/// decisions are identical whether or not anyone is watching, so observed
/// runs stay seed-for-seed identical to plain [`run`] calls.
pub type EpochObserver<'a> = &'a mut dyn FnMut(&EpochMetrics) -> bool;

/// Run with the default backend resolution (creates a PJRT runtime if the
/// config asks for the HLO backend).
pub fn run(cfg: &ExperimentConfig) -> Result<RunResult> {
    run_with(cfg, &mut |_| true)
}

/// Like [`run`], reporting each epoch to `on_epoch` as it completes.
pub fn run_with(cfg: &ExperimentConfig, on_epoch: EpochObserver<'_>) -> Result<RunResult> {
    match cfg.backend {
        Backend::Native => {
            let trainer = NativeTrainer::new(cfg)?;
            run_with_trainer_observed(cfg, trainer, on_epoch)
        }
        Backend::Hlo => {
            let rt = Runtime::from_default_artifacts()
                .context("creating PJRT runtime (run `make artifacts`?)")?;
            let trainer = HloTrainer::new(cfg, &rt)?;
            run_with_trainer_observed(cfg, trainer, on_epoch)
        }
    }
}

/// Run on an existing runtime (lets callers share compiled artifacts
/// across experiments).
pub fn run_hlo(cfg: &ExperimentConfig, rt: &Runtime) -> Result<RunResult> {
    let trainer = HloTrainer::new(cfg, rt)?;
    run_with_trainer(cfg, trainer)
}

/// The epoch/step loop, generic over the backend.
pub fn run_with_trainer<T: Trainer>(cfg: &ExperimentConfig, trainer: T) -> Result<RunResult> {
    run_with_trainer_observed(cfg, trainer, &mut |_| true)
}

/// [`run_with_trainer`] with a per-epoch observer (see [`EpochObserver`]).
pub fn run_with_trainer_observed<T: Trainer>(
    cfg: &ExperimentConfig,
    mut trainer: T,
    on_epoch: EpochObserver<'_>,
) -> Result<RunResult> {
    run_with_trainer_ref(cfg, &mut trainer, on_epoch)
}

/// [`run_with_trainer_observed`] over a borrowed trainer — lets callers
/// keep the trainer afterwards (e.g. `repro trace` dumping the
/// telemetry's event ring once the run completes).
pub fn run_with_trainer_ref<T: Trainer>(
    cfg: &ExperimentConfig,
    trainer: &mut T,
    on_epoch: EpochObserver<'_>,
) -> Result<RunResult> {
    cfg.validate()?;
    let (train, val) = load_data(cfg);
    let m = cfg.m();
    let layers = cfg.layer_plan();
    let nl = layers.len();

    let mut shuffle_rng = Rng::new(cfg.seed ^ 0x5A0FF);
    let mut batcher = Batcher::new(train.len(), m);
    let mut curve = RunCurve::new(&cfg.label());
    let mut cum_backward_flops: u64 = 0;
    let mut cum_layer_flops: Vec<u64> = vec![0; nl];
    // selection-churn diagnostics: previous step's per-layer selected
    // indices, run-continuous across epoch boundaries. The very first
    // step of the run has no predecessor and is skipped.
    let mut prev_sel: Vec<Vec<usize>> = vec![Vec::new(); nl];
    let mut have_prev = false;

    for epoch in 1..=cfg.epochs {
        let t0 = Instant::now();
        trainer.set_lr(cfg.schedule.lr_at(cfg.lr, epoch, cfg.epochs));
        // resolve this epoch's per-layer outer-product budgets from the
        // K schedules (clamped to [1, M]); constant schedules resolve to
        // the same configs every epoch — bit-for-bit the historical
        // behavior. Resolution happens on the coordinator thread, so
        // annealed budgets share the exec determinism guarantee.
        let layer_cfgs: Vec<AopLayerConfig> = layers
            .iter()
            .map(|rl| rl.cfg_at(epoch, cfg.epochs, m))
            .collect();
        let batches = batcher.epoch_batches(&train, &mut shuffle_rng);
        curve.steps_per_epoch = batches.len();
        // `audit: every:<n>` cadence — epoch 1 is always audited so every
        // run with auditing on produces at least one fidelity record.
        let audited = cfg.audit.is_some_and(|n| (epoch - 1) % n == 0);
        let mut audit_records: Vec<AuditLayerRecord> = Vec::new();
        let mut loss_sum = 0.0f64;
        let mut fro_sum = 0.0f64;
        let mut k_eff_sums: Vec<u64> = vec![0; nl];
        let mut jac_sums: Vec<f64> = vec![0.0; nl];
        let mut jac_steps: u64 = 0;
        let mut ent_sums: Vec<f64> = vec![0.0; nl];
        for (step, b) in batches.iter().enumerate() {
            let (loss, scores) = trainer.fwd_score(&b.x, &b.y)?;
            anyhow::ensure!(scores.len() == nl, "trainer scores vs layer plan");
            // counter-based stream: the draws are keyed by (seed, epoch,
            // step), independent of every other stream's consumption.
            // The per-layer draw order (output-layer-first) is defined
            // once, in `train::select_with_configs` — for flat configs
            // this is the historical single draw.
            let mut policy_rng =
                Rng::for_stream(cfg.seed ^ STREAM_POLICY, epoch as u64, step as u64);
            let score_refs: Vec<&[f32]> = scores.iter().map(|s| s.as_slice()).collect();
            // the caller owns selection on the trait path, so the loop
            // times it on the trainer's behalf; no clock is read unless
            // the trainer opted in (obs off ⇒ zero timer overhead).
            let t_sel = if trainer.obs_enabled() { Some(Instant::now()) } else { None };
            let sels = train::select_with_configs(&layer_cfgs, &score_refs, &mut policy_rng);
            if let Some(t) = t_sel {
                trainer.record_select_ns(t.elapsed().as_nanos().min(u64::MAX as u128) as u64);
            }
            let fro = trainer.apply(&sels)?;
            if audited && step + 1 == batches.len() {
                // last step of an audited epoch: the step's buffers are
                // still resident, so the auditor can re-reduce the exact
                // same mini-batch. Strictly observation-only (asserted by
                // the exec bit-identity grid).
                audit_records = trainer.audit(&b.x)?;
            }
            // selection diagnostics: consecutive-step index overlap and
            // score mass concentration, averaged per epoch. Exact layers
            // have no score pass, so their entropy is reported as 0.
            if have_prev {
                for (li, sel) in sels.iter().enumerate() {
                    jac_sums[li] += jaccard(&sel.indices, &prev_sel[li]);
                }
                jac_steps += 1;
            }
            for (li, sel) in sels.iter().enumerate() {
                if !matches!(layer_cfgs[li].policy, Policy::Exact) {
                    ent_sums[li] += score_entropy(&scores[li]);
                }
                prev_sel[li].clear();
                prev_sel[li].extend_from_slice(&sel.indices);
            }
            have_prev = true;
            loss_sum += loss as f64;
            fro_sum += fro as f64;
            for (li, sel) in sels.iter().enumerate() {
                let lf = flops::aop_step(
                    m,
                    layers[li].fan_in,
                    layers[li].fan_out,
                    sel.k_effective(),
                )
                .backward_only();
                cum_layer_flops[li] += lf;
                cum_backward_flops += lf;
                k_eff_sums[li] += sel.k_effective() as u64;
            }
        }
        let train_s = t0.elapsed().as_secs_f64();
        let rows_done = (batches.len() * m) as f64;
        let (val_loss, val_acc) = evaluate_chunked(trainer, &val, cfg.task.eval_batch())?;
        let layer_mem = trainer.layer_mem_fro();
        let metrics = EpochMetrics {
            epoch,
            train_loss: (loss_sum / batches.len() as f64) as f32,
            val_loss,
            val_acc,
            wstar_fro: (fro_sum / batches.len() as f64) as f32,
            mem_fro: trainer.mem_fro(),
            backward_flops: cum_backward_flops,
            rows_per_sec: if train_s > 0.0 { rows_done / train_s } else { 0.0 },
            wall_s: t0.elapsed().as_secs_f64(),
            layers: (0..nl)
                .map(|li| LayerEpochMetrics {
                    k_effective: k_eff_sums[li] as f64 / batches.len() as f64,
                    backward_flops: cum_layer_flops[li],
                    sel_jaccard: if jac_steps > 0 {
                        jac_sums[li] / jac_steps as f64
                    } else {
                        0.0
                    },
                    score_entropy: ent_sums[li] / batches.len() as f64,
                    mem_fro: layer_mem.get(li).copied().unwrap_or(0.0),
                })
                .collect(),
            audit: audit_records,
        };
        check_finite(&metrics)?;
        let keep_going = on_epoch(&metrics);
        curve.push(metrics);
        if !keep_going {
            break; // observer asked to stop (e.g. job cancellation)
        }
    }

    Ok(RunResult {
        config: cfg.clone(),
        curve,
        final_layers: trainer.weight_snapshot(),
        phases: trainer.phase_rollup(),
    })
}

/// Epoch-boundary divergence guard: a NaN/Inf in the loss or in an
/// update/memory norm fails the run (and hence the serve job) with a
/// structured diagnostic naming the offending metric, the epoch, and —
/// for per-layer norms — the layer index, instead of silently streaming
/// garbage curves.
fn check_finite(m: &EpochMetrics) -> Result<()> {
    let globals: [(&str, f64); 4] = [
        ("train_loss", m.train_loss as f64),
        ("val_loss", m.val_loss as f64),
        ("wstar_fro", m.wstar_fro as f64),
        ("mem_fro", m.mem_fro as f64),
    ];
    for (name, v) in globals {
        anyhow::ensure!(
            v.is_finite(),
            "non-finite metric '{name}' = {v} at epoch {}: run diverged",
            m.epoch
        );
    }
    for (li, l) in m.layers.iter().enumerate() {
        anyhow::ensure!(
            l.mem_fro.is_finite(),
            "non-finite metric 'mem_fro' = {} at epoch {}, layer {li}: run diverged",
            l.mem_fro,
            m.epoch
        );
    }
    Ok(())
}

/// Validation in fixed-size chunks (drop-tail), matching the static batch
/// dimension of the compiled `*_eval` artifacts. Returns sample-weighted
/// mean loss/accuracy over the evaluated chunks.
pub fn evaluate_chunked<T: Trainer>(
    trainer: &mut T,
    val: &Dataset,
    chunk: usize,
) -> Result<(f32, f32)> {
    let n_chunks = val.len() / chunk;
    anyhow::ensure!(n_chunks > 0, "validation set smaller than eval batch");
    let mut loss = 0.0f64;
    let mut acc = 0.0f64;
    for c in 0..n_chunks {
        let idx: Vec<usize> = (c * chunk..(c + 1) * chunk).collect();
        let part = val.gather(&idx);
        let (l, a) = trainer.evaluate(&part.x, &part.y)?;
        loss += l as f64;
        acc += a as f64;
    }
    Ok(((loss / n_chunks as f64) as f32, (acc / n_chunks as f64) as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::KSchedule;

    fn quick_energy(policy: Policy, memory: bool, k: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = policy;
        cfg.memory = memory;
        cfg.k = KSchedule::Constant(k);
        cfg.epochs = 12;
        cfg
    }

    #[test]
    fn native_energy_baseline_learns() {
        let cfg = quick_energy(Policy::Exact, false, 144);
        let r = run(&cfg).unwrap();
        assert_eq!(r.curve.epochs.len(), 12);
        let first = r.curve.epochs[0].val_loss;
        let last = r.final_val_loss();
        assert!(last < first * 0.8, "first={first} last={last}");
        assert!(r.final_w().is_finite());
    }

    #[test]
    fn native_energy_topk_mem_learns() {
        let cfg = quick_energy(Policy::TopK, true, 18);
        let r = run(&cfg).unwrap();
        assert!(r.final_val_loss() < r.curve.epochs[0].val_loss);
        // memory must be holding deferred mass at the end of training
        assert!(r.curve.epochs.last().unwrap().mem_fro > 0.0);
    }

    #[test]
    fn flops_accounting_scales_with_k() {
        let a = run(&quick_energy(Policy::TopK, true, 18)).unwrap();
        let b = run(&quick_energy(Policy::Exact, false, 144)).unwrap();
        let fa = a.curve.total_backward_flops();
        let fb = b.curve.total_backward_flops();
        // 18/144 = 1/8 of the backward cost
        assert!((fa as f64 / fb as f64 - 0.125).abs() < 1e-9, "{fa} vs {fb}");
    }

    #[test]
    fn same_seed_same_curve() {
        let cfg = quick_energy(Policy::WeightedK, true, 9);
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        for (ma, mb) in a.curve.epochs.iter().zip(b.curve.epochs.iter()) {
            assert_eq!(ma.val_loss, mb.val_loss);
        }
    }

    #[test]
    fn threads_do_not_change_the_curve() {
        // unit-level check of the exec determinism guarantee; the full
        // {1,2,4,7} × policy × regime matrix lives in rust/tests/exec.rs
        let mut cfg = quick_energy(Policy::WeightedK, true, 9);
        let a = run(&cfg).unwrap();
        cfg.threads = 4;
        let b = run(&cfg).unwrap();
        for (ma, mb) in a.curve.epochs.iter().zip(b.curve.epochs.iter()) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.val_loss.to_bits(), mb.val_loss.to_bits());
            assert_eq!(ma.backward_flops, mb.backward_flops);
        }
        for ((wa, ba), (wb, bb)) in a.final_layers.iter().zip(b.final_layers.iter()) {
            assert_eq!(wa.data(), wb.data());
            assert_eq!(ba, bb);
        }
    }

    #[test]
    fn annealed_k_schedule_drives_per_epoch_budgets() {
        // linear:3:18 over 6 epochs resolves to K = 3,6,9,12,15,18; topk
        // without replacement evaluates exactly K products per step, so
        // the recorded per-layer k_effective must follow the schedule
        let mut cfg = quick_energy(Policy::TopK, true, 18);
        cfg.epochs = 6;
        cfg.k = KSchedule::parse("linear:3:18").unwrap();
        let r = run(&cfg).unwrap();
        assert_eq!(r.curve.epochs.len(), 6);
        for (ei, ep) in r.curve.epochs.iter().enumerate() {
            let expect = cfg.k.k_at(ei + 1, 6, cfg.m()) as f64;
            assert_eq!(ep.layers[0].k_effective, expect, "epoch {}", ei + 1);
        }
        // the FLOP account integrates the schedule: strictly between a
        // flat K=3 run and a flat K=18 run of the same length
        let mut lo_cfg = quick_energy(Policy::TopK, true, 3);
        lo_cfg.epochs = 6;
        let mut hi_cfg = quick_energy(Policy::TopK, true, 18);
        hi_cfg.epochs = 6;
        let lo = run(&lo_cfg).unwrap().curve.total_backward_flops();
        let hi = run(&hi_cfg).unwrap().curve.total_backward_flops();
        let mid = r.curve.total_backward_flops();
        assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
    }

    #[test]
    fn epochs_record_throughput() {
        let cfg = quick_energy(Policy::TopK, true, 18);
        let r = run(&cfg).unwrap();
        assert!(r.curve.epochs.iter().all(|m| m.rows_per_sec > 0.0));
    }

    #[test]
    fn different_seed_different_curve() {
        let mut cfg = quick_energy(Policy::RandK, true, 9);
        let a = run(&cfg).unwrap();
        cfg.seed = 1;
        let b = run(&cfg).unwrap();
        assert_ne!(
            a.curve.final_val_loss(),
            b.curve.final_val_loss()
        );
    }

    #[test]
    fn observer_sees_every_epoch_without_changing_the_math() {
        let cfg = quick_energy(Policy::WeightedK, true, 9);
        let mut seen = Vec::new();
        let observed = run_with(&cfg, &mut |m| {
            seen.push(m.val_loss);
            true
        })
        .unwrap();
        let plain = run(&cfg).unwrap();
        assert_eq!(seen.len(), 12);
        for (ma, mb) in observed.curve.epochs.iter().zip(plain.curve.epochs.iter()) {
            assert_eq!(ma.val_loss, mb.val_loss);
            assert_eq!(ma.backward_flops, mb.backward_flops);
        }
        assert_eq!(observed.curve.steps_per_epoch, 576 / 144);
    }

    #[test]
    fn observer_can_stop_early() {
        let cfg = quick_energy(Policy::TopK, true, 18);
        let r = run_with(&cfg, &mut |m| m.epoch < 5).unwrap();
        assert_eq!(r.curve.epochs.len(), 5);
        assert!(r.final_w().is_finite());
    }

    #[test]
    fn audit_cadence_is_config_driven_and_observation_only() {
        let mut cfg = quick_energy(Policy::TopK, true, 18);
        cfg.epochs = 5;
        let base = run(&cfg).unwrap();
        assert!(base.curve.epochs.iter().all(|e| e.audit.is_empty()));
        cfg.audit = Some(2);
        let audited = run(&cfg).unwrap();
        // observation-only: auditing must not perturb the curve at all
        for (ma, mb) in audited.curve.epochs.iter().zip(base.curve.epochs.iter()) {
            assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits());
            assert_eq!(ma.val_loss.to_bits(), mb.val_loss.to_bits());
            assert_eq!(ma.wstar_fro.to_bits(), mb.wstar_fro.to_bits());
        }
        // every:2 over 5 epochs → audited at epochs 1, 3, 5
        for ep in &audited.curve.epochs {
            let want = (ep.epoch - 1) % 2 == 0;
            assert_eq!(!ep.audit.is_empty(), want, "epoch {}", ep.epoch);
            for a in &ep.audit {
                assert!(a.cosine.is_finite() && a.cosine.abs() <= 1.0 + 1e-9, "{a:?}");
                assert!(a.rel_err.is_finite() && a.rel_err >= 0.0, "{a:?}");
                assert!(a.mem_bias.is_finite() && a.mem_bias >= 0.0, "{a:?}");
            }
            // K=18 of M=144 is genuinely approximate — the auditor must
            // see a nonzero deviation somewhere
            if want {
                assert!(ep.audit.iter().any(|a| a.rel_err > 0.0));
            }
        }
    }

    #[test]
    fn selection_diagnostics_and_layer_memory_are_recorded() {
        let r = run(&quick_energy(Policy::TopK, true, 18)).unwrap();
        let last = r.curve.epochs.last().unwrap();
        for l in &last.layers {
            assert!((0.0..=1.0).contains(&l.sel_jaccard), "jaccard {}", l.sel_jaccard);
            assert!(l.score_entropy > 0.0, "entropy {}", l.score_entropy);
            assert!(l.mem_fro >= 0.0 && l.mem_fro.is_finite());
        }
        // the global mem_fro is the quadrature sum of the per-layer norms
        let sum_sq: f64 = last.layers.iter().map(|l| (l.mem_fro as f64).powi(2)).sum();
        let g = last.mem_fro as f64;
        let scale = (g * g).max(1e-12);
        assert!((g * g - sum_sq).abs() <= 1e-5 * scale, "{g} vs sqrt({sum_sq})");

        // exact selection has no score pass, keeps every index, and
        // defers nothing: the diagnostics must report exactly that
        let ex = run(&quick_energy(Policy::Exact, false, 144)).unwrap();
        for l in &ex.curve.epochs.last().unwrap().layers {
            assert_eq!(l.score_entropy, 0.0);
            assert_eq!(l.sel_jaccard, 1.0);
            assert_eq!(l.mem_fro, 0.0);
        }
    }

    struct NanTrainer {
        nl: usize,
    }

    impl Trainer for NanTrainer {
        fn set_lr(&mut self, _eta: f32) {}
        fn fwd_score(&mut self, x: &Matrix, _y: &Matrix) -> Result<(f32, Vec<Vec<f32>>)> {
            Ok((f32::NAN, vec![vec![1.0; x.rows()]; self.nl]))
        }
        fn apply(&mut self, _sels: &[policy::Selection]) -> Result<f32> {
            Ok(0.0)
        }
        fn evaluate(&mut self, _x: &Matrix, _y: &Matrix) -> Result<(f32, f32)> {
            Ok((0.0, 0.0))
        }
        fn mem_fro(&self) -> f32 {
            0.0
        }
        fn weight_snapshot(&self) -> Vec<(Matrix, Vec<f32>)> {
            Vec::new()
        }
    }

    #[test]
    fn non_finite_loss_fails_with_structured_diagnostic() {
        let cfg = quick_energy(Policy::TopK, true, 18);
        let mut t = NanTrainer {
            nl: cfg.layer_plan().len(),
        };
        let err = run_with_trainer_ref(&cfg, &mut t, &mut |_| true).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite metric 'train_loss'"), "{msg}");
        assert!(msg.contains("epoch 1"), "{msg}");
    }

    #[test]
    fn mnist_scaled_runs() {
        let mut cfg = ExperimentConfig::mnist_preset();
        cfg.data_scale = 0.02; // 1200 train / 200 val
        cfg.epochs = 3;
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(16);
        cfg.memory = true;
        let r = run(&cfg).unwrap();
        assert_eq!(r.curve.epochs.len(), 3);
        let acc = r.curve.final_val_acc();
        assert!(acc > 0.3, "acc={acc}"); // well above 10% chance
    }
}
