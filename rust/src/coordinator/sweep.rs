//! Experiment sweeps: fan a list of configurations out over worker
//! threads and collect the curves.
//!
//! PJRT clients are not `Send`, so each worker owns its own `Runtime`
//! (artifact compilation is per-thread; compile times are reported by
//! `repro inspect-artifacts`). Native-backend sweeps have no such state
//! and parallelize trivially.

use anyhow::Result;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::{self, RunResult};
use crate::metrics::EpochMetrics;
use crate::util::pool;

/// Run all configurations, up to `workers` at a time, preserving order.
/// Errors are returned per-experiment (a failed run does not abort the
/// sweep).
pub fn run_sweep(configs: &[ExperimentConfig], workers: usize) -> Vec<Result<RunResult>> {
    run_sweep_observed(configs, workers, |_, _| true)
}

/// Like [`run_sweep`], reporting per-epoch progress incrementally:
/// `on_epoch(config_index, metrics)` is called from the worker thread as
/// each epoch of each run completes, and may return `false` to stop that
/// run early (its partial result is still returned). This is the fan-out
/// primitive the serve subsystem and long figure sweeps build on.
pub fn run_sweep_observed<F>(
    configs: &[ExperimentConfig],
    workers: usize,
    on_epoch: F,
) -> Vec<Result<RunResult>>
where
    F: Fn(usize, &EpochMetrics) -> bool + Sync,
{
    let items: Vec<(usize, ExperimentConfig)> =
        configs.iter().cloned().enumerate().collect();
    pool::run_parallel(items, workers, |(idx, cfg)| {
        // Per-config run; the HLO backend creates a per-thread runtime
        // inside `run_with` (PJRT handles are not Send).
        experiment::run_with(cfg, &mut |m| on_epoch(*idx, m))
    })
}

/// The 7 series of one paper-figure panel (one K): baseline + 3 policies
/// × {mem, nomem}, in the paper's legend order.
pub fn panel_configs(base: &ExperimentConfig, k: usize) -> Vec<ExperimentConfig> {
    use crate::aop::Policy;
    use crate::coordinator::config::KSchedule;
    let mut out = Vec::with_capacity(7);
    let mut push = |policy: Policy, memory: bool| {
        let mut c = base.clone();
        c.policy = policy;
        c.memory = memory;
        c.k = KSchedule::constant(if policy == Policy::Exact { c.m() } else { k });
        out.push(c);
    };
    push(Policy::Exact, false);
    for p in Policy::figure_set() {
        push(p, true);
        push(p, false);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;

    #[test]
    fn panel_has_seven_series() {
        let base = ExperimentConfig::energy_preset();
        use crate::coordinator::config::KSchedule;
        let cfgs = panel_configs(&base, 18);
        assert_eq!(cfgs.len(), 7);
        assert_eq!(cfgs[0].policy, Policy::Exact);
        assert_eq!(cfgs[0].k, KSchedule::Constant(144)); // baseline uses all rows
        let labels: Vec<String> = cfgs.iter().map(|c| c.label()).collect();
        assert_eq!(
            labels,
            vec![
                "baseline",
                "topk-mem",
                "topk-nomem",
                "weightedk-mem",
                "weightedk-nomem",
                "randk-mem",
                "randk-nomem"
            ]
        );
        assert!(cfgs[1..].iter().all(|c| c.k == KSchedule::Constant(18)));
    }

    #[test]
    fn observed_sweep_reports_per_config_progress() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut base = ExperimentConfig::energy_preset();
        base.epochs = 2;
        let cfgs = panel_configs(&base, 18);
        let ticks: Vec<AtomicUsize> = (0..cfgs.len()).map(|_| AtomicUsize::new(0)).collect();
        let results = run_sweep_observed(&cfgs, 4, |idx, m| {
            assert!(m.epoch >= 1 && m.epoch <= 2);
            ticks[idx].fetch_add(1, Ordering::Relaxed);
            true
        });
        assert_eq!(results.len(), 7);
        for (i, t) in ticks.iter().enumerate() {
            assert_eq!(t.load(Ordering::Relaxed), 2, "config {i}");
        }
    }

    #[test]
    fn native_sweep_runs_parallel() {
        let mut base = ExperimentConfig::energy_preset();
        base.epochs = 3;
        let cfgs = panel_configs(&base, 18);
        let results = run_sweep(&cfgs, 4);
        assert_eq!(results.len(), 7);
        for r in results {
            let r = r.unwrap();
            assert_eq!(r.curve.epochs.len(), 3);
            assert!(r.final_val_loss().is_finite());
        }
    }
}
