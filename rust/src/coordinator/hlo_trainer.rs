//! AOT/PJRT trainer: the production path.
//!
//! Drives the two-phase HLO artifacts of `python/compile/model.py`:
//!
//! 1. `"{task}_fwd_score"` — forward, loss, X̂/Ĝ memory folding, policy
//!    scores, exact bias gradient (all computed on-device);
//! 2. (Rust, between the phases) — the selection policy decides which
//!    outer products to evaluate; this is the coordinator's contribution
//!    and the reason one artifact serves every policy/K/memory setting;
//! 3. `"{task}_apply"` — Pallas-AOP weight update + memory update.
//!
//! The model state (W, b, m^X, m^G) round-trips through host `Matrix`
//! buffers each step. That is the honest cost model for a coordinator
//! that owns state placement; see EXPERIMENTS.md §Perf for the measured
//! overhead vs the native path.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::aop::policy::Selection;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::Trainer;
use crate::runtime::{ArgRef, Executable, Runtime};
use crate::tensor::{init, rng::Rng, Matrix};

/// PJRT-backed single-dense-layer trainer.
pub struct HloTrainer {
    fwd: Rc<Executable>,
    apply: Rc<Executable>,
    eval: Rc<Executable>,
    pub w: Matrix,
    pub b: Vec<f32>,
    mem_x: Matrix,
    mem_g: Matrix,
    eta: f32,
    /// fwd_score outputs awaiting the policy decision.
    pending: Option<(Matrix, Matrix, Vec<f32>)>, // xhat, ghat, db
}

impl HloTrainer {
    /// Build against a runtime; compiles (or reuses cached) artifacts.
    pub fn new(cfg: &ExperimentConfig, rt: &Runtime) -> Result<HloTrainer> {
        anyhow::ensure!(
            cfg.layers.is_none(),
            "the hlo backend compiles the fixed single-layer artifacts; \
             layer-graph configs need --backend native"
        );
        let task = cfg.task.name();
        let meta = rt.manifest.task(task)?;
        let (n, p) = cfg.task.dims();
        anyhow::ensure!(
            meta.n_in == n && meta.n_out == p && meta.batch == cfg.m(),
            "manifest/task mismatch: manifest {:?} vs config ({n},{p},{})",
            meta,
            cfg.m()
        );
        let mut wrng = Rng::new(cfg.seed ^ 0x57EED);
        let w = init::glorot_uniform(&mut wrng, n, p);
        Ok(HloTrainer {
            fwd: rt
                .load(&format!("{task}_fwd_score"))
                .context("loading fwd_score artifact")?,
            apply: rt
                .load(&format!("{task}_apply"))
                .context("loading apply artifact")?,
            eval: rt
                .load(&format!("{task}_eval"))
                .context("loading eval artifact")?,
            w,
            b: vec![0.0; p],
            mem_x: Matrix::zeros(cfg.m(), n),
            mem_g: Matrix::zeros(cfg.m(), p),
            eta: cfg.lr,
            pending: None,
        })
    }
}

impl Trainer for HloTrainer {
    fn set_lr(&mut self, eta: f32) {
        self.eta = eta;
    }

    fn fwd_score(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, Vec<Vec<f32>>)> {
        let out = self.fwd.run_ref(&[
            ArgRef::from(x),
            ArgRef::from(y),
            ArgRef::from(&self.w),
            ArgRef::from(&self.b),
            ArgRef::from(&self.mem_x),
            ArgRef::from(&self.mem_g),
            ArgRef::Scalar(self.eta),
        ])?;
        // outputs: loss, xhat, ghat, db, scores
        let mut it = out.into_iter();
        let loss = it.next().unwrap().as_scalar()?;
        let xhat = it.next().unwrap().into_matrix()?;
        let ghat = it.next().unwrap().into_matrix()?;
        let db = it.next().unwrap().into_vector()?;
        let scores = it.next().unwrap().into_vector()?;
        self.pending = Some((xhat, ghat, db));
        // single compiled dense layer == length-1 layer graph
        Ok((loss, vec![scores]))
    }

    fn apply(&mut self, sels: &[Selection]) -> Result<f32> {
        anyhow::ensure!(sels.len() == 1, "hlo trainer is single-layer");
        let sel = &sels[0];
        let (xhat, ghat, db) = self
            .pending
            .take()
            .expect("apply called without fwd_score");
        let out = self.apply.run_ref(&[
            ArgRef::from(&xhat),
            ArgRef::from(&ghat),
            ArgRef::from(&self.w),
            ArgRef::from(&self.b),
            ArgRef::from(&db),
            ArgRef::from(&sel.sel_scale),
            ArgRef::from(&sel.keep),
        ])?;
        // outputs: w_new, b_new, mem_x_new, mem_g_new, wstar_fro
        let mut it = out.into_iter();
        self.w = it.next().unwrap().into_matrix()?;
        self.b = it.next().unwrap().into_vector()?;
        self.mem_x = it.next().unwrap().into_matrix()?;
        self.mem_g = it.next().unwrap().into_matrix()?;
        it.next().unwrap().as_scalar()
    }

    fn evaluate(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, f32)> {
        let out = self.eval.run_ref(&[
            ArgRef::from(x),
            ArgRef::from(y),
            ArgRef::from(&self.w),
            ArgRef::from(&self.b),
        ])?;
        Ok((out[0].as_scalar()?, out[1].as_scalar()?))
    }

    fn mem_fro(&self) -> f32 {
        (self.mem_x.frobenius().powi(2) + self.mem_g.frobenius().powi(2)).sqrt()
    }

    fn weight_snapshot(&self) -> Vec<(Matrix, Vec<f32>)> {
        vec![(self.w.clone(), self.b.clone())]
    }
}

// Execution-path tests live in rust/tests/native_vs_hlo.rs (they need the
// built artifacts); nothing to unit-test here beyond what the compiler
// already enforces.
