//! Figure/table harness: regenerate every table and figure of the paper's
//! evaluation section from scratch (DESIGN.md §4).
//!
//! * [`figure`] — Figs. 2/3: for each K of the task's sweep, run the 7
//!   series, write `results/fig{2,3}_k{K}.csv` (wide CSV, one column per
//!   series), append full records to `results/runs.jsonl`, and print a
//!   paper-shape summary (who wins, memory-vs-no-memory gap);
//! * [`table_one`] — print Tab. I from the config presets;
//! * [`complexity`] — the Sec. I computational-reduction claim: FLOP
//!   ratios and measured wall-clock of the AOP gradient vs K.

// Clock reads are deliberate here (wall-clock harness progress reporting) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;

use anyhow::Result;

use crate::aop::flops;
use crate::coordinator::config::{Backend, ExperimentConfig, Task};
use crate::coordinator::experiment::RunResult;
use crate::coordinator::sweep;
use crate::metrics::{self, print_table, RunCurve};

/// Output locations for the harness.
pub struct FigureOptions {
    pub out_dir: PathBuf,
    pub backend: Backend,
    pub epochs: Option<usize>,
    pub data_scale: f32,
    pub seed: u64,
    pub workers: usize,
}

impl Default for FigureOptions {
    fn default() -> Self {
        FigureOptions {
            out_dir: PathBuf::from("results"),
            backend: Backend::Native,
            epochs: None,
            data_scale: 1.0,
            seed: 0,
            workers: crate::util::pool::default_workers(),
        }
    }
}

/// Which paper figure a task regenerates.
pub fn figure_number(task: Task) -> usize {
    match task {
        Task::Energy => 2,
        Task::Mnist => 3,
    }
}

/// Regenerate one paper figure (all three K panels). Returns the results
/// grouped per K in sweep order.
pub fn figure(task: Task, opts: &FigureOptions) -> Result<Vec<(usize, Vec<RunResult>)>> {
    let fig = figure_number(task);
    let mut base = ExperimentConfig::preset(task);
    base.backend = opts.backend;
    base.seed = opts.seed;
    base.data_scale = opts.data_scale;
    if let Some(e) = opts.epochs {
        base.epochs = e;
    }

    let mut all = Vec::new();
    for &k in &task.figure_ks() {
        let configs = sweep::panel_configs(&base, k);
        eprintln!(
            "[fig{fig}] K={k} (M={}): running {} series on {} workers ({} backend)",
            base.m(),
            configs.len(),
            opts.workers,
            opts.backend.name()
        );
        let results = sweep::run_sweep(&configs, opts.workers);
        let mut ok = Vec::new();
        for r in results {
            match r {
                Ok(r) => ok.push(r),
                Err(e) => eprintln!("[fig{fig}] series failed: {e:#}"),
            }
        }
        // CSV panel
        let curves: Vec<RunCurve> = ok.iter().map(|r| r.curve.clone()).collect();
        let csv = opts.out_dir.join(format!("fig{fig}_k{k}.csv"));
        metrics::write_curves_csv(&csv, &curves)?;
        eprintln!("[fig{fig}] wrote {}", csv.display());
        // JSONL full records
        let jsonl = opts.out_dir.join("runs.jsonl");
        for r in &ok {
            let record = crate::util::json::obj(vec![
                ("figure", crate::util::json::num(fig as f64)),
                ("k", crate::util::json::num(k as f64)),
                ("config", r.config.to_json()),
                ("curve", r.curve.to_json()),
            ]);
            metrics::append_jsonl(&jsonl, &record)?;
        }
        print_panel_summary(fig, k, &ok);
        all.push((k, ok));
    }
    Ok(all)
}

/// Console summary in the shape the paper's prose discusses a panel:
/// final/tail losses per series and the memory-vs-no-memory contrast.
pub fn print_panel_summary(fig: usize, k: usize, results: &[RunResult]) {
    println!("\n=== Fig. {fig}, K = {k} (M = {}) ===", results.first().map(|r| r.config.m()).unwrap_or(0));
    let tail = 5;
    let mut rows: Vec<Vec<String>> = Vec::new();
    let baseline_tail = results
        .iter()
        .find(|r| r.config.label() == "baseline")
        .map(|r| r.curve.tail_mean_val_loss(tail))
        .unwrap_or(f32::NAN);
    for r in results {
        let t = r.curve.tail_mean_val_loss(tail);
        rows.push(vec![
            r.config.label(),
            format!("{:.5}", r.final_val_loss()),
            format!("{:.5}", t),
            format!("{:.5}", r.curve.best_val_loss()),
            if r.config.label() == "baseline" {
                "--".into()
            } else {
                format!("{:+.1}%", (t / baseline_tail - 1.0) * 100.0)
            },
            format!("{:.0}s", r.curve.total_wall_s()),
        ]);
    }
    print_table(
        &["series", "final", "tail-mean", "best", "vs baseline", "wall"],
        &rows,
    );
    // who-wins line, mirroring the paper's reading of each panel
    if let Some(best) = results
        .iter()
        .filter(|r| r.config.label() != "baseline")
        .min_by(|a, b| {
            a.curve
                .tail_mean_val_loss(tail)
                .partial_cmp(&b.curve.tail_mean_val_loss(tail))
                .unwrap()
        })
    {
        let bt = best.curve.tail_mean_val_loss(tail);
        let verdict = if bt <= baseline_tail {
            "Mem-AOP-GD beats exact back-propagation"
        } else {
            "exact back-propagation retains the lead"
        };
        println!(
            "--> best approximate series: {} (tail {:.5} vs baseline {:.5}) — {}",
            best.config.label(),
            bt,
            baseline_tail,
            verdict
        );
    }
}

/// Print Tab. I.
pub fn table_one() {
    println!("Table I. Parameters and hyperparameters (from config presets)\n");
    print_table(
        &["", "Energy", "MNIST"],
        &crate::coordinator::config::table_one_rows(),
    );
}

/// The computational-complexity claim: FLOP model + measured native
/// wall-clock of the weight-gradient computation across the paper's K
/// sweep. Printed as a table; also written to `results/complexity.csv`.
pub fn complexity(out_dir: &PathBuf) -> Result<()> {
    use crate::tensor::{ops, rng::Rng, Matrix};
    use std::time::Instant;

    println!("Computational reduction of the AOP weight gradient (Sec. I claim)\n");
    let mut rows = Vec::new();
    let mut csv = String::from("task,m,n,p,k,ratio_flops,exact_us,aop_us,measured_ratio\n");
    for (task, m, n, p) in [("energy", 144usize, 16usize, 1usize), ("mnist", 64, 784, 10)] {
        let ks = if task == "energy" {
            [144usize, 18, 9, 3]
        } else {
            [64usize, 32, 16, 8]
        };
        let mut rng = Rng::new(0);
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let g = Matrix::from_fn(m, p, |_, _| rng.normal());
        // measured exact
        let time_it = |f: &mut dyn FnMut()| -> f64 {
            let reps = 200;
            let t = Instant::now();
            for _ in 0..reps {
                f();
            }
            t.elapsed().as_secs_f64() * 1e6 / reps as f64
        };
        let exact_us = time_it(&mut || {
            std::hint::black_box(ops::matmul_tn(&x, &g));
        });
        for &k in &ks {
            let sel: Vec<(usize, f32)> = (0..k).map(|i| (i * (m / k.max(1)).max(1) % m, 1.0)).collect();
            let aop_us = time_it(&mut || {
                std::hint::black_box(ops::masked_outer_compact(&x, &g, &sel));
            });
            let ratio = flops::backward_reduction(m, n, p, k);
            rows.push(vec![
                task.to_string(),
                format!("{k}/{m}"),
                format!("{:.3}", ratio),
                format!("{exact_us:.1}"),
                format!("{aop_us:.1}"),
                format!("{:.3}", aop_us / exact_us),
            ]);
            csv.push_str(&format!(
                "{task},{m},{n},{p},{k},{ratio:.4},{exact_us:.2},{aop_us:.2},{:.4}\n",
                aop_us / exact_us
            ));
        }
    }
    print_table(
        &["task", "K/M", "FLOP ratio", "exact µs", "AOP µs", "measured ratio"],
        &rows,
    );
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(out_dir.join("complexity.csv"), csv)?;
    println!("\nwrote {}", out_dir.join("complexity.csv").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_numbers() {
        assert_eq!(figure_number(Task::Energy), 2);
        assert_eq!(figure_number(Task::Mnist), 3);
    }

    #[test]
    fn tiny_figure_run_writes_csv() {
        let dir = std::env::temp_dir().join(format!("memaop_fig_{}", std::process::id()));
        let opts = FigureOptions {
            out_dir: dir.clone(),
            backend: Backend::Native,
            epochs: Some(2),
            data_scale: 1.0,
            seed: 0,
            workers: 4,
        };
        let res = figure(Task::Energy, &opts).unwrap();
        assert_eq!(res.len(), 3); // three K panels
        for (k, runs) in &res {
            assert_eq!(runs.len(), 7, "K={k}");
            assert!(dir.join(format!("fig2_k{k}.csv")).exists());
        }
        assert!(dir.join("runs.jsonl").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
