//! Layer-3 coordinator: the training framework around the algorithm.
//!
//! * [`config`] — experiment configuration + Tab. I presets, JSON I/O;
//! * [`experiment`] — run one configured experiment (native or HLO
//!   backend) and produce a metrics curve;
//! * [`hlo_trainer`] — the AOT path: drives the two-phase
//!   `fwd_score`/`apply` artifacts with policy decisions made in Rust;
//! * [`native_trainer`] — the pure-Rust oracle path (same math);
//! * [`mlp_driver`] — end-to-end multi-layer MLP training through the
//!   monolithic artifacts (e2e example backend);
//! * [`sweep`] — parallel experiment fan-out;
//! * [`figures`] — regenerate Fig. 2 / Fig. 3 / Tab. I / the complexity
//!   claim from scratch, writing CSVs under `results/`.

pub mod checkpoint;
pub mod config;
pub mod experiment;
pub mod figures;
pub mod hlo_trainer;
pub mod mlp_driver;
pub mod native_trainer;
pub mod sweep;
