//! Pure-Rust trainer for the paper's single-layer tasks.
//!
//! The numerics oracle for the HLO path: identical math, identical policy
//! decisions (both paths draw selections from the same seeded RNG stream
//! in [`experiment`](crate::coordinator::experiment)), so curves must
//! agree to f32 tolerance — enforced by `rust/tests/native_vs_hlo.rs`.

use anyhow::Result;

use crate::aop::engine::{AopEngine, FwdScore};
use crate::aop::policy::Selection;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::Trainer;
use crate::exec::Executor;
use crate::tensor::{init, rng::Rng, Matrix};

/// Native single-dense-layer trainer. Executes through the `exec`
/// subsystem with `cfg.threads` workers — `threads = 1` is the inline
/// serial path, and any other value is bit-identical to it.
pub struct NativeTrainer {
    engine: AopEngine,
    eta: f32,
    /// Persistent worker pool, one per trainer (dispatch reuses warm
    /// threads across every step of the run).
    exec: Executor,
    /// Cached fwd_score output between `scores` and `apply` (the trait
    /// splits the step so the caller owns the policy decision).
    pending: Option<FwdScore>,
}

impl NativeTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Result<NativeTrainer> {
        let (n, p) = cfg.task.dims();
        // weight init stream is independent of the policy stream
        let mut wrng = Rng::new(cfg.seed ^ 0x57EED);
        let w = init::glorot_uniform(&mut wrng, n, p);
        let engine = AopEngine::new(
            w,
            cfg.task.loss(),
            cfg.m(),
            cfg.policy,
            cfg.k,
            cfg.memory,
        );
        Ok(NativeTrainer {
            engine,
            eta: cfg.lr,
            exec: Executor::new(cfg.threads),
            pending: None,
        })
    }
}

impl Trainer for NativeTrainer {
    fn set_lr(&mut self, eta: f32) {
        self.eta = eta;
    }

    fn fwd_score(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let fs = self.engine.fwd_score_exec(x, y, self.eta, &self.exec);
        let loss = fs.loss;
        let scores = fs.scores.clone();
        let db = fs.db.clone();
        self.pending = Some(fs);
        Ok((loss, scores, db))
    }

    fn apply(&mut self, sel: &Selection) -> Result<f32> {
        let fs = self
            .pending
            .take()
            .expect("apply called without fwd_score");
        let stats = self.engine.apply_exec(&fs, sel, &self.exec);
        Ok(stats.wstar_fro)
    }

    fn evaluate(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, f32)> {
        Ok(self.engine.evaluate_exec(x, y, &self.exec))
    }

    fn mem_fro(&self) -> f32 {
        self.engine.memory.deferred_mass()
    }

    fn weight_snapshot(&self) -> (Matrix, Vec<f32>) {
        (self.engine.w.clone(), self.engine.b.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::policy::{self, Policy};

    #[test]
    fn trait_step_cycle_runs() {
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = Policy::TopK;
        cfg.k = 18;
        cfg.memory = true;
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let mut rng = Rng::new(0);
        let x = Matrix::from_fn(144, 16, |_, _| rng.normal());
        let y = Matrix::from_fn(144, 1, |_, _| rng.normal());
        let (loss, scores, _db) = t.fwd_score(&x, &y).unwrap();
        assert!(loss.is_finite());
        assert_eq!(scores.len(), 144);
        let sel = policy::select(Policy::TopK, &scores, 18, true, &mut rng);
        let fro = t.apply(&sel).unwrap();
        assert!(fro > 0.0);
        let (vl, _) = t.evaluate(&x, &y).unwrap();
        assert!(vl.is_finite());
        assert!(t.mem_fro() > 0.0);
    }

    #[test]
    #[should_panic(expected = "apply called without fwd_score")]
    fn apply_without_fwd_panics() {
        let cfg = ExperimentConfig::energy_preset();
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let sel = Selection {
            sel_scale: vec![1.0; 144],
            keep: vec![0.0; 144],
            indices: (0..144).collect(),
        };
        let _ = t.apply(&sel);
    }
}
