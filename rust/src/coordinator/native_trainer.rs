//! Pure-Rust trainer — a thin adapter binding the layer-graph training
//! core (`crate::train`) to the backend-agnostic [`Trainer`] interface.
//!
//! The numerics oracle for the HLO path: identical math, identical policy
//! decisions (both paths draw selections from the same seeded RNG stream
//! in [`experiment`](crate::coordinator::experiment)), so single-layer
//! curves must agree to f32 tolerance — enforced by
//! `rust/tests/native_vs_hlo.rs`. Beyond the paper's flat models it
//! trains any `layers` spec: per-layer activations and per-layer
//! `{k, policy, memory}` resolved by `ExperimentConfig::layer_plan`.

use anyhow::Result;

use crate::aop::policy::Selection;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::Trainer;
use crate::exec::Executor;
use crate::obs::{AuditLayerRecord, ObsConfig, Phase, PhaseRollup, StepTelemetry};
use crate::tensor::{rng::Rng, Matrix};
use crate::train::{self, Dense, Graph, GraphState, GraphWorkspace};

/// Native layer-graph trainer. Executes through the `exec` subsystem
/// with `cfg.threads` workers — `threads = 1` is the inline serial path,
/// and any other value is bit-identical to it.
pub struct NativeTrainer {
    graph: Graph,
    state: GraphState,
    eta: f32,
    /// Persistent worker pool, one per trainer (dispatch reuses warm
    /// threads across every step of the run).
    exec: Executor,
    /// Resident step workspace (§Perf pass): the trace, foldings,
    /// scores and shard partials of the pending `fwd_score` live here
    /// between the trait's two phases (the workspace's internal pairing
    /// marker enforces the fwd_score→apply ordering), and steady-state
    /// steps allocate only the trait-mandated score clones.
    ws: GraphWorkspace,
    /// Dedicated evaluation workspace, keyed at the task's eval batch.
    /// Separate from `ws` on purpose: `Graph::evaluate_ws` writes the
    /// exact staging buffers, which would clobber the training trace
    /// pending between `fwd_score` and `apply`. All-f32 — evaluation is
    /// forward-exact regardless of the training trace modes.
    ws_eval: GraphWorkspace,
}

impl NativeTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Result<NativeTrainer> {
        cfg.validate()?;
        let plan = cfg.layer_plan();
        // defense in depth behind validate(): a degenerate plan must
        // produce an Err a serve worker can report, never reach the
        // Graph constructor's panic
        anyhow::ensure!(
            !plan.is_empty() && plan.iter().all(|rl| rl.fan_out > 0),
            "layer plan resolves to no usable layers (empty or zero-width spec)"
        );
        // weight init stream is independent of the policy stream; layers
        // draw in input-to-output order, so the flat single-layer case
        // consumes exactly the historical stream
        let mut wrng = Rng::new(cfg.seed ^ 0x57EED);
        let layers: Vec<Dense> = plan
            .iter()
            .map(|rl| Dense::glorot(&mut wrng, rl.fan_in, rl.fan_out, rl.activation))
            .collect();
        let graph = Graph::new(layers, cfg.task.loss());
        // the graph state carries the epoch-1 resolution of each layer's
        // K schedule; per-epoch budgets are supplied by the experiment
        // loop through `select_with_configs` (the caller owns selection),
        // so an annealing schedule never mutates trainer state
        let cfgs: Vec<_> = plan
            .iter()
            .map(|rl| rl.cfg_at(1, cfg.epochs, cfg.m()))
            .collect();
        let state = GraphState::from_configs(&graph, cfg.m(), &cfgs);
        // telemetry on by default: every run (and thus every serve job)
        // gets a phase rollup for free. The histograms and counters are
        // pre-sized here, so steady-state steps stay allocation-free,
        // and obs never feeds back into the math — the exec bit-identity
        // grid passes with it on or off (rust/tests/exec.rs).
        let mut ws = GraphWorkspace::new(&graph, cfg.m());
        ws.set_obs(ObsConfig::on());
        // §Mixed precision: the resolved per-layer trace/accum pairs
        // (head + exact-policy pins already applied by layer_plan)
        ws.set_precision(&graph, &cfg.precision_plan());
        record_trace_footprint(&mut ws);
        let ws_eval = GraphWorkspace::new(&graph, cfg.task.eval_batch());
        Ok(NativeTrainer {
            graph,
            state,
            eta: cfg.lr,
            exec: Executor::new(cfg.threads),
            ws,
            ws_eval,
        })
    }

    /// Reconfigure telemetry (e.g. `repro trace` raising the event-ring
    /// capacity, or benches switching it off). Resets any counts
    /// recorded so far (the trace-footprint gauge is re-recorded).
    pub fn set_obs(&mut self, cfg: ObsConfig) {
        self.ws.set_obs(cfg);
        record_trace_footprint(&mut self.ws);
    }

    /// The trainer's step telemetry (histograms, counters, event ring).
    pub fn telemetry(&self) -> &StepTelemetry {
        self.ws.obs()
    }
}

/// Seed the rollup's per-layer trace-bytes gauge (§Mixed precision)
/// from the workspace's resolved precision: compressed layers report
/// their backward-read footprint, f32 layers stay at 0 so all-f32
/// rollups keep the pre-v7 frame shape.
fn record_trace_footprint(ws: &mut GraphWorkspace) {
    use crate::tensor::quant::TraceMode;
    let prec: Vec<TraceMode> = ws.precision().iter().map(|p| p.trace).collect();
    for (li, trace) in prec.into_iter().enumerate() {
        if trace != TraceMode::F32 {
            let bytes = ws.layer_trace_bytes(li) as u64;
            ws.obs_mut().record_trace_bytes(li, bytes);
        }
    }
}

impl Trainer for NativeTrainer {
    fn set_lr(&mut self, eta: f32) {
        self.eta = eta;
    }

    fn fwd_score(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, Vec<Vec<f32>>)> {
        let (loss, _acc) =
            train::fwd_score(&self.graph, &self.state, x, y, self.eta, &self.exec, &mut self.ws);
        // the trait hands scores to the caller by value; Exact-policy
        // layers never compute scores (their workspace vector is stale)
        // and never read them either — see train::workspace
        let scores = (0..self.graph.layers.len())
            .map(|li| self.ws.scores(li).to_vec())
            .collect();
        Ok((loss, scores))
    }

    fn apply(&mut self, sels: &[Selection]) -> Result<f32> {
        // panics "apply called without fwd_score" via the workspace's
        // pairing marker if the phases are misused
        let out = train::apply(
            &mut self.graph,
            &mut self.state,
            sels,
            self.eta,
            &self.exec,
            true,
            &mut self.ws,
        );
        Ok(out.wstar_fro)
    }

    fn evaluate(&mut self, x: &Matrix, y: &Matrix) -> Result<(f32, f32)> {
        // resident eval buffers (bitwise the throwaway evaluate_exec
        // path); the training workspace is untouched
        Ok(self.graph.evaluate_ws(x, y, &self.exec, &mut self.ws_eval))
    }

    fn mem_fro(&self) -> f32 {
        self.state.deferred_mass()
    }

    fn weight_snapshot(&self) -> Vec<(Matrix, Vec<f32>)> {
        self.graph
            .layers
            .iter()
            .map(|l| (l.w.clone(), l.b.clone()))
            .collect()
    }

    fn obs_enabled(&self) -> bool {
        self.ws.obs().enabled()
    }

    fn record_select_ns(&mut self, ns: u64) {
        self.ws.obs_mut().record_ns(Phase::Select, ns);
    }

    fn phase_rollup(&self) -> Option<PhaseRollup> {
        let obs = self.ws.obs();
        if obs.enabled() {
            Some(obs.rollup())
        } else {
            None
        }
    }

    fn layer_mem_fro(&self) -> Vec<f32> {
        // per-layer norms; `Trainer::mem_fro` stays their quadrature sum
        // (`GraphState::deferred_mass`), pinned by the experiment tests
        self.state
            .layers
            .iter()
            .map(|l| l.mem.deferred_mass())
            .collect()
    }

    fn audit(&mut self, x: &Matrix) -> Result<Vec<AuditLayerRecord>> {
        let mut out = Vec::new();
        train::audit_into(
            &self.graph,
            &self.state,
            x,
            self.eta,
            &self.exec,
            true,
            &mut self.ws,
            &mut out,
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::policy::{self, Policy};
    use crate::coordinator::config::{KSchedule, LayerSpec};

    #[test]
    fn trait_step_cycle_runs() {
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(18);
        cfg.memory = true;
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let mut rng = Rng::new(0);
        let x = Matrix::from_fn(144, 16, |_, _| rng.normal());
        let y = Matrix::from_fn(144, 1, |_, _| rng.normal());
        let (loss, scores) = t.fwd_score(&x, &y).unwrap();
        assert!(loss.is_finite());
        assert_eq!(scores.len(), 1);
        assert_eq!(scores[0].len(), 144);
        let sel = policy::select(Policy::TopK, &scores[0], 18, true, &mut rng);
        let fro = t.apply(std::slice::from_ref(&sel)).unwrap();
        assert!(fro > 0.0);
        let (vl, _) = t.evaluate(&x, &y).unwrap();
        assert!(vl.is_finite());
        assert!(t.mem_fro() > 0.0);
        assert_eq!(t.weight_snapshot().len(), 1);
    }

    #[test]
    fn layered_config_builds_matching_graph() {
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(18);
        cfg.memory = true;
        cfg.layers = Some(vec![
            LayerSpec {
                width: 8,
                activation: Some(crate::model::Activation::Tanh),
                k: Some(KSchedule::Constant(36)),
                ..LayerSpec::plain(8)
            },
            LayerSpec::plain(1),
        ]);
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(144, 16, |_, _| rng.normal());
        let y = Matrix::from_fn(144, 1, |_, _| rng.normal());
        let (_, scores) = t.fwd_score(&x, &y).unwrap();
        assert_eq!(scores.len(), 2);
        let sels: Vec<_> = [(36usize, 0usize), (18, 1)]
            .iter()
            .map(|&(k, li)| policy::select(Policy::TopK, &scores[li], k, true, &mut rng))
            .collect();
        let fro = t.apply(&sels).unwrap();
        assert!(fro.is_finite());
        let snap = t.weight_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0.shape(), (16, 8));
        assert_eq!(snap[1].0.shape(), (8, 1));
    }

    #[test]
    fn audit_hook_reports_per_layer_fidelity() {
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(18);
        cfg.memory = true;
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(144, 16, |_, _| rng.normal());
        let y = Matrix::from_fn(144, 1, |_, _| rng.normal());
        let (_, scores) = t.fwd_score(&x, &y).unwrap();
        let sel = policy::select(Policy::TopK, &scores[0], 18, true, &mut rng);
        t.apply(std::slice::from_ref(&sel)).unwrap();
        let recs = t.audit(&x).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].layer, 0);
        assert!(recs[0].cosine.is_finite() && recs[0].cosine.abs() <= 1.0 + 1e-9);
        // K=18 of M=144: the kept-K update genuinely deviates from exact
        assert!(recs[0].rel_err > 0.0);
        // single layer: the quadrature sum degenerates to the layer norm
        let lm = t.layer_mem_fro();
        assert_eq!(lm.len(), 1);
        assert_eq!(lm[0], t.mem_fro());
    }

    #[test]
    fn precision_config_threads_through_to_training_and_eval() {
        use crate::tensor::quant::{AccumMode, TraceMode};
        let mut cfg = ExperimentConfig::energy_preset();
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(18);
        cfg.memory = true;
        cfg.trace = TraceMode::Q8;
        cfg.accum = AccumMode::F64;
        cfg.layers = Some(vec![LayerSpec::plain(8), LayerSpec::plain(1)]);
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(144, 16, |_, _| rng.normal());
        let y = Matrix::from_fn(144, 1, |_, _| rng.normal());
        for _ in 0..4 {
            let (loss, scores) = t.fwd_score(&x, &y).unwrap();
            assert!(loss.is_finite());
            let sels: Vec<_> = (0..2)
                .map(|li| policy::select(Policy::TopK, &scores[li], 18, true, &mut rng))
                .collect();
            t.apply(&sels).unwrap();
        }
        // evaluation is forward-exact and must not disturb the pending-
        // trace invariants (dedicated eval workspace)
        let (vl, _) = t.evaluate(&x, &y).unwrap();
        assert!(vl.is_finite());
        // the audit reports the resolved input trace per layer: layer 0
        // reads the raw f32 batch, layer 1 reads the q8 trace
        let recs = t.audit(&x).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].trace, TraceMode::F32);
        assert_eq!(recs[1].trace, TraceMode::Q8);
        // the rollup carries the compressed footprint: layer 0 stores
        // its output in q8 (144×8 codes + per-row steps); the pinned
        // f32 head reports nothing
        let roll = t.phase_rollup().unwrap();
        assert_eq!(roll.layers[0].trace_bytes, (144 * 8 + 4 * 144) as u64);
        assert_eq!(roll.layers[1].trace_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "apply called without fwd_score")]
    fn apply_without_fwd_panics() {
        let cfg = ExperimentConfig::energy_preset();
        let mut t = NativeTrainer::new(&cfg).unwrap();
        let sel = Selection {
            sel_scale: vec![1.0; 144],
            keep: vec![0.0; 144],
            indices: (0..144).collect(),
        };
        let _ = t.apply(std::slice::from_ref(&sel));
    }
}
