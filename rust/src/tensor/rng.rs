//! Deterministic RNG + samplers (offline substitute for `rand`).
//!
//! xoshiro256++ seeded through SplitMix64, with the samplers the
//! coordinator needs: uniforms, Box–Muller normals, shuffles, and the
//! with/without-replacement weighted draws of the randK / weightedK
//! selection policies (Sec. II-B of the paper).
//!
//! Determinism is a correctness feature here: the native and HLO training
//! paths must make *identical* policy decisions for the cross-check tests
//! in `rust/tests/native_vs_hlo.rs`, so every stochastic choice flows
//! through this generator with an explicit seed.

/// Registry of RNG stream-domain constants (repro-lint rule R1).
///
/// Every [`Rng::for_stream`] call site that XORs a domain tag into its
/// seed must take that tag from this table: `for_stream(seed ^ DOMAIN,
/// stream, counter)`. The table is the *whole* domain space — a
/// collision here would silently correlate two components' draws (e.g.
/// policy selection with fault injection), breaking the determinism
/// contract without failing a single test. Uniqueness is enforced twice:
/// by the `domain_values_are_unique` unit test below, and statically by
/// `cargo run -p repro-lint -- rust/src`, which also rejects bare
/// numeric domains and `STREAM_*`/`FLT_*` constants declared anywhere
/// else in the tree.
///
/// This file (including its unit tests, which construct raw streams on
/// purpose) is the one place raw stream keys are legal.
pub mod domains {
    /// Per-step policy-selection draws: keyed `(seed ^ STREAM_POLICY,
    /// epoch, step)` by the experiment loop. The value is the historical
    /// bare constant from `coordinator/experiment.rs`, registered
    /// bit-identically.
    pub const STREAM_POLICY: u64 = 0x9011C4;
    /// Client-side submit-retry jitter (serve protocol `retry_delay`).
    pub const STREAM_RETRY: u64 = 0x434C_545F_5254_5259; // "CLT_RTRY"
    /// Fault injection: worker panic at an epoch boundary.
    pub const FLT_PANIC: u64 = 0x464C_545F_50414E49; // "FLT_PANI"
    /// Fault injection: torn (half-written) registry persist.
    pub const FLT_TORN: u64 = 0x464C_545F_544F524E; // "FLT_TORN"
    /// Fault injection: connection dropped before the response.
    pub const FLT_DROP: u64 = 0x464C_545F_4452_4F50; // "FLT_DROP"

    /// The full table, in declaration order — what the uniqueness test
    /// and any future introspection (MEM-DFA feedback streams) walk.
    pub const ALL: &[(&str, u64)] = &[
        ("STREAM_POLICY", STREAM_POLICY),
        ("STREAM_RETRY", STREAM_RETRY),
        ("FLT_PANIC", FLT_PANIC),
        ("FLT_TORN", FLT_TORN),
        ("FLT_DROP", FLT_DROP),
    ];
}

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    spare_normal: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-experiment / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    /// Counter-based stream constructor: the generator state is a pure
    /// function of `(seed, stream, counter)` — no draw-history
    /// dependence. This is what keeps stochastic selection policies
    /// bit-identical at every `threads` setting: a decision's stream is
    /// keyed by *position* (epoch, step, shard), never by how many draws
    /// some other component consumed first. The three words are folded
    /// through SplitMix64 with distinct odd multipliers, so nearby keys
    /// (`counter`, `counter+1`) yield statistically independent streams.
    pub fn for_stream(seed: u64, stream: u64, counter: u64) -> Rng {
        let mut sm = seed;
        let mut s = [0u64; 4];
        sm ^= stream.wrapping_mul(0xA0761D6478BD642F);
        s[0] = splitmix64(&mut sm);
        sm ^= counter.wrapping_mul(0xE7037ED1A0B428DB);
        s[1] = splitmix64(&mut sm);
        s[2] = splitmix64(&mut sm);
        s[3] = splitmix64(&mut sm);
        Rng {
            s,
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform_f64();
            let u2 = self.uniform_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (sin, cos) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some((r * sin) as f32);
            return (r * cos) as f32;
        }
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free-enough for our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// `k` distinct indices drawn uniformly from [0, n) (randK policy).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        self.sample_without_replacement_into(n, k, &mut scratch, &mut out);
        out
    }

    /// [`Rng::sample_without_replacement`] into reusable buffers —
    /// identical draw sequence, no allocation once the buffers have
    /// capacity (`scratch` grows to `n`, `out` to `k`). The per-step
    /// selection path runs on this.
    pub fn sample_without_replacement_into(
        &mut self,
        n: usize,
        k: usize,
        scratch: &mut Vec<usize>,
        out: &mut Vec<usize>,
    ) {
        assert!(k <= n, "k={k} > n={n}");
        scratch.clear();
        scratch.extend(0..n);
        // partial Fisher–Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.below(n - i);
            scratch.swap(i, j);
        }
        out.clear();
        out.extend_from_slice(&scratch[..k]);
    }

    /// `k` distinct indices drawn ∝ `weights` without replacement via the
    /// Gumbel-top-k trick (weightedK policy, the paper's sampling mode).
    /// Zero-weight rows are never selected unless fewer than `k` rows have
    /// positive weight.
    pub fn weighted_sample_without_replacement(
        &mut self,
        weights: &[f32],
        k: usize,
    ) -> Vec<usize> {
        let mut keys = Vec::new();
        let mut out = Vec::new();
        self.weighted_sample_without_replacement_into(weights, k, &mut keys, &mut out);
        out
    }

    /// [`Rng::weighted_sample_without_replacement`] into reusable buffers
    /// — identical draw sequence, no allocation at capacity. The sort is
    /// `sort_unstable_by` (in-place, allocation-free) over a **total**
    /// order: key ties break on ascending row index. Ties are not
    /// hypothetical — every zero-weight row keys at `-inf` — and the
    /// index tie-break reproduces exactly what the historical stable
    /// sort did (keys are generated in index order), keeping the
    /// selected set index-stable across std versions and platforms, the
    /// same discipline as `top_k_indices`.
    pub fn weighted_sample_without_replacement_into(
        &mut self,
        weights: &[f32],
        k: usize,
        keys: &mut Vec<(f64, usize)>,
        out: &mut Vec<usize>,
    ) {
        let n = weights.len();
        assert!(k <= n, "k={k} > n={n}");
        keys.clear();
        keys.extend(weights.iter().enumerate().map(|(i, &w)| {
            let u = self.uniform_f64().max(1e-300);
            let gumbel = -(-u.ln()).ln();
            let logw = if w > 0.0 {
                (w as f64).ln()
            } else {
                f64::NEG_INFINITY
            };
            (logw + gumbel, i)
        }));
        keys.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        out.clear();
        out.extend(keys.iter().take(k).map(|&(_, i)| i));
    }

    /// `k` indices drawn ∝ `weights` WITH replacement (eq. (5) variant),
    /// by inverse-CDF on the cumulative weights.
    pub fn weighted_sample_with_replacement(
        &mut self,
        weights: &[f32],
        k: usize,
    ) -> Vec<usize> {
        let mut cdf = Vec::new();
        let mut out = Vec::new();
        self.weighted_sample_with_replacement_into(weights, k, &mut cdf, &mut out);
        out
    }

    /// [`Rng::weighted_sample_with_replacement`] into reusable buffers —
    /// identical draw sequence, no allocation at capacity.
    pub fn weighted_sample_with_replacement_into(
        &mut self,
        weights: &[f32],
        k: usize,
        cdf: &mut Vec<f64>,
        out: &mut Vec<usize>,
    ) {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        assert!(total > 0.0, "all weights are zero");
        cdf.clear();
        let mut acc = 0.0f64;
        for &w in weights {
            acc += w.max(0.0) as f64;
            cdf.push(acc);
        }
        out.clear();
        out.extend((0..k).map(|_| {
            let u = self.uniform_f64() * total;
            match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(weights.len() - 1),
            }
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.normal() as f64;
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_without_replacement_is_distinct_and_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let idx = r.sample_without_replacement(37, 11);
            assert_eq!(idx.len(), 11);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 11);
            assert!(idx.iter().all(|&i| i < 37));
        }
    }

    #[test]
    fn sample_without_replacement_full() {
        let mut r = Rng::new(4);
        let mut idx = r.sample_without_replacement(9, 9);
        idx.sort_unstable();
        assert_eq!(idx, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_without_replacement_prefers_heavy_rows() {
        let mut r = Rng::new(5);
        let w = [10.0f32, 10.0, 10.0, 0.01, 0.01, 0.01, 0.01, 0.01];
        let mut hits = [0usize; 8];
        for _ in 0..500 {
            for i in r.weighted_sample_without_replacement(&w, 3) {
                hits[i] += 1;
            }
        }
        let heavy: usize = hits[..3].iter().sum();
        let light: usize = hits[3..].iter().sum();
        assert!(heavy > 20 * light.max(1), "heavy={heavy} light={light}");
    }

    #[test]
    fn weighted_without_replacement_distinct() {
        let mut r = Rng::new(6);
        let w: Vec<f32> = (1..=20).map(|i| i as f32).collect();
        for _ in 0..50 {
            let idx = r.weighted_sample_without_replacement(&w, 7);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 7);
        }
    }

    #[test]
    fn weighted_without_replacement_breaks_zero_weight_ties_by_index() {
        // every zero-weight row keys at -inf; when k forces selection
        // into the dead rows, the tie must resolve by ascending index —
        // a total order, stable across std versions and platforms
        let w = [0.0f32, 0.0, 0.0, 0.0, 1.0, 0.0];
        let mut r = Rng::new(3);
        let idx = r.weighted_sample_without_replacement(&w, 4);
        assert_eq!(idx[0], 4, "the only positive weight wins");
        assert_eq!(&idx[1..], &[0, 1, 2], "-inf ties in index order");
    }

    #[test]
    fn weighted_with_replacement_frequency() {
        let mut r = Rng::new(7);
        let w = [1.0f32, 3.0];
        let mut hits = [0usize; 2];
        for i in r.weighted_sample_with_replacement(&w, 40000) {
            hits[i] += 1;
        }
        let frac = hits[1] as f64 / 40000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn counter_streams_are_pure_functions_of_their_key() {
        let mut a = Rng::for_stream(7, 3, 11);
        let mut b = Rng::for_stream(7, 3, 11);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // every key component matters
        let base = Rng::for_stream(7, 3, 11).next_u64();
        assert_ne!(Rng::for_stream(8, 3, 11).next_u64(), base);
        assert_ne!(Rng::for_stream(7, 4, 11).next_u64(), base);
        assert_ne!(Rng::for_stream(7, 3, 12).next_u64(), base);
    }

    #[test]
    fn adjacent_counter_streams_look_independent() {
        // crude independence check: mean of XOR-popcount over pairs
        let mut acc = 0u32;
        for c in 0..64u64 {
            let a = Rng::for_stream(0, 0, c).next_u64();
            let b = Rng::for_stream(0, 0, c + 1).next_u64();
            acc += (a ^ b).count_ones();
        }
        let mean = acc as f64 / 64.0;
        assert!((mean - 32.0).abs() < 4.0, "mean popcount {mean}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn domain_values_are_unique() {
        // the runtime twin of repro-lint rule R1: a duplicate value in
        // the registry correlates two components' stream domains
        for (i, (name_a, val_a)) in domains::ALL.iter().enumerate() {
            for (name_b, val_b) in &domains::ALL[i + 1..] {
                assert_ne!(
                    val_a, val_b,
                    "stream domains {name_a} and {name_b} collide on {val_a:#x}"
                );
                assert_ne!(name_a, name_b, "duplicate domain name {name_a}");
            }
        }
    }

    #[test]
    fn registered_domains_yield_distinct_streams() {
        // XOR-ing any two distinct registered domains into the same base
        // seed must produce decorrelated first draws
        let vals: Vec<u64> = domains::ALL.iter().map(|(_, v)| *v).collect();
        for (i, &a) in vals.iter().enumerate() {
            for &b in &vals[i + 1..] {
                assert_ne!(
                    Rng::for_stream(7 ^ a, 0, 0).next_u64(),
                    Rng::for_stream(7 ^ b, 0, 0).next_u64(),
                    "domains {a:#x} and {b:#x} produced identical streams"
                );
            }
        }
    }
}
