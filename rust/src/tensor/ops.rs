//! Compute kernels for the native path.
//!
//! `masked_outer` is the Rust twin of the Pallas `aop_outer` kernel — the
//! paper's approximate matrix product (eq. (4)/(5)). Two execution
//! regimes mirror DESIGN.md §8:
//!
//!   * **mask regime** — iterate all M rows with a per-row scale (used for
//!     numerics cross-checks against the HLO path);
//!   * **compaction regime** — iterate only the selected rows
//!     ([`masked_outer_compact`]), realizing the K/M FLOP reduction the
//!     paper claims; numerically identical for without-replacement
//!     policies since unselected scales are exactly 0.
//!
//! ## The 8-lane accumulation contract (§Perf pass, PR 4)
//!
//! Every kernel here is written as a fixed [`LANES`]-wide split loop:
//! eight explicit accumulators (or eight independent element streams),
//! a separate scalar tail loop for the `len % 8` remainder, and **no
//! value-dependent branches inside the lane loops** — so LLVM
//! auto-vectorizes them to AVX2/NEON width without needing
//! `-ffast-math`-style reassociation. The grouping of every reduction is
//! therefore part of each kernel's definition: it depends only on the
//! operand *shapes* (never on row-range position, thread count, or
//! runtime CPU features), which is what keeps the exec subsystem's
//! bit-identity-across-threads contract intact. Removing the historical
//! per-element `w == 0.0` skip branches is part of the same contract
//! (branch-free inner loops); the per-row `scale == 0.0` skip in the
//! mask-regime AOP stays — it is selection semantics (unselected rows
//! contribute exactly nothing, giving the mask regime its O(K·N·P)
//! cost), decided per row, not per lane.
//!
//! `matmul`/`matmul_tn` are cache-blocked with an ikj loop order so the
//! inner loop is a contiguous f32 AXPY the compiler auto-vectorizes.
//! Narrow-B shapes take a transposed-dot path; hot callers pass a cached
//! transpose through [`matmul_rows_bt`] so the per-call `transpose()` of
//! the historical narrow path disappears from steady-state steps.

use super::quant::AccumMode;
use super::Matrix;

/// Lane width of the split loops (f32 lanes of one AVX2 register; two
/// NEON registers). Changing it changes reduction groupings — and hence
/// the low-order bits of every curve — so it is a compile-time constant.
pub const LANES: usize = 8;

/// Cache-block edge (rows of A per block / rows of B per block).
const BLOCK: usize = 64;

/// Below this many B-columns the ikj inner loop is too narrow to
/// vectorize; switch to the transposed-dot path (§Perf pass, see
/// EXPERIMENTS.md — 3-4× on the paper's 784×10 shapes).
const NARROW_N: usize = 24;

/// Vectorizable dot product: eight independent accumulator lanes over
/// `chunks_exact(8)`, pairwise-combined, then a scalar tail — the
/// reduction stays in SIMD lanes despite float non-associativity.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let (a8, a_tail) = a.split_at(a.len() - a.len() % LANES);
    let (b8, b_tail) = b.split_at(a8.len());
    for (ai, bi) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ai[l] * bi[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (av, bv) in a_tail.iter().zip(b_tail.iter()) {
        s += av * bv;
    }
    s
}

/// [`dot`] with f64 accumulator lanes (§Mixed precision, `accum: f64`):
/// the **same 8-lane loop shape** — eight independent lanes over
/// `chunks_exact(8)`, pairwise combine, scalar tail — with every
/// accumulator widened to f64 and one rounding to f32 at the end. The
/// grouping is still a pure function of the operand length, so the
/// exec bit-identity contract holds per config.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let (a8, a_tail) = a.split_at(a.len() - a.len() % LANES);
    let (b8, b_tail) = b.split_at(a8.len());
    for (ai, bi) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ai[l] as f64 * bi[l] as f64;
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (av, bv) in a_tail.iter().zip(b_tail.iter()) {
        s += *av as f64 * *bv as f64;
    }
    s as f32
}

/// [`dot`] with Kahan-compensated f32 lanes (`accum: kahan`): eight
/// accumulator lanes each carrying a compensation term, combined
/// pairwise (sums then compensations) at the end. Same loop shape,
/// same determinism contract as [`dot_f64`].
#[inline]
pub fn dot_kahan(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut comp = [0.0f32; LANES];
    let (a8, a_tail) = a.split_at(a.len() - a.len() % LANES);
    let (b8, b_tail) = b.split_at(a8.len());
    for (ai, bi) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        for l in 0..LANES {
            let y = ai[l] * bi[l] - comp[l];
            let t = acc[l] + y;
            comp[l] = (t - acc[l]) - y;
            acc[l] = t;
        }
    }
    let s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    let c = (comp[0] + comp[1]) + (comp[2] + comp[3]) + ((comp[4] + comp[5]) + (comp[6] + comp[7]));
    let mut sum = s - c;
    let mut tail_comp = 0.0f32;
    for (av, bv) in a_tail.iter().zip(b_tail.iter()) {
        let y = av * bv - tail_comp;
        let t = sum + y;
        tail_comp = (t - sum) - y;
        sum = t;
    }
    sum
}

/// Accumulation-mode dispatch for the dot kernels. `F32` is byte-for-
/// byte the seed [`dot`] — selecting it changes nothing.
#[inline]
pub fn dot_acc(a: &[f32], b: &[f32], mode: AccumMode) -> f32 {
    match mode {
        AccumMode::F32 => dot(a, b),
        AccumMode::F64 => dot_f64(a, b),
        AccumMode::Kahan => dot_kahan(a, b),
    }
}

/// Fixed-order reduction of stacked row-major partials with **f64**
/// accumulators: `dst[e] = Σ_part parts[part*stride + e]`, parts taken
/// in ascending index order (the exec shard-reduction order), elements
/// processed in [`LANES`]-wide chunks with a persistent f64 accumulator
/// per element and a single rounding to f32 at the end. `use_part`
/// gates each partial (the compaction regime skips empty shards).
///
/// Note the widening only matters because the accumulator *persists*
/// across the whole partial chain — adding one f32 to an f64 and
/// rounding immediately would reproduce f32 bits exactly.
pub fn sum_parts_f64(
    dst: &mut [f32],
    parts: &[f32],
    stride: usize,
    use_part: impl Fn(usize) -> bool,
) {
    assert_eq!(dst.len(), stride, "destination is one stride");
    assert_eq!(parts.len() % stride.max(1), 0, "parts are whole strides");
    let n_parts = if stride == 0 { 0 } else { parts.len() / stride };
    let mut e = 0usize;
    while e < stride {
        let w = (stride - e).min(LANES);
        let mut acc = [0.0f64; LANES];
        for si in 0..n_parts {
            if !use_part(si) {
                continue;
            }
            let p = &parts[si * stride + e..si * stride + e + w];
            for l in 0..w {
                acc[l] += p[l] as f64;
            }
        }
        for l in 0..w {
            dst[e + l] = acc[l] as f32;
        }
        e += w;
    }
}

/// [`sum_parts_f64`] with Kahan-compensated f32 accumulators instead of
/// f64 — same fixed part order, same lane chunking.
pub fn sum_parts_kahan(
    dst: &mut [f32],
    parts: &[f32],
    stride: usize,
    use_part: impl Fn(usize) -> bool,
) {
    assert_eq!(dst.len(), stride, "destination is one stride");
    assert_eq!(parts.len() % stride.max(1), 0, "parts are whole strides");
    let n_parts = if stride == 0 { 0 } else { parts.len() / stride };
    let mut e = 0usize;
    while e < stride {
        let w = (stride - e).min(LANES);
        let mut acc = [0.0f32; LANES];
        let mut comp = [0.0f32; LANES];
        for si in 0..n_parts {
            if !use_part(si) {
                continue;
            }
            let p = &parts[si * stride + e..si * stride + e + w];
            for l in 0..w {
                let y = p[l] - comp[l];
                let t = acc[l] + y;
                comp[l] = (t - acc[l]) - y;
                acc[l] = t;
            }
        }
        for l in 0..w {
            dst[e + l] = acc[l];
        }
        e += w;
    }
}

/// Contiguous `y += alpha * x`, 8-lane split + scalar tail. Elementwise
/// (no cross-lane reduction), so the split changes no bits — it only
/// hands the compiler a branch-free fixed-width body.
#[inline]
pub(crate) fn axpy_slice(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let split = y.len() - y.len() % LANES;
    let (y8, y_tail) = y.split_at_mut(split);
    let (x8, x_tail) = x.split_at(split);
    for (yc, xc) in y8.chunks_exact_mut(LANES).zip(x8.chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] += alpha * xc[l];
        }
    }
    for (yv, &xv) in y_tail.iter_mut().zip(x_tail.iter()) {
        *yv += alpha * xv;
    }
}

/// `A (m×k) @ B (k×n)` — blocked ikj matmul; narrow-B shapes (the paper's
/// 16×1 and 784×10 heads) take a transposed-dot path instead.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let (_, n) = b.shape();
    let mut out = Matrix::zeros(m, n);
    matmul_rows(a, b, 0..m, out.data_mut());
    out
}

/// Row-range matmul: computes output rows `rows` of `A @ B` into `out`
/// (a `rows.len() × n` row-major block). Every output row is the same
/// sequence of float ops regardless of the range it is computed through
/// — both the path choice (narrow-B vs blocked ikj) and the k-blocking
/// depend only on the operand shapes — so sharded and whole-matrix
/// products are bitwise identical per row. This is the primitive the
/// `exec` subsystem's data-parallel forward/backward passes are built on.
///
/// The narrow-B path transposes `b` on every call; per-step hot paths
/// must use [`matmul_rows_bt`] with a cached transpose instead.
pub fn matmul_rows(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f32]) {
    let (_, ka) = a.shape();
    let (_, n) = b.shape();
    if narrow_b(ka, n) {
        let bt = b.transpose();
        return matmul_rows_bt(a, b, &bt, rows, out);
    }
    matmul_rows_blocked(a, b, rows, out);
}

/// [`matmul_rows`] with a caller-cached `bt = b.transpose()` — the
/// narrow-B path reads `bt` directly, so no transpose happens per call.
/// Bitwise identical to [`matmul_rows`] (the transposed values are the
/// same floats; the path choice is the same shape-only predicate).
pub fn matmul_rows_bt(
    a: &Matrix,
    b: &Matrix,
    bt: &Matrix,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul inner dims: {ka} vs {kb}");
    assert_eq!(bt.shape(), (n, kb), "bt must be b transposed");
    assert!(rows.end <= m, "row range {rows:?} out of {m}");
    assert_eq!(out.len(), rows.len() * n, "output block size");
    if narrow_b(ka, n) {
        // every output element is a contiguous k-length dot at SIMD width
        for (oi, i) in rows.enumerate() {
            let arow = a.row(i);
            let orow = &mut out[oi * n..(oi + 1) * n];
            for (j, ov) in orow.iter_mut().enumerate() {
                *ov = dot(arow, bt.row(j));
            }
        }
        return;
    }
    matmul_rows_blocked(a, b, rows, out);
}

/// Whether the transposed-dot path pays for a `(· × k) @ (k × n)`.
#[inline]
fn narrow_b(k: usize, n: usize) -> bool {
    n <= NARROW_N && k >= 32
}

/// Whether [`matmul_rows_bt`] will actually read the cached transpose
/// for a `(· × k) @ (k × n)` product — exported so callers can skip
/// warming (and re-refreshing) a transpose cache no kernel will ever
/// read (e.g. a wide non-narrow layer with no backward consumer).
#[inline]
pub fn matmul_uses_bt(k: usize, n: usize) -> bool {
    narrow_b(k, n)
}

/// The blocked ikj body shared by both entry points.
fn matmul_rows_blocked(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f32]) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul inner dims: {ka} vs {kb}");
    assert!(rows.end <= m, "row range {rows:?} out of {m}");
    assert_eq!(out.len(), rows.len() * n, "output block size");
    out.fill(0.0);
    for k0 in (0..ka).step_by(BLOCK) {
        let k1 = (k0 + BLOCK).min(ka);
        for (oi, i) in rows.clone().enumerate() {
            let arow = a.row(i);
            let orow = &mut out[oi * n..(oi + 1) * n];
            for k in k0..k1 {
                let aik = arow[k];
                let brow = b.row(k);
                axpy_slice(orow, aik, brow);
            }
        }
    }
}

/// `A^T (k×m)^T=(m? ) ...` — computes `A^T @ B` for `A (m×n)`, `B (m×p)`
/// without materializing `A^T`: `out[n×p] = sum_m A[m,n] B[m,p]`.
///
/// This is exactly the all-rows outer-product sum of eq. (3) and the
/// baseline the AOP approximates.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    let (m2, p) = b.shape();
    assert_eq!(m, m2, "matmul_tn leading dims: {m} vs {m2}");
    if aop_transposed(n, p) {
        let mut out_t = Matrix::zeros(p, n);
        for r in 0..m {
            accumulate_outer_t(out_t.data_mut(), n, a.row(r), b.row(r), 1.0);
        }
        return out_t.transpose();
    }
    let mut out = Matrix::zeros(n, p);
    for r in 0..m {
        accumulate_outer(out.data_mut(), p, a.row(r), b.row(r), 1.0);
    }
    out
}

/// Rank-1 update `out += s * x ⊗ g` into a flat row-major `n × p` block
/// (`p = g.len()`). Branch-free inner loops: a zero `s·x[n]` contributes
/// `+0.0` products (lane contract above). Rows with `s == 0.0` are
/// skipped wholesale — selection semantics, not a lane branch.
#[inline]
fn accumulate_outer(out: &mut [f32], p: usize, x: &[f32], g: &[f32], s: f32) {
    debug_assert_eq!(out.len(), x.len() * p);
    debug_assert_eq!(g.len(), p);
    if s == 0.0 {
        return;
    }
    for (orow, &xv) in out.chunks_exact_mut(p).zip(x.iter()) {
        axpy_slice(orow, s * xv, g);
    }
}

/// Transposed rank-1 update: `out_t[p, n] += (s·g[p]) * x[n]` into a flat
/// row-major `p × n` block (`n = x.len()`) — the inner loop runs over the
/// long N axis contiguously, which is what makes the paper's
/// (N=784, P=10) head shape vectorize (§Perf pass).
#[inline]
fn accumulate_outer_t(out_t: &mut [f32], n: usize, x: &[f32], g: &[f32], s: f32) {
    debug_assert_eq!(out_t.len(), g.len() * n);
    debug_assert_eq!(x.len(), n);
    if s == 0.0 {
        return;
    }
    for (orow, &gv) in out_t.chunks_exact_mut(n).zip(g.iter()) {
        axpy_slice(orow, s * gv, x);
    }
}

/// Whether the AOP accumulation for an `(n, p)` layer runs in the
/// transposed `p × n` layout. A pure function of the operand shape —
/// exported so workspace owners can size partial buffers and apply the
/// summed update without an intermediate `transpose()` copy
/// (`Matrix::sub_transposed`).
#[inline]
pub fn aop_transposed(n: usize, p: usize) -> bool {
    p < n && p <= NARROW_N && n >= 64
}

/// Rows (as a flat length) of the AOP accumulation layout for `(n, p)`:
/// `(p, n)` when transposed, `(n, p)` otherwise.
#[inline]
pub fn aop_layout(n: usize, p: usize) -> (usize, usize) {
    if aop_transposed(n, p) {
        (p, n)
    } else {
        (n, p)
    }
}

/// Mask-regime AOP: `out[n,p] = sum_m scale[m] * x[m,n] * g[m,p]`.
/// Mirrors the Pallas kernel (same reduction over m; the accumulation
/// layout is an implementation detail below f32 tolerance).
pub fn masked_outer(x: &Matrix, g: &Matrix, scale: &[f32]) -> Matrix {
    masked_outer_range(x, g, scale, 0..x.rows())
}

/// Row-range mask-regime AOP partial into a caller-owned buffer in the
/// [`aop_layout`] of the *full* operand shape (zeroed first, then
/// accumulated in ascending row order). This is the zero-allocation
/// primitive the workspace-resident training step shards on; every
/// shard — and the whole-batch call — applies the same per-term float
/// ops regardless of where its row range sits.
pub fn masked_outer_range_into(
    x: &Matrix,
    g: &Matrix,
    scale: &[f32],
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let (m, n) = x.shape();
    let (m2, p) = g.shape();
    assert_eq!(m, m2);
    assert_eq!(scale.len(), m);
    assert!(rows.end <= m, "row range {rows:?} out of {m}");
    assert_eq!(out.len(), n * p, "partial buffer size");
    out.fill(0.0);
    if aop_transposed(n, p) {
        for r in rows {
            accumulate_outer_t(out, n, x.row(r), g.row(r), scale[r]);
        }
    } else {
        for r in rows {
            accumulate_outer(out, p, x.row(r), g.row(r), scale[r]);
        }
    }
}

/// Compaction-regime AOP partial into a caller-owned [`aop_layout`]
/// buffer: only the `indices` (ascending, with per-row `scale`) that fall
/// inside `rows` are touched. Returns how many rows contributed — **0
/// means the buffer was left untouched** (not zeroed): the shard adds
/// nothing and the caller must skip it in the reduction, which is what
/// spares empty shards a hot-path memset of the whole `n × p` partial.
/// No per-call allocation: the in-range index window is found by binary
/// search on the ascending `indices`.
pub fn masked_outer_compact_range_into(
    x: &Matrix,
    g: &Matrix,
    indices: &[usize],
    scale: &[f32],
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) -> usize {
    let (m, n) = x.shape();
    let (m2, p) = g.shape();
    assert_eq!(m, m2);
    assert_eq!(scale.len(), m);
    assert_eq!(out.len(), n * p, "partial buffer size");
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices ascending");
    let lo = indices.partition_point(|&i| i < rows.start);
    let hi = indices.partition_point(|&i| i < rows.end);
    if lo == hi {
        return 0;
    }
    out.fill(0.0);
    let window = &indices[lo..hi];
    if aop_transposed(n, p) {
        for &r in window {
            accumulate_outer_t(out, n, x.row(r), g.row(r), scale[r]);
        }
    } else {
        for &r in window {
            accumulate_outer(out, p, x.row(r), g.row(r), scale[r]);
        }
    }
    window.len()
}

/// Row-range mask-regime AOP returning an owned `n × p` matrix — the
/// allocating convenience wrapper over [`masked_outer_range_into`]
/// (analysis, props, and benches; the training step uses the `_into`
/// form on workspace buffers).
pub fn masked_outer_range(
    x: &Matrix,
    g: &Matrix,
    scale: &[f32],
    rows: std::ops::Range<usize>,
) -> Matrix {
    let (_, n) = x.shape();
    let (_, p) = g.shape();
    let (a, b) = aop_layout(n, p);
    let mut out = Matrix::zeros(a, b);
    masked_outer_range_into(x, g, scale, rows, out.data_mut());
    if aop_transposed(n, p) {
        out.transpose()
    } else {
        out
    }
}

/// Compaction-regime AOP: only the rows in `selected` (with their scales)
/// are touched — cost `O(K·N·P)` instead of `O(M·N·P)`, the paper's
/// computational-reduction claim.
pub fn masked_outer_compact(x: &Matrix, g: &Matrix, selected: &[(usize, f32)]) -> Matrix {
    let (_, n) = x.shape();
    let (_, p) = g.shape();
    if aop_transposed(n, p) {
        let mut out_t = Matrix::zeros(p, n);
        for &(r, s) in selected {
            accumulate_outer_t(out_t.data_mut(), n, x.row(r), g.row(r), s);
        }
        return out_t.transpose();
    }
    let mut out = Matrix::zeros(n, p);
    for &(r, s) in selected {
        accumulate_outer(out.data_mut(), p, x.row(r), g.row(r), s);
    }
    out
}

/// Per-row rescale (memory update; Rust twin of the Pallas `row_scale`).
pub fn row_scale(a: &Matrix, keep: &[f32]) -> Matrix {
    let (m, _) = a.shape();
    assert_eq!(keep.len(), m);
    // lint: allow(hot-path-alloc) Pallas-twin reference path; the step updates memory in place via keep_rows workspace kernels
    let mut out = a.clone();
    for r in 0..m {
        let k = keep[r];
        for v in out.row_mut(r) {
            *v *= k;
        }
    }
    out
}

/// Row-norm-product policy scores (Rust twin of the Pallas `scores`):
/// `s_m = ||x[m,:]|| * ||g[m,:]||`.
pub fn norm_product_scores(x: &Matrix, g: &Matrix) -> Vec<f32> {
    assert_eq!(x.rows(), g.rows());
    x.row_norms()
        .into_iter()
        .zip(g.row_norms())
        .map(|(a, b)| a * b)
        // lint: allow(hot-path-alloc) Pallas-twin reference path; the step scores rows into workspace buffers via score_rows_acc
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    /// O(mnk) naive reference.
    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|x| a[(i, x)] * b[(x, j)]).sum())
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (64, 64, 64), (100, 130, 70)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let d = matmul(&a, &b).max_abs_diff(&naive_matmul(&a, &b));
            assert!(d < 1e-3, "({m},{k},{n}): {d}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randm(&mut rng, 17, 17);
        let eye = Matrix::from_fn(17, 17, |r, c| (r == c) as u32 as f32);
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn dot_matches_f64_reference() {
        let mut rng = Rng::new(11);
        for len in [0usize, 1, 7, 8, 9, 63, 64, 100, 1000] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let refd: f64 = a
                .iter()
                .zip(b.iter())
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            let d = (dot(&a, &b) as f64 - refd).abs();
            let tol = 1e-4 * (1.0 + refd.abs()) * (len.max(1) as f64).sqrt();
            assert!(d < tol, "len={len}: {d}");
        }
    }

    #[test]
    fn widened_dots_track_f64_reference_tighter() {
        let mut rng = Rng::new(12);
        for len in [1usize, 8, 9, 64, 1000, 4096] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let refd: f64 = a
                .iter()
                .zip(b.iter())
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            // the f64-lane kernel is within one f32 rounding of the
            // serial f64 sum (only the final cast and lane grouping
            // differ); kahan stays within a few ulps of it too
            let d64 = (dot_f64(&a, &b) as f64 - refd).abs();
            assert!(d64 <= 1e-5 * (1.0 + refd.abs()), "len={len}: {d64}");
            let dk = (dot_kahan(&a, &b) as f64 - refd).abs();
            assert!(dk <= 1e-4 * (1.0 + refd.abs()), "len={len}: {dk}");
            // plain-f32 dispatch is bit-identical to the seed kernel
            assert_eq!(dot_acc(&a, &b, AccumMode::F32).to_bits(), dot(&a, &b).to_bits());
        }
    }

    #[test]
    fn sum_parts_widened_match_f64_reference() {
        let mut rng = Rng::new(13);
        let (n_parts, stride) = (7usize, 83usize);
        let parts: Vec<f32> = (0..n_parts * stride).map(|_| rng.normal()).collect();
        let skip = |si: usize| si != 2; // exercise the compaction gate
        let mut refd = vec![0.0f64; stride];
        for si in 0..n_parts {
            if !skip(si) {
                continue;
            }
            for e in 0..stride {
                refd[e] += parts[si * stride + e] as f64;
            }
        }
        let mut d64 = vec![0.0f32; stride];
        sum_parts_f64(&mut d64, &parts, stride, skip);
        let mut dk = vec![0.0f32; stride];
        sum_parts_kahan(&mut dk, &parts, stride, skip);
        for e in 0..stride {
            assert_eq!(d64[e], refd[e] as f32, "e={e}");
            assert!((dk[e] as f64 - refd[e]).abs() <= 1e-5 * (1.0 + refd[e].abs()), "e={e}");
        }
    }

    #[test]
    fn matmul_tn_equals_transpose_then_matmul() {
        let mut rng = Rng::new(2);
        for (m, n, p) in [(144, 16, 1), (64, 784, 10), (33, 20, 11)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let d = matmul_tn(&x, &g).max_abs_diff(&matmul(&x.transpose(), &g));
            assert!(d < 1e-3, "({m},{n},{p}): {d}");
        }
    }

    #[test]
    fn matmul_rows_is_bitwise_slice_of_matmul() {
        let mut rng = Rng::new(42);
        // both the narrow-B dot path (k>=32, n<=24) and the blocked path
        for (m, k, n) in [(20, 40, 3), (64, 784, 10), (30, 12, 30), (7, 5, 2)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let full = matmul(&a, &b);
            for (lo, hi) in [(0, m), (0, m / 2), (m / 2, m), (1, m.min(5))] {
                let mut out = vec![f32::NAN; (hi - lo) * n];
                matmul_rows(&a, &b, lo..hi, &mut out);
                assert_eq!(
                    &out[..],
                    &full.data()[lo * n..hi * n],
                    "({m},{k},{n}) rows {lo}..{hi}"
                );
            }
        }
    }

    #[test]
    fn matmul_rows_bt_is_bitwise_matmul_rows() {
        let mut rng = Rng::new(44);
        // narrow (cached-transpose) and blocked (bt ignored) paths
        for (m, k, n) in [(20, 40, 3), (64, 784, 10), (30, 12, 30)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let bt = b.transpose();
            for (lo, hi) in [(0, m), (m / 3, m / 2 + 1)] {
                let mut plain = vec![f32::NAN; (hi - lo) * n];
                matmul_rows(&a, &b, lo..hi, &mut plain);
                let mut cached = vec![f32::NAN; (hi - lo) * n];
                matmul_rows_bt(&a, &b, &bt, lo..hi, &mut cached);
                assert_eq!(plain, cached, "({m},{k},{n}) rows {lo}..{hi}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "bt must be b transposed")]
    fn matmul_rows_bt_rejects_wrong_cache() {
        let a = Matrix::zeros(2, 40);
        let b = Matrix::zeros(40, 3);
        let mut out = vec![0.0; 6];
        matmul_rows_bt(&a, &b, &Matrix::zeros(40, 3), 0..2, &mut out);
    }

    #[test]
    fn masked_outer_range_partials_sum_to_full() {
        let mut rng = Rng::new(43);
        for (m, n, p) in [(30, 9, 5), (64, 784, 10)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let scale: Vec<f32> = (0..m).map(|i| ((i % 4) as f32) * 0.5).collect();
            let full = masked_outer(&x, &g, &scale);
            let mut acc = Matrix::zeros(n, p);
            for lo in (0..m).step_by(16) {
                let hi = (lo + 16).min(m);
                acc.axpy(1.0, &masked_outer_range(&x, &g, &scale, lo..hi));
            }
            assert!(acc.max_abs_diff(&full) < 1e-4, "({m},{n},{p})");
        }
    }

    #[test]
    fn masked_outer_range_into_matches_owned_in_both_layouts() {
        let mut rng = Rng::new(45);
        // (9, 5): standard layout; (784, 10): transposed layout
        for (m, n, p) in [(30usize, 9usize, 5usize), (40, 784, 10)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let scale: Vec<f32> = (0..m).map(|i| ((i % 3) as f32) * 0.5).collect();
            let (a, b) = aop_layout(n, p);
            for (lo, hi) in [(0, m), (5, m - 3)] {
                let owned = masked_outer_range(&x, &g, &scale, lo..hi);
                let mut buf = vec![f32::NAN; n * p];
                masked_outer_range_into(&x, &g, &scale, lo..hi, &mut buf);
                let flat = Matrix::from_vec(a, b, buf);
                let flat_np = if aop_transposed(n, p) {
                    flat.transpose()
                } else {
                    flat
                };
                assert_eq!(flat_np.data(), owned.data(), "({m},{n},{p}) {lo}..{hi}");
            }
        }
    }

    #[test]
    fn compact_range_into_filters_by_binary_search() {
        let mut rng = Rng::new(46);
        let (m, n, p) = (25usize, 8usize, 6usize);
        let x = randm(&mut rng, m, n);
        let g = randm(&mut rng, m, p);
        let indices = [1usize, 7, 8, 15, 24];
        let mut scale = vec![0.0f32; m];
        for &i in &indices {
            scale[i] = 1.0 + i as f32 * 0.1;
        }
        // partials over a 16-row grid must sum to the mask-regime result
        let full = masked_outer(&x, &g, &scale);
        let mut acc = Matrix::zeros(n, p);
        let mut contributed = 0usize;
        for lo in (0..m).step_by(16) {
            let hi = (lo + 16).min(m);
            let mut buf = vec![f32::NAN; n * p];
            let cnt = masked_outer_compact_range_into(&x, &g, &indices, &scale, lo..hi, &mut buf);
            contributed += cnt;
            acc.axpy(1.0, &Matrix::from_vec(n, p, buf));
        }
        assert_eq!(contributed, indices.len());
        assert!(acc.max_abs_diff(&full) < 1e-4);
        // a range with no selected rows reports 0 and leaves the buffer
        // untouched (the caller's contract is to skip it)
        let mut buf = vec![f32::NAN; n * p];
        let cnt = masked_outer_compact_range_into(&x, &g, &indices, &scale, 2..7, &mut buf);
        assert_eq!(cnt, 0);
        assert!(buf.iter().all(|v| v.is_nan()), "untouched on empty window");
    }

    #[test]
    fn masked_outer_range_equals_mask_restricted_to_range() {
        // the kernel-path property: restricting the row range is bitwise
        // the same as zeroing the scales outside it — accumulation layout
        // and per-term ops depend only on the operand shapes, never on
        // where the range sits
        let mut rng = Rng::new(47);
        for (m, n, p) in [(30usize, 9usize, 5usize), (48, 784, 10)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let scale: Vec<f32> = (0..m).map(|i| 0.25 + (i % 5) as f32).collect();
            for (lo, hi) in [(0, m / 2), (m / 3, m), (4, 5)] {
                let ranged = masked_outer_range(&x, &g, &scale, lo..hi);
                let mut masked_scale = vec![0.0f32; m];
                masked_scale[lo..hi].copy_from_slice(&scale[lo..hi]);
                let masked = masked_outer(&x, &g, &masked_scale);
                assert_eq!(ranged.data(), masked.data(), "({m},{n},{p}) {lo}..{hi}");
            }
        }
    }

    #[test]
    fn masked_outer_full_mask_is_matmul_tn() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 48, 12);
        let g = randm(&mut rng, 48, 7);
        let ones = vec![1.0f32; 48];
        assert!(masked_outer(&x, &g, &ones).max_abs_diff(&matmul_tn(&x, &g)) < 1e-4);
    }

    #[test]
    fn masked_outer_zero_mask_is_zero() {
        let mut rng = Rng::new(4);
        let x = randm(&mut rng, 10, 4);
        let g = randm(&mut rng, 10, 3);
        let out = masked_outer(&x, &g, &vec![0.0; 10]);
        assert_eq!(out, Matrix::zeros(4, 3));
    }

    #[test]
    fn masked_outer_complement_decomposition() {
        // eq. (7) identity: masked(s) + masked(1-s) == full product
        let mut rng = Rng::new(5);
        let x = randm(&mut rng, 30, 9);
        let g = randm(&mut rng, 30, 5);
        let mask: Vec<f32> = (0..30).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let inv: Vec<f32> = mask.iter().map(|v| 1.0 - v).collect();
        let sum = masked_outer(&x, &g, &mask).add(&masked_outer(&x, &g, &inv));
        assert!(sum.max_abs_diff(&matmul_tn(&x, &g)) < 1e-4);
    }

    #[test]
    fn compact_equals_mask_regime() {
        let mut rng = Rng::new(6);
        let x = randm(&mut rng, 25, 8);
        let g = randm(&mut rng, 25, 6);
        let mut scale = vec![0.0f32; 25];
        let selected: Vec<(usize, f32)> = [(3, 1.0), (7, 2.5), (24, 0.5)].to_vec();
        for &(i, s) in &selected {
            scale[i] = s;
        }
        let a = masked_outer(&x, &g, &scale);
        let b = masked_outer_compact(&x, &g, &selected);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn single_row_outer_is_rank_one() {
        let x = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let g = Matrix::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        let out = masked_outer_compact(&x, &g, &[(1, 1.0)]);
        let expect = Matrix::from_vec(3, 2, vec![120.0, 160.0, 150.0, 200.0, 180.0, 240.0]);
        assert!(out.max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn row_scale_semantics() {
        let a = Matrix::from_fn(3, 2, |r, c| (r * 2 + c + 1) as f32);
        let out = row_scale(&a, &[1.0, 0.0, 2.0]);
        assert_eq!(out.row(0), a.row(0));
        assert_eq!(out.row(1), &[0.0, 0.0]);
        assert_eq!(out.row(2), &[10.0, 12.0]);
    }

    #[test]
    fn scores_match_definition() {
        let mut rng = Rng::new(7);
        let x = randm(&mut rng, 12, 5);
        let g = randm(&mut rng, 12, 3);
        let s = norm_product_scores(&x, &g);
        for m in 0..12 {
            let xn: f32 = x.row(m).iter().map(|v| v * v).sum::<f32>().sqrt();
            let gn: f32 = g.row(m).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((s[m] - xn * gn).abs() < 1e-5);
        }
    }
}
