//! Compute kernels for the native path.
//!
//! `masked_outer` is the Rust twin of the Pallas `aop_outer` kernel — the
//! paper's approximate matrix product (eq. (4)/(5)). Two execution
//! regimes mirror DESIGN.md §8:
//!
//!   * **mask regime** — iterate all M rows with a per-row scale (used for
//!     numerics cross-checks against the HLO path);
//!   * **compaction regime** — iterate only the selected rows
//!     ([`masked_outer_compact`]), realizing the K/M FLOP reduction the
//!     paper claims; numerically identical for without-replacement
//!     policies since unselected scales are exactly 0.
//!
//! `matmul`/`matmul_tn` are cache-blocked with an ikj loop order so the
//! inner loop is a contiguous f32 AXPY the compiler auto-vectorizes.

use super::Matrix;

/// Cache-block edge (rows of A per block / rows of B per block).
const BLOCK: usize = 64;

/// Below this many B-columns the ikj inner loop is too narrow to
/// vectorize; switch to the transposed-dot path (§Perf pass, see
/// EXPERIMENTS.md — 3-4× on the paper's 784×10 shapes).
const NARROW_N: usize = 24;

/// Vectorizable dot product: 8 independent accumulators so the compiler
/// can keep the reduction in SIMD lanes despite float non-associativity.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let ai = &a[c * 8..c * 8 + 8];
        let bi = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += ai[l] * bi[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Contiguous `y += alpha * x` (auto-vectorizes).
#[inline]
fn axpy_slice(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

/// `A (m×k) @ B (k×n)` — blocked ikj matmul; narrow-B shapes (the paper's
/// 16×1 and 784×10 heads) take a transposed-dot path instead.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let (_, n) = b.shape();
    let mut out = Matrix::zeros(m, n);
    matmul_rows(a, b, 0..m, out.data_mut());
    out
}

/// Row-range matmul: computes output rows `rows` of `A @ B` into `out`
/// (a `rows.len() × n` row-major block). Every output row is the same
/// sequence of float ops regardless of the range it is computed through
/// — both the path choice (narrow-B vs blocked ikj) and the k-blocking
/// depend only on the operand shapes — so sharded and whole-matrix
/// products are bitwise identical per row. This is the primitive the
/// `exec` subsystem's data-parallel forward/backward passes are built on.
pub fn matmul_rows(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f32]) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul inner dims: {ka} vs {kb}");
    assert!(rows.end <= m, "row range {rows:?} out of {m}");
    assert_eq!(out.len(), rows.len() * n, "output block size");
    if n <= NARROW_N && ka >= 32 {
        // transpose B once (k·n traffic), then every output element is a
        // contiguous k-length dot that runs at SIMD width
        let bt = b.transpose();
        for (oi, i) in rows.enumerate() {
            let arow = a.row(i);
            let orow = &mut out[oi * n..(oi + 1) * n];
            for j in 0..n {
                orow[j] = dot(arow, bt.row(j));
            }
        }
        return;
    }
    out.fill(0.0);
    for k0 in (0..ka).step_by(BLOCK) {
        let k1 = (k0 + BLOCK).min(ka);
        for (oi, i) in rows.clone().enumerate() {
            let arow = a.row(i);
            let orow = &mut out[oi * n..(oi + 1) * n];
            for k in k0..k1 {
                let aik = arow[k];
                let brow = b.row(k);
                axpy_slice(orow, aik, brow);
            }
        }
    }
}

/// `A^T (k×m)^T=(m? ) ...` — computes `A^T @ B` for `A (m×n)`, `B (m×p)`
/// without materializing `A^T`: `out[n×p] = sum_m A[m,n] B[m,p]`.
///
/// This is exactly the all-rows outer-product sum of eq. (3) and the
/// baseline the AOP approximates.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    let (m2, p) = b.shape();
    assert_eq!(m, m2, "matmul_tn leading dims: {m} vs {m2}");
    if use_transposed_aop(n, p) {
        let mut out_t = Matrix::zeros(p, n);
        for r in 0..m {
            accumulate_outer_t(&mut out_t, a.row(r), b.row(r), 1.0);
        }
        return out_t.transpose();
    }
    let mut out = Matrix::zeros(n, p);
    for r in 0..m {
        accumulate_outer(&mut out, a.row(r), b.row(r), 1.0);
    }
    out
}

/// Rank-1 update `out += s * x ⊗ g` with contiguous inner loop.
#[inline]
fn accumulate_outer(out: &mut Matrix, x: &[f32], g: &[f32], s: f32) {
    debug_assert_eq!(out.shape(), (x.len(), g.len()));
    if s == 0.0 {
        return;
    }
    for (n, &xv) in x.iter().enumerate() {
        let w = s * xv;
        if w == 0.0 {
            continue;
        }
        axpy_slice(out.row_mut(n), w, g);
    }
}

/// Transposed rank-1 update: `out_t[p, n] += (s·g[p]) * x[n]` — the inner
/// loop runs over the long N axis contiguously, which is what makes the
/// paper's (N=784, P=10) head shape vectorize (§Perf pass).
#[inline]
fn accumulate_outer_t(out_t: &mut Matrix, x: &[f32], g: &[f32], s: f32) {
    debug_assert_eq!(out_t.shape(), (g.len(), x.len()));
    if s == 0.0 {
        return;
    }
    for (p, &gv) in g.iter().enumerate() {
        let w = s * gv;
        if w == 0.0 {
            continue;
        }
        axpy_slice(out_t.row_mut(p), w, x);
    }
}

/// Whether the transposed accumulation layout pays for (n, p).
#[inline]
fn use_transposed_aop(n: usize, p: usize) -> bool {
    p < n && p <= NARROW_N && n >= 64
}

/// Mask-regime AOP: `out[n,p] = sum_m scale[m] * x[m,n] * g[m,p]`.
/// Mirrors the Pallas kernel (same reduction over m; the accumulation
/// layout is an implementation detail below f32 tolerance).
pub fn masked_outer(x: &Matrix, g: &Matrix, scale: &[f32]) -> Matrix {
    masked_outer_range(x, g, scale, 0..x.rows())
}

/// Row-range mask-regime AOP: the partial sum over `rows` only — the
/// shard partial the `exec` subsystem reduces in fixed shard order. The
/// accumulation layout (transposed or not) is decided from the *full*
/// operand shape, so every shard—and the whole-batch call—applies the
/// same per-term float ops.
pub fn masked_outer_range(
    x: &Matrix,
    g: &Matrix,
    scale: &[f32],
    rows: std::ops::Range<usize>,
) -> Matrix {
    let (m, n) = x.shape();
    let (m2, p) = g.shape();
    assert_eq!(m, m2);
    assert_eq!(scale.len(), m);
    assert!(rows.end <= m, "row range {rows:?} out of {m}");
    if use_transposed_aop(n, p) {
        let mut out_t = Matrix::zeros(p, n);
        for r in rows {
            accumulate_outer_t(&mut out_t, x.row(r), g.row(r), scale[r]);
        }
        return out_t.transpose();
    }
    let mut out = Matrix::zeros(n, p);
    for r in rows {
        accumulate_outer(&mut out, x.row(r), g.row(r), scale[r]);
    }
    out
}

/// Compaction-regime AOP: only the rows in `selected` (with their scales)
/// are touched — cost `O(K·N·P)` instead of `O(M·N·P)`, the paper's
/// computational-reduction claim.
pub fn masked_outer_compact(
    x: &Matrix,
    g: &Matrix,
    selected: &[(usize, f32)],
) -> Matrix {
    let (_, n) = x.shape();
    let (_, p) = g.shape();
    if use_transposed_aop(n, p) {
        let mut out_t = Matrix::zeros(p, n);
        for &(r, s) in selected {
            accumulate_outer_t(&mut out_t, x.row(r), g.row(r), s);
        }
        return out_t.transpose();
    }
    let mut out = Matrix::zeros(n, p);
    for &(r, s) in selected {
        accumulate_outer(&mut out, x.row(r), g.row(r), s);
    }
    out
}

/// Per-row rescale (memory update; Rust twin of the Pallas `row_scale`).
pub fn row_scale(a: &Matrix, keep: &[f32]) -> Matrix {
    let (m, _) = a.shape();
    assert_eq!(keep.len(), m);
    let mut out = a.clone();
    for r in 0..m {
        let k = keep[r];
        for v in out.row_mut(r) {
            *v *= k;
        }
    }
    out
}

/// Row-norm-product policy scores (Rust twin of the Pallas `scores`):
/// `s_m = ||x[m,:]|| * ||g[m,:]||`.
pub fn norm_product_scores(x: &Matrix, g: &Matrix) -> Vec<f32> {
    assert_eq!(x.rows(), g.rows());
    x.row_norms()
        .into_iter()
        .zip(g.row_norms())
        .map(|(a, b)| a * b)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    /// O(mnk) naive reference.
    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|x| a[(i, x)] * b[(x, j)]).sum())
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (64, 64, 64), (100, 130, 70)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let d = matmul(&a, &b).max_abs_diff(&naive_matmul(&a, &b));
            assert!(d < 1e-3, "({m},{k},{n}): {d}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randm(&mut rng, 17, 17);
        let eye = Matrix::from_fn(17, 17, |r, c| (r == c) as u32 as f32);
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn matmul_tn_equals_transpose_then_matmul() {
        let mut rng = Rng::new(2);
        for (m, n, p) in [(144, 16, 1), (64, 784, 10), (33, 20, 11)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let d = matmul_tn(&x, &g).max_abs_diff(&matmul(&x.transpose(), &g));
            assert!(d < 1e-3, "({m},{n},{p}): {d}");
        }
    }

    #[test]
    fn matmul_rows_is_bitwise_slice_of_matmul() {
        let mut rng = Rng::new(42);
        // both the narrow-B dot path (k>=32, n<=24) and the blocked path
        for (m, k, n) in [(20, 40, 3), (64, 784, 10), (30, 12, 30), (7, 5, 2)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let full = matmul(&a, &b);
            for (lo, hi) in [(0, m), (0, m / 2), (m / 2, m), (1, m.min(5))] {
                let mut out = vec![f32::NAN; (hi - lo) * n];
                matmul_rows(&a, &b, lo..hi, &mut out);
                assert_eq!(
                    &out[..],
                    &full.data()[lo * n..hi * n],
                    "({m},{k},{n}) rows {lo}..{hi}"
                );
            }
        }
    }

    #[test]
    fn masked_outer_range_partials_sum_to_full() {
        let mut rng = Rng::new(43);
        for (m, n, p) in [(30, 9, 5), (64, 784, 10)] {
            let x = randm(&mut rng, m, n);
            let g = randm(&mut rng, m, p);
            let scale: Vec<f32> = (0..m).map(|i| ((i % 4) as f32) * 0.5).collect();
            let full = masked_outer(&x, &g, &scale);
            let mut acc = Matrix::zeros(n, p);
            for lo in (0..m).step_by(16) {
                let hi = (lo + 16).min(m);
                acc.axpy(1.0, &masked_outer_range(&x, &g, &scale, lo..hi));
            }
            assert!(acc.max_abs_diff(&full) < 1e-4, "({m},{n},{p})");
        }
    }

    #[test]
    fn masked_outer_full_mask_is_matmul_tn() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 48, 12);
        let g = randm(&mut rng, 48, 7);
        let ones = vec![1.0f32; 48];
        assert!(masked_outer(&x, &g, &ones).max_abs_diff(&matmul_tn(&x, &g)) < 1e-4);
    }

    #[test]
    fn masked_outer_zero_mask_is_zero() {
        let mut rng = Rng::new(4);
        let x = randm(&mut rng, 10, 4);
        let g = randm(&mut rng, 10, 3);
        let out = masked_outer(&x, &g, &vec![0.0; 10]);
        assert_eq!(out, Matrix::zeros(4, 3));
    }

    #[test]
    fn masked_outer_complement_decomposition() {
        // eq. (7) identity: masked(s) + masked(1-s) == full product
        let mut rng = Rng::new(5);
        let x = randm(&mut rng, 30, 9);
        let g = randm(&mut rng, 30, 5);
        let mask: Vec<f32> = (0..30).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let inv: Vec<f32> = mask.iter().map(|v| 1.0 - v).collect();
        let sum = masked_outer(&x, &g, &mask).add(&masked_outer(&x, &g, &inv));
        assert!(sum.max_abs_diff(&matmul_tn(&x, &g)) < 1e-4);
    }

    #[test]
    fn compact_equals_mask_regime() {
        let mut rng = Rng::new(6);
        let x = randm(&mut rng, 25, 8);
        let g = randm(&mut rng, 25, 6);
        let mut scale = vec![0.0f32; 25];
        let selected: Vec<(usize, f32)> = [(3, 1.0), (7, 2.5), (24, 0.5)].to_vec();
        for &(i, s) in &selected {
            scale[i] = s;
        }
        let a = masked_outer(&x, &g, &scale);
        let b = masked_outer_compact(&x, &g, &selected);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn single_row_outer_is_rank_one() {
        let x = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let g = Matrix::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        let out = masked_outer_compact(&x, &g, &[(1, 1.0)]);
        let expect = Matrix::from_vec(3, 2, vec![120.0, 160.0, 150.0, 200.0, 180.0, 240.0]);
        assert!(out.max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn row_scale_semantics() {
        let a = Matrix::from_fn(3, 2, |r, c| (r * 2 + c + 1) as f32);
        let out = row_scale(&a, &[1.0, 0.0, 2.0]);
        assert_eq!(out.row(0), a.row(0));
        assert_eq!(out.row(1), &[0.0, 0.0]);
        assert_eq!(out.row(2), &[10.0, 12.0]);
    }

    #[test]
    fn scores_match_definition() {
        let mut rng = Rng::new(7);
        let x = randm(&mut rng, 12, 5);
        let g = randm(&mut rng, 12, 3);
        let s = norm_product_scores(&x, &g);
        for m in 0..12 {
            let xn: f32 = x.row(m).iter().map(|v| v * v).sum::<f32>().sqrt();
            let gn: f32 = g.row(m).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((s[m] - xn * gn).abs() < 1e-5);
        }
    }
}
