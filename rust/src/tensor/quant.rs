//! Mixed-precision forward traces (§Mixed precision): the bf16 / q8
//! codecs behind [`TraceBuf`], plus the `trace` / `accum` precision
//! knobs threaded from `ExperimentConfig` down to the shard kernels.
//!
//! The memory-axis approximation (Chakrabarti & Moseley, *Backprop with
//! Approximate Activations*) complements Mem-AOP-GD's compute-axis
//! subsampling: the **forward stays exact**, but the activation trace
//! the backward pass re-reads is stored low-precision. Two codecs:
//!
//! * `bf16` — pure truncation of the f32 bit pattern (`bits >> 16`).
//!   2 bytes/element, exact on any value with an 8-bit mantissa.
//! * `q8` — per-row symmetric linear quantization: one f32 step per row
//!   (`max_abs / 127`) plus an `i8` code per element. 1 byte/element
//!   (+4 per row), absolute error ≤ `max_abs / 254` per element.
//!
//! Determinism contract: both codecs are pure per-row functions of the
//! data — never of thread count or shard position — so encoding inside
//! a sharded forward produces the same bits as a serial encode, and the
//! exec bit-identity grid holds under every precision config
//! (`rust/tests/exec.rs`).

use crate::tensor::Matrix;

/// Storage precision of one layer's activation trace (the buffer the
/// backward pass re-reads). Selected per layer via
/// `--layers "w[:act[:ksched[:trace]]]"` or flat via `--trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceMode {
    /// Full-precision trace — the seed behavior, bit-identical to it.
    F32,
    /// Truncated bfloat16 codes: 2 bytes/element, exactly 2× smaller.
    Bf16,
    /// Per-row symmetric int8: 1 byte/element + one f32 step per row.
    Q8,
}

impl TraceMode {
    pub const ALL: [TraceMode; 3] = [TraceMode::F32, TraceMode::Bf16, TraceMode::Q8];

    pub fn name(self) -> &'static str {
        match self {
            TraceMode::F32 => "f32",
            TraceMode::Bf16 => "bf16",
            TraceMode::Q8 => "q8",
        }
    }

    pub fn parse(s: &str) -> Option<TraceMode> {
        match s {
            "f32" => Some(TraceMode::F32),
            "bf16" => Some(TraceMode::Bf16),
            "q8" => Some(TraceMode::Q8),
            _ => None,
        }
    }

    /// Parse with the config-surface error contract: unknown strings
    /// come back as a message listing the valid spellings, so CLI and
    /// serve submits fail structured instead of panicking downstream.
    pub fn parse_or_suggest(s: &str) -> Result<TraceMode, String> {
        TraceMode::parse(s)
            // lint: allow(hot-path-alloc) config-parse error path, runs once per submit
            .ok_or_else(|| format!("unknown trace mode '{s}' (expected one of: f32, bf16, q8)"))
    }

    /// Bytes the backward pass reads for an `rows × cols` trace in this
    /// mode (codes + per-row steps; the reported `trace_bytes`).
    pub fn trace_bytes(self, rows: usize, cols: usize) -> usize {
        match self {
            TraceMode::F32 => 4 * rows * cols,
            TraceMode::Bf16 => 2 * rows * cols,
            TraceMode::Q8 => rows * cols + 4 * rows,
        }
    }
}

/// Accumulator width of the lane kernels (scores, column sums, and the
/// fixed-order shard reductions). Same 8-lane loop shape in every mode;
/// only the accumulator type changes — a drift-measurement knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccumMode {
    /// f32 lanes — the seed behavior, bit-identical to it.
    F32,
    /// f64 lanes, rounded to f32 once at the end.
    F64,
    /// Kahan-compensated f32 lanes (one compensation term per lane).
    Kahan,
}

impl AccumMode {
    pub const ALL: [AccumMode; 3] = [AccumMode::F32, AccumMode::F64, AccumMode::Kahan];

    pub fn name(self) -> &'static str {
        match self {
            AccumMode::F32 => "f32",
            AccumMode::F64 => "f64",
            AccumMode::Kahan => "kahan",
        }
    }

    pub fn parse(s: &str) -> Option<AccumMode> {
        match s {
            "f32" => Some(AccumMode::F32),
            "f64" => Some(AccumMode::F64),
            "kahan" => Some(AccumMode::Kahan),
            _ => None,
        }
    }

    pub fn parse_or_suggest(s: &str) -> Result<AccumMode, String> {
        AccumMode::parse(s).ok_or_else(|| {
            // lint: allow(hot-path-alloc) config-parse error path, runs once per submit
            format!("unknown accumulation mode '{s}' (expected one of: f32, f64, kahan)")
        })
    }
}

/// One layer's resolved precision pair, as the workspace carries it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPrecision {
    pub trace: TraceMode,
    pub accum: AccumMode,
}

impl LayerPrecision {
    /// The seed precision: f32 traces, f32 accumulation.
    pub fn exact() -> LayerPrecision {
        LayerPrecision { trace: TraceMode::F32, accum: AccumMode::F32 }
    }
}

impl Default for LayerPrecision {
    fn default() -> Self {
        LayerPrecision::exact()
    }
}

// ---------------------------------------------------------------------
// bf16 codec
// ---------------------------------------------------------------------

/// Truncate to bfloat16 (round-toward-zero on the mantissa — matches
/// the classic "top half of an f32" storage format).
#[inline(always)]
pub fn bf16_encode(v: f32) -> u16 {
    (v.to_bits() >> 16) as u16
}

#[inline(always)]
pub fn bf16_decode(c: u16) -> f32 {
    f32::from_bits((c as u32) << 16)
}

/// Encode one row (or any contiguous block) of f32 values.
pub fn bf16_encode_block(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "bf16 encode length mismatch");
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = bf16_encode(s);
    }
}

// ---------------------------------------------------------------------
// q8 codec
// ---------------------------------------------------------------------

/// Quantize one row symmetrically: returns the dequantization step
/// (`max_abs / 127`; 0.0 for an all-zero row) and fills `dst` with
/// codes in `[-127, 127]`. Pure function of the row's data.
pub fn q8_encode_row(src: &[f32], dst: &mut [i8]) -> f32 {
    assert_eq!(src.len(), dst.len(), "q8 encode length mismatch");
    let mut max_abs = 0.0f32;
    for &v in src {
        max_abs = max_abs.max(v.abs());
    }
    if max_abs == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let step = max_abs / 127.0;
    let inv = 1.0 / step;
    for (d, &v) in dst.iter_mut().zip(src.iter()) {
        *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    step
}

#[inline(always)]
pub fn q8_decode(code: i8, step: f32) -> f32 {
    code as f32 * step
}

// ---------------------------------------------------------------------
// TraceBuf — one layer's owned activation trace
// ---------------------------------------------------------------------

/// One layer's activation-trace storage, pre-sized at workspace build
/// (zero allocations in steady state — re-keyed only on shape change).
///
/// `F32` *is* the seed buffer: the forward writes it directly and every
/// reader reads it, bit-identical to the pre-quantization step. The
/// quantized variants keep an f32 `stage` alongside the codes: the
/// forward is computed exactly into `stage` (the next layer's forward
/// and the loss head read exact activations — the paper's forward stays
/// exact), the codes are encoded from it per shard row-block, and the
/// **backward** pass reads only the codes through [`TraceRef`] — that
/// read path is the 2–4× memory-traffic reduction, and `trace_bytes`
/// reports its footprint. (Dropping the stage would require the next
/// layer's forward to consume requantized inputs; see ROADMAP.)
#[derive(Debug, Clone)]
pub enum TraceBuf {
    F32(Matrix),
    Bf16 {
        rows: usize,
        cols: usize,
        codes: Vec<u16>,
        stage: Matrix,
    },
    Q8 {
        rows: usize,
        cols: usize,
        /// Per-row dequantization step (`max_abs / 127`).
        steps: Vec<f32>,
        codes: Vec<i8>,
        stage: Matrix,
    },
}

impl TraceBuf {
    pub fn new(mode: TraceMode, rows: usize, cols: usize) -> TraceBuf {
        match mode {
            TraceMode::F32 => TraceBuf::F32(Matrix::zeros(rows, cols)),
            TraceMode::Bf16 => TraceBuf::Bf16 {
                rows,
                cols,
                // lint: allow(hot-path-alloc) workspace constructor, runs once at build time; steps reuse the buffers
                codes: vec![0; rows * cols],
                stage: Matrix::zeros(rows, cols),
            },
            TraceMode::Q8 => TraceBuf::Q8 {
                rows,
                cols,
                // lint: allow(hot-path-alloc) workspace constructor, runs once at build time; steps reuse the buffers
                steps: vec![0.0; rows],
                // lint: allow(hot-path-alloc) workspace constructor, runs once at build time; steps reuse the buffers
                codes: vec![0; rows * cols],
                stage: Matrix::zeros(rows, cols),
            },
        }
    }

    pub fn mode(&self) -> TraceMode {
        match self {
            TraceBuf::F32(_) => TraceMode::F32,
            TraceBuf::Bf16 { .. } => TraceMode::Bf16,
            TraceBuf::Q8 { .. } => TraceMode::Q8,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            TraceBuf::F32(m) => m.shape(),
            TraceBuf::Bf16 { rows, cols, .. } | TraceBuf::Q8 { rows, cols, .. } => (*rows, *cols),
        }
    }

    /// Bytes the backward pass reads from this trace (codes + per-row
    /// steps; the forward-only `stage` is excluded — it is never read
    /// after the next layer's forward consumes it).
    pub fn trace_bytes(&self) -> usize {
        let (r, c) = self.shape();
        self.mode().trace_bytes(r, c)
    }

    /// The exact (f32) activations from the last forward — the `F32`
    /// matrix itself, or the quantized variants' staging buffer. Read
    /// by the next layer's forward, the loss head, and the auditor.
    pub fn exact(&self) -> &Matrix {
        match self {
            TraceBuf::F32(m) => m,
            TraceBuf::Bf16 { stage, .. } | TraceBuf::Q8 { stage, .. } => stage,
        }
    }

    /// Mutable exact buffer — the forward-only eval path
    /// (`Graph::evaluate_ws`) writes activations here without touching
    /// the codes (nothing reads them back in an eval).
    pub fn exact_mut(&mut self) -> &mut Matrix {
        match self {
            TraceBuf::F32(m) => m,
            TraceBuf::Bf16 { stage, .. } | TraceBuf::Q8 { stage, .. } => stage,
        }
    }

    /// Borrowed dequant-on-read view for the backward shard kernels.
    pub fn as_ref(&self) -> TraceRef<'_> {
        match self {
            TraceBuf::F32(m) => TraceRef::F32(m),
            TraceBuf::Bf16 { cols, codes, .. } => TraceRef::Bf16 { cols: *cols, codes },
            TraceBuf::Q8 { cols, steps, codes, .. } => {
                TraceRef::Q8 { cols: *cols, steps, codes }
            }
        }
    }
}

/// Borrowed view of a trace: what the backward shard kernels consume.
/// `F32` wraps any plain matrix (including the step's input batch), so
/// one kernel signature covers both the exact and quantized paths.
#[derive(Debug, Clone, Copy)]
pub enum TraceRef<'a> {
    F32(&'a Matrix),
    Bf16 { cols: usize, codes: &'a [u16] },
    Q8 { cols: usize, steps: &'a [f32], codes: &'a [i8] },
}

impl TraceRef<'_> {
    pub fn cols(&self) -> usize {
        match self {
            TraceRef::F32(m) => m.cols(),
            TraceRef::Bf16 { cols, .. } | TraceRef::Q8 { cols, .. } => *cols,
        }
    }

    /// Dequantized element access — convenience for tests and cold
    /// paths; the hot kernels match on the variant and stream rows.
    pub fn at(&self, r: usize, c: usize) -> f32 {
        match self {
            TraceRef::F32(m) => m[(r, c)],
            TraceRef::Bf16 { cols, codes } => bf16_decode(codes[r * cols + c]),
            TraceRef::Q8 { cols, steps, codes } => q8_decode(codes[r * cols + c], steps[r]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn mode_names_round_trip() {
        for m in TraceMode::ALL {
            assert_eq!(TraceMode::parse(m.name()), Some(m));
        }
        for a in AccumMode::ALL {
            assert_eq!(AccumMode::parse(a.name()), Some(a));
        }
        assert!(TraceMode::parse_or_suggest("fp16").unwrap_err().contains("bf16"));
        assert!(AccumMode::parse_or_suggest("f128").unwrap_err().contains("kahan"));
    }

    #[test]
    fn bf16_truncation_is_exact_on_short_mantissas() {
        // 8-bit-mantissa values survive bf16 exactly
        for v in [0.0f32, 1.0, -2.5, 0.15625, 384.0, -0.0078125] {
            assert_eq!(bf16_decode(bf16_encode(v)), v);
        }
        // relative truncation error strictly under one bf16 ulp (2^-7)
        // for normal values: the dropped mantissa bits are < 2^(e-7) and
        // |v| >= 2^e
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            let v = rng.normal();
            let d = bf16_decode(bf16_encode(v));
            assert!((v - d).abs() <= v.abs() / 128.0, "v={v} d={d}");
        }
    }

    #[test]
    fn q8_round_trip_error_bounded_by_half_step() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let row: Vec<f32> = (0..37).map(|_| rng.normal() * 3.0).collect();
            let mut codes = vec![0i8; row.len()];
            let step = q8_encode_row(&row, &mut codes);
            let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!((step - max_abs / 127.0).abs() <= f32::EPSILON * max_abs);
            for (&v, &c) in row.iter().zip(codes.iter()) {
                let err = (v - q8_decode(c, step)).abs();
                // half a step = max_abs / 254, padded one ulp for the
                // division rounding in the encoder
                assert!(err <= max_abs / 254.0 * (1.0 + 1e-5), "v={v} err={err}");
            }
        }
    }

    #[test]
    fn q8_zero_row_encodes_to_zero_step() {
        let row = [0.0f32; 9];
        let mut codes = [1i8; 9];
        let step = q8_encode_row(&row, &mut codes);
        assert_eq!(step, 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn trace_buf_bytes_match_mode_arithmetic() {
        let (r, c) = (64, 4096);
        let f = TraceBuf::new(TraceMode::F32, r, c);
        let b = TraceBuf::new(TraceMode::Bf16, r, c);
        let q = TraceBuf::new(TraceMode::Q8, r, c);
        assert_eq!(f.trace_bytes(), 4 * r * c);
        assert_eq!(b.trace_bytes(), 2 * r * c);
        assert_eq!(q.trace_bytes(), r * c + 4 * r);
        // the acceptance arithmetic: bf16 is exactly 2x, q8 just under 4x
        assert_eq!(f.trace_bytes() / b.trace_bytes(), 2);
        assert!(f.trace_bytes() as f64 / q.trace_bytes() as f64 > 3.9);
    }

    #[test]
    fn trace_ref_at_matches_codec() {
        let mut rng = Rng::new(3);
        let m = Matrix::from_fn(5, 8, |_, _| rng.normal());
        let mut buf = TraceBuf::new(TraceMode::Q8, 5, 8);
        if let TraceBuf::Q8 { steps, codes, stage, .. } = &mut buf {
            stage.data_mut().copy_from_slice(m.data());
            for r in 0..5 {
                steps[r] = q8_encode_row(m.row(r), &mut codes[r * 8..(r + 1) * 8]);
            }
        }
        let tr = buf.as_ref();
        for r in 0..5 {
            for c in 0..8 {
                assert!((tr.at(r, c) - m[(r, c)]).abs() <= m.row(r).iter().fold(0.0f32, |a, v| a.max(v.abs())) / 254.0 * 1.01);
            }
        }
        assert_eq!(buf.exact().data(), m.data());
    }
}
