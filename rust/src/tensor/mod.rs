//! Dense f32 matrix substrate.
//!
//! Row-major `Matrix` with the operations the native Mem-AOP-GD path and
//! the host-side glue need: (blocked) matmul, the masked outer-product
//! accumulation that *is* the paper's AOP (eq. (4)/(5)), row norms, and
//! elementwise ops. Deliberately not a general tensor library — shapes are
//! always 2-D, dtype is always f32 (matching the AOT artifacts).

pub mod init;
pub mod ops;
pub mod quant;
pub mod rng;

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            for r in 0..self.rows {
                write!(f, "\n  {:?}", self.row(r))?;
            }
        }
        Ok(())
    }
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    /// Build from a function of (row, col).
    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Write `self^T` into an existing `cols × rows` matrix — the
    /// allocation-free path the per-step weight-transpose cache
    /// (`train::Dense::refresh_w_t`) runs on.
    pub fn transpose_into(&self, out: &mut Matrix) {
        assert_eq!(
            out.shape(),
            (self.cols, self.rows),
            "transpose_into shape mismatch"
        );
        for r in 0..self.rows {
            let row = self.row(r);
            for (c, &v) in row.iter().enumerate() {
                out.data[c * self.rows + r] = v;
            }
        }
    }

    /// In-place `self[r, c] -= other_t[c, r]` for a transposed-layout
    /// operand — applies a `cols × rows` accumulation (the transposed
    /// AOP layout of `tensor::ops`) without materializing its transpose.
    /// Per-element it performs exactly the subtraction `axpy(-1.0, ·)`
    /// would after a `transpose()` copy.
    pub fn sub_transposed(&mut self, other_t: &Matrix) {
        assert_eq!(
            other_t.shape(),
            (self.cols, self.rows),
            "sub_transposed shape mismatch"
        );
        let (rows, cols) = (self.rows, self.cols);
        for r in 0..rows {
            let row = &mut self.data[r * cols..(r + 1) * cols];
            for (c, v) in row.iter_mut().enumerate() {
                *v -= other_t.data[c * rows + r];
            }
        }
    }

    /// Elementwise map into a new matrix.
    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self - other` (new matrix).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// `self + other` (new matrix).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Scale by a constant (new matrix).
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|v| alpha * v)
    }

    /// Add a row-vector to every row (broadcast bias add).
    pub fn add_row_broadcast(&self, bias: &[f32]) -> Matrix {
        assert_eq!(bias.len(), self.cols);
        let mut out = self.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (v, &b) in row.iter_mut().zip(bias.iter()) {
                *v += b;
            }
        }
        out
    }

    /// Column sums (e.g. bias gradient `sum_m G[m, :]`).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(r).iter()) {
                *o += v;
            }
        }
        out
    }

    /// Euclidean norm of each row (SIMD-friendly dot).
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                ops::dot(row, row).sqrt()
            })
            .collect()
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        ops::dot(&self.data, &self.data).sqrt()
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True if every entry is finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// Matrix product `self @ other` (delegates to the blocked kernel).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        ops::matmul(self, other)
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        ops::matmul_tn(self, other)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 7 + c * 3) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().shape(), (5, 3));
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn transpose_into_reuses_buffer() {
        let a = Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f32);
        let mut out = Matrix::full(3, 4, f32::NAN); // stale contents
        a.transpose_into(&mut out);
        assert_eq!(out, a.transpose());
    }

    #[test]
    fn sub_transposed_matches_axpy_of_transpose() {
        let mut a = Matrix::from_fn(5, 2, |r, c| (r + c) as f32 * 0.5);
        let t = Matrix::from_fn(2, 5, |r, c| (r * 5 + c) as f32 * 0.25);
        let mut expect = a.clone();
        expect.axpy(-1.0, &t.transpose());
        a.sub_transposed(&t);
        assert_eq!(a.data(), expect.data());
    }

    #[test]
    #[should_panic(expected = "sub_transposed shape mismatch")]
    fn sub_transposed_rejects_bad_shape() {
        let mut a = Matrix::zeros(2, 3);
        a.sub_transposed(&Matrix::zeros(2, 3));
    }

    #[test]
    fn arithmetic_ops() {
        let a = Matrix::from_fn(2, 2, |r, c| (r + c) as f32);
        let b = Matrix::full(2, 2, 1.0);
        assert_eq!(a.add(&b).sub(&b), a);
        assert_eq!(a.scale(2.0)[(1, 1)], 4.0);
        let mut c = a.clone();
        c.axpy(-1.0, &a);
        assert_eq!(c, Matrix::zeros(2, 2));
    }

    #[test]
    fn broadcast_and_sums() {
        let a = Matrix::from_fn(3, 2, |r, _| r as f32);
        let biased = a.add_row_broadcast(&[10.0, 20.0]);
        assert_eq!(biased[(2, 1)], 22.0);
        assert_eq!(a.col_sums(), vec![3.0, 3.0]);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        assert_eq!(a.row_norms(), vec![5.0, 0.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "buffer/shape mismatch")]
    fn bad_buffer_rejected() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn max_abs_diff_and_finite() {
        let a = Matrix::full(2, 2, 1.0);
        let mut b = a.clone();
        b[(0, 1)] = 1.5;
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.is_finite());
        b[(1, 1)] = f32::NAN;
        assert!(!b.is_finite());
    }
}
