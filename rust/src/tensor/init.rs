//! Parameter initializers.
//!
//! Glorot/He schemes matching the Keras defaults the paper's reference
//! implementation used (`glorot_uniform` for dense layers), so the native
//! and HLO paths start from the same weight distribution family.

use super::rng::Rng;
use super::Matrix;

/// Glorot (Xavier) uniform: U(-l, l), l = sqrt(6 / (fan_in + fan_out)).
/// Keras's default dense initializer.
pub fn glorot_uniform(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Matrix {
    let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
    Matrix::from_fn(fan_in, fan_out, |_, _| {
        (rng.uniform() * 2.0 - 1.0) * limit
    })
}

/// Glorot normal: N(0, 2/(fan_in+fan_out)).
pub fn glorot_normal(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Matrix {
    let std = (2.0 / (fan_in + fan_out) as f32).sqrt();
    Matrix::from_fn(fan_in, fan_out, |_, _| rng.normal() * std)
}

/// He normal: N(0, 2/fan_in) — for relu hidden layers in the e2e MLP.
pub fn he_normal(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Matrix {
    let std = (2.0 / fan_in as f32).sqrt();
    Matrix::from_fn(fan_in, fan_out, |_, _| rng.normal() * std)
}

/// Zero bias vector.
pub fn zeros_bias(n: usize) -> Vec<f32> {
    vec![0.0; n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_uniform_bounds() {
        let mut rng = Rng::new(0);
        let w = glorot_uniform(&mut rng, 16, 1);
        let limit = (6.0f32 / 17.0).sqrt();
        assert!(w.data().iter().all(|v| v.abs() <= limit));
        assert_eq!(w.shape(), (16, 1));
    }

    #[test]
    fn glorot_uniform_not_degenerate() {
        let mut rng = Rng::new(1);
        let w = glorot_uniform(&mut rng, 784, 10);
        let mean: f32 = w.data().iter().sum::<f32>() / w.data().len() as f32;
        assert!(mean.abs() < 0.01);
        assert!(w.frobenius() > 0.0);
    }

    #[test]
    fn he_normal_std() {
        let mut rng = Rng::new(2);
        let w = he_normal(&mut rng, 1024, 1024);
        let n = w.data().len() as f32;
        let var: f32 = w.data().iter().map(|v| v * v).sum::<f32>() / n;
        let expect = 2.0 / 1024.0;
        assert!((var / expect - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = glorot_normal(&mut Rng::new(3), 8, 4);
        let b = glorot_normal(&mut Rng::new(3), 8, 4);
        assert_eq!(a, b);
    }
}
