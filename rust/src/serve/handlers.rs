//! Request dispatch: one function from protocol [`Request`] to response
//! JSON against the shared [`ServerState`].
//!
//! Kept free of any socket I/O so the whole op surface is unit-testable
//! in-process — the TCP layer in `server.rs` only frames lines and calls
//! [`ServerState::handle`]. Every path returns a response object; client
//! mistakes (unknown job id, malformed config, full queue) become
//! `ok:false` envelopes, never a closed connection or a panic.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::serve::protocol::{self, err_response, ok_response, Request, PROTOCOL_VERSION};
use crate::serve::queue::Scheduler;
use crate::serve::registry::Registry;
use crate::util::json::{self, Json};

/// Everything a connection handler needs, shared via `Arc` across the
/// accept loop and every connection thread.
pub struct ServerState {
    pub registry: Arc<Registry>,
    pub scheduler: Scheduler,
    started: Instant,
    requests: AtomicU64,
    shutdown: AtomicBool,
}

impl ServerState {
    pub fn new(registry: Arc<Registry>, scheduler: Scheduler) -> ServerState {
        ServerState {
            registry,
            scheduler,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Set once a `shutdown` op arrives; the accept loop polls this.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Dispatch one request frame. Infallible by design: every error is
    /// encoded as an `ok:false` response.
    pub fn handle(&self, frame: &Json) -> Json {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let req = match Request::from_json(frame) {
            Ok(r) => r,
            Err(e) => return err_response(&format!("{e:#}")),
        };
        match req {
            Request::Submit { config, tag } => match self.scheduler.submit(config, &tag) {
                Ok(id) => ok_response(vec![("id", json::num(id as f64))]),
                Err(e) => err_response(&format!("{e:#}")),
            },
            Request::Status { id } => match self.registry.view(id) {
                Some(v) => ok_response(vec![("job", v.to_json())]),
                None => err_response(&format!("no job {id}")),
            },
            Request::Result { id } => {
                let Some(view) = self.registry.view(id) else {
                    return err_response(&format!("no job {id}"));
                };
                match self.registry.result_of(id) {
                    Some((cfg, curve)) => ok_response(vec![
                        ("job", view.to_json()),
                        ("config", cfg.to_json()),
                        ("curve", curve.to_json()),
                    ]),
                    None => err_response(&format!(
                        "job {id} has no result yet (state '{}')",
                        view.state.name()
                    )),
                }
            }
            Request::List => ok_response(vec![(
                "jobs",
                Json::Arr(self.registry.views().iter().map(|v| v.to_json()).collect()),
            )]),
            Request::Cancel { id } => match self.registry.cancel(id) {
                // Queued jobs finalize immediately; running jobs stop at
                // the next epoch boundary.
                Ok(state) => ok_response(vec![(
                    "state",
                    json::s(match state {
                        crate::serve::registry::JobState::Cancelled => "cancelled",
                        _ => "cancelling",
                    }),
                )]),
                Err(e) => err_response(&format!("{e:#}")),
            },
            Request::Metrics => self.metrics_response(),
            Request::Ping => ok_response(vec![
                ("protocol", json::num(PROTOCOL_VERSION as f64)),
                ("uptime_s", json::num(self.uptime_s())),
            ]),
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                ok_response(vec![("state", json::s("shutting-down"))])
            }
        }
    }

    /// The `metrics` payload: queue/job counters, throughput, and the
    /// per-policy FLOP-savings rollup from `aop::flops`.
    fn metrics_response(&self) -> Json {
        let counts = self.registry.counts();
        let uptime = self.uptime_s();
        // throughput of *this* process: jobs restored from a previous
        // lifetime don't count toward the current uptime's rate
        let done_here = counts.done.saturating_sub(self.registry.restored_count());
        let jobs_per_sec = if uptime > 0.0 {
            done_here as f64 / uptime
        } else {
            0.0
        };
        let policies: Vec<Json> = self
            .registry
            .rollup()
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("policy", json::s(r.policy.name())),
                    ("jobs", json::num(r.jobs as f64)),
                    ("backward_flops", json::num(r.backward_flops as f64)),
                    ("exact_flops", json::num(r.exact_flops as f64)),
                    ("saved_frac", json::num(r.saved_frac())),
                ])
            })
            .collect();
        ok_response(vec![
            ("uptime_s", json::num(uptime)),
            ("requests_total", json::num(self.requests.load(Ordering::Relaxed) as f64)),
            ("queue_depth", json::num(self.scheduler.queue_depth() as f64)),
            ("workers", json::num(self.scheduler.worker_count() as f64)),
            // thread-slot budget: a running job holds `threads` slots
            ("slots_total", json::num(self.scheduler.worker_count() as f64)),
            ("slots_free", json::num(self.scheduler.slots_free() as f64)),
            ("jobs_per_sec", json::num(jobs_per_sec)),
            (
                "jobs",
                json::obj(vec![
                    ("queued", json::num(counts.queued as f64)),
                    ("running", json::num(counts.running as f64)),
                    ("done", json::num(counts.done as f64)),
                    ("failed", json::num(counts.failed as f64)),
                    ("cancelled", json::num(counts.cancelled as f64)),
                    ("total", json::num(counts.total() as f64)),
                ]),
            ),
            ("policies", Json::Arr(policies)),
        ])
    }
}

/// Convenience used by the TCP layer: format a protocol-level read error
/// (bad JSON on a line) as a response frame.
pub fn frame_error(e: &anyhow::Error) -> Json {
    protocol::err_response(&format!("{e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::protocol::is_ok;
    use std::time::Duration;

    fn state() -> ServerState {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 2, 32);
        ServerState::new(reg, sched)
    }

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = Policy::TopK;
        cfg.k = crate::coordinator::config::KSchedule::Constant(18);
        cfg.memory = true;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    fn submit_req(seed: u64) -> Json {
        json::obj(vec![
            ("op", json::s("submit")),
            ("config", quick_cfg(seed).to_json()),
            ("tag", json::s("unit")),
        ])
    }

    fn wait_done(st: &ServerState, id: u64) -> Json {
        let status = json::obj(vec![("op", json::s("status")), ("id", json::num(id as f64))]);
        for _ in 0..2000 {
            let resp = st.handle(&status);
            assert!(is_ok(&resp), "{}", resp.dump());
            let state = resp
                .get("job")
                .and_then(|j| j.get("state"))
                .and_then(|s| s.as_str())
                .unwrap()
                .to_string();
            if state == "done" || state == "failed" || state == "cancelled" {
                return resp.get("job").unwrap().clone();
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("job {id} never finished");
    }

    #[test]
    fn submit_status_result_roundtrip() {
        let st = state();
        let resp = st.handle(&submit_req(0));
        assert!(is_ok(&resp), "{}", resp.dump());
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        let job = wait_done(&st, id);
        assert_eq!(job.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(job.get("tag").unwrap().as_str().unwrap(), "unit");

        let result = st.handle(&json::obj(vec![
            ("op", json::s("result")),
            ("id", json::num(id as f64)),
        ]));
        assert!(is_ok(&result));
        let curve = result.get("curve").unwrap();
        assert_eq!(curve.get("epochs").unwrap().as_arr().unwrap().len(), 2);
        // decoded config matches what was submitted
        let cfg = ExperimentConfig::from_json(result.get("config").unwrap()).unwrap();
        assert_eq!(cfg.seed, 0);
        assert_eq!(cfg.policy, Policy::TopK);
        st.scheduler.shutdown();
    }

    #[test]
    fn errors_are_envelopes_not_panics() {
        let st = state();
        // bad op
        let r = st.handle(&json::obj(vec![("op", json::s("explode"))]));
        assert!(!is_ok(&r));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown op"));
        // unknown job
        let r = st.handle(&json::obj(vec![("op", json::s("status")), ("id", json::num(77))]));
        assert!(!is_ok(&r));
        // result before completion / for missing job
        let r = st.handle(&json::obj(vec![("op", json::s("result")), ("id", json::num(77))]));
        assert!(!is_ok(&r));
        // malformed submit
        let r = st.handle(&json::obj(vec![("op", json::s("submit"))]));
        assert!(!is_ok(&r));
        st.scheduler.shutdown();
    }

    #[test]
    fn list_metrics_and_shutdown_flag() {
        let st = state();
        let a = st.handle(&submit_req(1));
        let b = st.handle(&submit_req(2));
        let ida = a.get("id").unwrap().as_f64().unwrap() as u64;
        let idb = b.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, ida);
        wait_done(&st, idb);

        let list = st.handle(&json::obj(vec![("op", json::s("list"))]));
        assert!(is_ok(&list));
        assert_eq!(list.get("jobs").unwrap().as_arr().unwrap().len(), 2);

        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        assert!(is_ok(&m), "{}", m.dump());
        let jobs = m.get("jobs").unwrap();
        assert_eq!(jobs.get("done").unwrap().as_usize().unwrap(), 2);
        let pols = m.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(pols.len(), 1);
        assert_eq!(pols[0].get("policy").unwrap().as_str().unwrap(), "topk");
        // topk K=18 of M=144 ⇒ 7/8 of the backward FLOPs saved
        let saved = pols[0].get("saved_frac").unwrap().as_f64().unwrap();
        assert!((saved - 0.875).abs() < 1e-9, "{saved}");

        assert!(!st.shutdown_requested());
        let s = st.handle(&json::obj(vec![("op", json::s("shutdown"))]));
        assert!(is_ok(&s));
        assert_eq!(s.get("state").unwrap().as_str().unwrap(), "shutting-down");
        assert!(st.shutdown_requested());
        st.scheduler.shutdown();
    }

    #[test]
    fn degenerate_layer_specs_are_protocol_errors_not_panics() {
        // regression: an empty or zero-width `layers` spec (or a
        // degenerate k schedule) must come back as an ok:false envelope
        // at submit — it must never reach a worker thread where the
        // Graph constructor would panic and kill it
        let st = state();
        let submit_with = |mutate: &dyn Fn(&mut Vec<(String, Json)>)| -> Json {
            let mut cfg_json = quick_cfg(0).to_json();
            if let Json::Obj(pairs) = &mut cfg_json {
                mutate(pairs);
            }
            st.handle(&json::obj(vec![
                ("op", json::s("submit")),
                ("config", cfg_json),
            ]))
        };
        // empty layers array
        let r = submit_with(&|pairs| pairs.push(("layers".to_string(), Json::Arr(vec![]))));
        assert!(!is_ok(&r), "{}", r.dump());
        assert!(r.get("error").unwrap().as_str().unwrap().contains("layers"));
        // zero-width layer
        let r = submit_with(&|pairs| {
            pairs.push((
                "layers".to_string(),
                Json::Arr(vec![json::obj(vec![("width", json::num(0.0))])]),
            ));
        });
        assert!(!is_ok(&r), "{}", r.dump());
        // degenerate k schedule string
        let r = submit_with(&|pairs| {
            pairs.retain(|(k, _)| k != "k");
            pairs.push(("k".to_string(), json::s("step:18:0:0.5")));
        });
        assert!(!is_ok(&r), "{}", r.dump());
        // the server is still alive and serving
        let p = st.handle(&json::obj(vec![("op", json::s("ping"))]));
        assert!(is_ok(&p));
        assert_eq!(st.registry.counts().total(), 0, "nothing was enqueued");
        st.scheduler.shutdown();
    }

    #[test]
    fn oversized_threads_request_is_a_protocol_error() {
        let st = state(); // 2-slot scheduler
        let mut cfg = quick_cfg(0);
        cfg.threads = 8;
        let r = st.handle(&json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
        ]));
        assert!(!is_ok(&r));
        let err = r.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("threads=8"), "{err}");

        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        assert!(is_ok(&m));
        assert_eq!(m.get("slots_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(m.get("slots_free").unwrap().as_usize().unwrap(), 2);
        st.scheduler.shutdown();
    }

    #[test]
    fn ping_reports_protocol() {
        let st = state();
        let p = st.handle(&json::obj(vec![("op", json::s("ping"))]));
        assert!(is_ok(&p));
        assert_eq!(
            p.get("protocol").unwrap().as_usize().unwrap() as u64,
            PROTOCOL_VERSION
        );
        st.scheduler.shutdown();
    }
}
