//! Request dispatch: one function from protocol [`Request`] to response
//! JSON against the shared [`ServerState`].
//!
//! Kept free of any socket I/O so the whole op surface is unit-testable
//! in-process — the TCP layer in `server.rs` only frames lines and calls
//! [`ServerState::handle`]. Every path returns a response object; client
//! mistakes (unknown job id, malformed config, full queue) become
//! `ok:false` envelopes, never a closed connection or a panic.

// Clock reads are deliberate here (request timing/uptime for the metrics op) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{AtomicHistogram, PromBuf};
use crate::serve::protocol::{
    self, err_rejection, err_response, ok_response, MetricsFormat, Request, PROTOCOL_VERSION,
};
use crate::serve::queue::Scheduler;
use crate::serve::registry::Registry;
use crate::tensor::quant::TraceMode;
use crate::util::json::{self, Json};

/// Stable op labels for the per-op request accounting (protocol v5;
/// Prometheus `op` label values). `error` collects frames that fail to
/// parse into any op. These are a wire-format promise — only ever
/// extended, never renamed.
const OP_NAMES: [&str; 11] = [
    "submit", "status", "result", "list", "cancel", "metrics", "watch", "ping", "shutdown",
    "health", "error",
];
const OP_ERROR: usize = OP_NAMES.len() - 1;

/// Rejection reason labels (protocol v8): the `reason` field of a
/// rejection envelope and the `reason` label on `repro_rejected_total`.
/// Same stability promise as [`OP_NAMES`]: extended, never renamed.
pub const REJECT_REASONS: [&str; 4] =
    ["queue_full", "rate_limited", "shutting_down", "oversized"];

/// Server-side clamp on a `watch` long-poll (protocol v6): bounds how
/// long one request can hold a connection thread.
const MAX_WATCH_WAIT_MS: u64 = 30_000;

/// Server-side clamp on a `health` probe wait (protocol v8).
const MAX_HEALTH_WAIT_MS: u64 = 10_000;

fn op_index(req: &Request) -> usize {
    match req {
        Request::Submit { .. } => 0,
        Request::Status { .. } => 1,
        Request::Result { .. } => 2,
        Request::List { .. } => 3,
        Request::Cancel { .. } => 4,
        Request::Metrics { .. } => 5,
        Request::Watch { .. } => 6,
        Request::Ping => 7,
        Request::Shutdown => 8,
        Request::Health { .. } => 9,
    }
}

/// Admission-control knobs the TCP layer passes down from
/// `ServeOptions` (protocol v8). The defaults disable rate limiting,
/// so in-process `ServerState`s behave exactly as before.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Sustained `submit` rate allowed per client IP (tokens/second);
    /// `0.0` disables the limiter entirely.
    pub rate_limit_per_sec: f64,
    /// Token-bucket capacity: how many submits a client may burst
    /// after sitting idle.
    pub rate_limit_burst: f64,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits { rate_limit_per_sec: 0.0, rate_limit_burst: 8.0 }
    }
}

/// Token-bucket state for one client IP.
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Everything a connection handler needs, shared via `Arc` across the
/// accept loop and every connection thread.
pub struct ServerState {
    pub registry: Arc<Registry>,
    pub scheduler: Scheduler,
    started: Instant,
    requests: AtomicU64,
    /// Per-op request latency (and, via its count, per-op request
    /// totals): every handled frame records exactly one sample, so
    /// `Σ_op count == requests_total` whenever no request is in flight.
    op_lat: [AtomicHistogram; OP_NAMES.len()],
    /// Rejected submits by reason, indexed parallel to
    /// [`REJECT_REASONS`].
    rejected: [AtomicU64; REJECT_REASONS.len()],
    limits: Limits,
    buckets: Mutex<HashMap<IpAddr, Bucket>>,
    /// Open client connections; the accept loop's RAII guard maintains
    /// this so `repro_connections_open` is honest.
    connections: AtomicU64,
    shutdown: AtomicBool,
}

impl ServerState {
    pub fn new(registry: Arc<Registry>, scheduler: Scheduler) -> ServerState {
        ServerState::with_limits(registry, scheduler, Limits::default())
    }

    pub fn with_limits(
        registry: Arc<Registry>,
        scheduler: Scheduler,
        limits: Limits,
    ) -> ServerState {
        ServerState {
            registry,
            scheduler,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            op_lat: std::array::from_fn(|_| AtomicHistogram::new()),
            rejected: std::array::from_fn(|_| AtomicU64::new(0)),
            limits,
            buckets: Mutex::new(HashMap::new()),
            connections: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Set once a `shutdown` op arrives; the accept loop polls this.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Connection-count bookkeeping for the TCP layer's RAII guard.
    pub fn connection_opened(&self) {
        self.connections.fetch_add(1, Ordering::SeqCst);
    }

    pub fn connection_closed(&self) {
        self.connections.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn connections_open(&self) -> u64 {
        self.connections.load(Ordering::SeqCst)
    }

    /// Dispatch one request frame from an in-process caller (no peer
    /// address, so the per-client rate limiter never applies).
    pub fn handle(&self, frame: &Json) -> Json {
        self.handle_from(frame, None)
    }

    /// Dispatch one request frame. Infallible by design: every error is
    /// encoded as an `ok:false` response. `peer` is the client IP the
    /// TCP layer saw; submit-rate limiting is keyed on it.
    pub fn handle_from(&self, frame: &Json, peer: Option<IpAddr>) -> Json {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let req = match Request::from_json(frame) {
            Ok(r) => r,
            Err(e) => {
                let resp = err_response(&format!("{e:#}"));
                self.record_op(OP_ERROR, t0);
                return resp;
            }
        };
        let op = op_index(&req);
        match req {
            Request::Submit { config, tag } => {
                let resp = if let Some(retry_ms) = self.rate_limited(peer) {
                    self.count_rejection("rate_limited");
                    err_rejection(
                        &format!(
                            "rate limit: this client exceeded {:.1} submits/s (burst {})",
                            self.limits.rate_limit_per_sec, self.limits.rate_limit_burst
                        ),
                        "rate_limited",
                        Some(retry_ms),
                    )
                } else {
                    match self.scheduler.submit(config, &tag) {
                        Ok(id) => ok_response(vec![("id", json::num(id as f64))]),
                        Err(rej) => {
                            self.count_rejection(rej.reason);
                            err_rejection(&rej.to_string(), rej.reason, rej.retry_after_ms)
                        }
                    }
                };
                self.record_op(op, t0);
                resp
            }
            Request::Status { id, compact } => {
                let resp = match self.registry.view(id) {
                    Some(v) => ok_response(vec![(
                        "job",
                        if compact { v.to_json_compact() } else { v.to_json() },
                    )]),
                    None => err_response(&format!("no job {id}")),
                };
                self.record_op(op, t0);
                resp
            }
            Request::Result { id } => {
                let resp = match self.registry.view(id) {
                    None => err_response(&format!("no job {id}")),
                    Some(view) => match self.registry.result_of(id) {
                        Some((cfg, curve)) => ok_response(vec![
                            ("job", view.to_json()),
                            ("config", cfg.to_json()),
                            ("curve", curve.to_json()),
                        ]),
                        None => err_response(&format!(
                            "job {id} has no result yet (state '{}')",
                            view.state.name()
                        )),
                    },
                };
                self.record_op(op, t0);
                resp
            }
            Request::List { compact } => {
                let resp = ok_response(vec![(
                    "jobs",
                    Json::Arr(
                        self.registry
                            .views()
                            .iter()
                            .map(|v| if compact { v.to_json_compact() } else { v.to_json() })
                            .collect(),
                    ),
                )]);
                self.record_op(op, t0);
                resp
            }
            Request::Cancel { id } => {
                let resp = match self.registry.cancel(id) {
                    // Queued jobs finalize immediately; running jobs stop
                    // at the next epoch boundary.
                    Ok(state) => ok_response(vec![(
                        "state",
                        json::s(match state {
                            crate::serve::registry::JobState::Cancelled => "cancelled",
                            _ => "cancelling",
                        }),
                    )]),
                    Err(e) => err_response(&format!("{e:#}")),
                };
                self.record_op(op, t0);
                resp
            }
            Request::Metrics { format } => {
                // record this request BEFORE rendering, so the snapshot
                // it returns satisfies `Σ_op hist counts ==
                // requests_total` exactly (the metrics op's own sample
                // covers parse + dispatch, not render time)
                self.record_op(op, t0);
                self.metrics_response(format)
            }
            Request::Watch { id, cursor, wait_ms } => {
                let wait = std::time::Duration::from_millis(wait_ms.min(MAX_WATCH_WAIT_MS));
                let resp = match self.registry.watch(id, cursor, wait) {
                    Ok((epochs, next, state)) => ok_response(vec![
                        ("epochs", Json::Arr(epochs)),
                        ("cursor", json::num(next as f64)),
                        ("state", json::s(state.name())),
                    ]),
                    Err(e) => err_response(&format!("{e:#}")),
                };
                // the sample includes the long-poll block — that IS this
                // request's latency
                self.record_op(op, t0);
                resp
            }
            Request::Ping => {
                let resp = ok_response(vec![
                    ("protocol", json::num(PROTOCOL_VERSION as f64)),
                    ("uptime_s", json::num(self.uptime_s())),
                ]);
                self.record_op(op, t0);
                resp
            }
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                let resp = ok_response(vec![("state", json::s("shutting-down"))]);
                self.record_op(op, t0);
                resp
            }
            Request::Health { wait_ms } => {
                // the probe is a real round-trip through the scheduler
                // pool: a wedged pool shows up as pool_alive=false, not
                // as a cheerful gauge read
                let wait = Duration::from_millis(wait_ms.min(MAX_HEALTH_WAIT_MS));
                let probe = self.scheduler.probe(wait);
                let queue_depth = self.scheduler.queue_depth();
                let capacity = self.scheduler.capacity();
                let alive = probe.is_some();
                let healthy =
                    alive && !self.scheduler.is_shutting_down() && queue_depth < capacity;
                let mut pairs = vec![
                    ("status", json::s(if healthy { "ok" } else { "degraded" })),
                    ("pool_alive", Json::Bool(alive)),
                    ("queue_depth", json::num(queue_depth as f64)),
                    ("queue_capacity", json::num(capacity as f64)),
                    ("slots_free", json::num(self.scheduler.slots_free() as f64)),
                    ("slots_total", json::num(self.scheduler.worker_count() as f64)),
                ];
                if let Some(d) = probe {
                    pairs.push(("probe_ms", json::num(d.as_secs_f64() * 1000.0)));
                }
                let resp = ok_response(pairs);
                self.record_op(op, t0);
                resp
            }
        }
    }

    /// Token-bucket check for one submit from `peer`. `Some(ms)` means
    /// reject with that retry hint; `None` admits. Disabled (rate 0.0)
    /// and in-process (peer-less) submits always admit.
    fn rate_limited(&self, peer: Option<IpAddr>) -> Option<u64> {
        let rate = self.limits.rate_limit_per_sec;
        if rate <= 0.0 {
            return None;
        }
        let ip = peer?;
        let mut buckets = self.buckets.lock().unwrap();
        let now = Instant::now();
        let b = buckets
            .entry(ip)
            .or_insert(Bucket { tokens: self.limits.rate_limit_burst, last: now });
        b.tokens = (b.tokens + now.duration_since(b.last).as_secs_f64() * rate)
            .min(self.limits.rate_limit_burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            None
        } else {
            Some((((1.0 - b.tokens) / rate) * 1000.0).ceil() as u64)
        }
    }

    fn count_rejection(&self, reason: &str) {
        if let Some(i) = REJECT_REASONS.iter().position(|r| *r == reason) {
            self.rejected[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The scrape-time liveness bit behind `repro_health_status`: cheap
    /// on purpose (no pool probe) so `metrics` stays fast.
    fn healthy_now(&self) -> bool {
        !self.scheduler.is_shutting_down()
            && self.scheduler.queue_depth() < self.scheduler.capacity()
    }

    fn record_op(&self, op: usize, t0: Instant) {
        let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.op_lat[op].record(ns);
    }

    /// The `metrics` payload in the requested rendering: queue/slot/pool
    /// gauges, job counters, per-op request latency, and the per-policy
    /// FLOP-savings rollup from `aop::flops`.
    fn metrics_response(&self, format: MetricsFormat) -> Json {
        let g = self.gauges();
        match format {
            MetricsFormat::Json => self.metrics_json(&g),
            MetricsFormat::Compact => self.metrics_compact(&g),
            MetricsFormat::Prometheus => ok_response(vec![
                ("format", json::s("prometheus")),
                ("text", json::s(&self.prometheus_text(&g))),
            ]),
        }
    }

    /// One consistent read of every scalar the renderings share.
    fn gauges(&self) -> Gauges {
        let counts = self.registry.counts();
        let uptime = self.uptime_s();
        // throughput of *this* process: jobs restored from a previous
        // lifetime don't count toward the current uptime's rate
        let done_here = counts.done.saturating_sub(self.registry.restored_count());
        let slots_total = self.scheduler.worker_count();
        let slots_busy = self.scheduler.slots_busy();
        Gauges {
            uptime,
            requests_total: self.requests.load(Ordering::Relaxed),
            queue_depth: self.scheduler.queue_depth(),
            slots_total,
            slots_busy,
            slots_free: self.scheduler.slots_free(),
            // slot (thread) utilization, not job count / worker count:
            // a threads=4 job on a 4-slot server is 100% utilization
            // even though one pool worker drives it
            utilization: if slots_total > 0 {
                slots_busy as f64 / slots_total as f64
            } else {
                0.0
            },
            pool_busy: self.scheduler.pool_busy(),
            pool_pending: self.scheduler.pool_pending(),
            jobs_per_sec: if uptime > 0.0 { done_here as f64 / uptime } else { 0.0 },
            counts,
        }
    }

    fn jobs_obj(counts: &crate::serve::registry::StateCounts) -> Json {
        json::obj(vec![
            ("queued", json::num(counts.queued as f64)),
            ("running", json::num(counts.running as f64)),
            ("done", json::num(counts.done as f64)),
            ("failed", json::num(counts.failed as f64)),
            ("cancelled", json::num(counts.cancelled as f64)),
            ("total", json::num(counts.total() as f64)),
        ])
    }

    fn metrics_json(&self, g: &Gauges) -> Json {
        let policies: Vec<Json> = self
            .registry
            .rollup()
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("policy", json::s(r.policy.name())),
                    ("jobs", json::num(r.jobs as f64)),
                    ("backward_flops", json::num(r.backward_flops as f64)),
                    ("exact_flops", json::num(r.exact_flops as f64)),
                    ("saved_frac", json::num(r.saved_frac())),
                ])
            })
            .collect();
        let ops: Vec<Json> = OP_NAMES
            .iter()
            .zip(self.op_lat.iter())
            .filter_map(|(name, h)| {
                let h = h.snapshot();
                if h.is_empty() {
                    return None;
                }
                Some(json::obj(vec![
                    ("op", json::s(name)),
                    ("count", json::num(h.count() as f64)),
                    ("total_ns", json::num(h.sum_ns() as f64)),
                    ("p50_ns", json::num(h.quantile_ns(0.5) as f64)),
                    ("p99_ns", json::num(h.quantile_ns(0.99) as f64)),
                    ("max_ns", json::num(h.max_ns() as f64)),
                ]))
            })
            .collect();
        ok_response(vec![
            ("uptime_s", json::num(g.uptime)),
            ("requests_total", json::num(g.requests_total as f64)),
            ("queue_depth", json::num(g.queue_depth as f64)),
            ("workers", json::num(g.slots_total as f64)),
            // thread-slot budget: a running job holds `threads` slots
            ("slots_total", json::num(g.slots_total as f64)),
            ("slots_busy", json::num(g.slots_busy as f64)),
            ("slots_free", json::num(g.slots_free as f64)),
            ("utilization", json::num(g.utilization)),
            (
                "pool",
                json::obj(vec![
                    ("workers_busy", json::num(g.pool_busy as f64)),
                    ("tasks_pending", json::num(g.pool_pending as f64)),
                ]),
            ),
            ("jobs_per_sec", json::num(g.jobs_per_sec)),
            ("jobs", Self::jobs_obj(&g.counts)),
            (
                "rejected",
                json::obj(
                    REJECT_REASONS
                        .iter()
                        .zip(self.rejected.iter())
                        .map(|(r, n)| (*r, json::num(n.load(Ordering::Relaxed) as f64)))
                        .collect(),
                ),
            ),
            ("ops", Json::Arr(ops)),
            ("policies", Json::Arr(policies)),
        ])
    }

    /// Compact mode: only the gauges pollers scrape — no policy rollup
    /// (which walks every completed curve) and no op histograms.
    fn metrics_compact(&self, g: &Gauges) -> Json {
        ok_response(vec![
            ("uptime_s", json::num(g.uptime)),
            ("requests_total", json::num(g.requests_total as f64)),
            ("queue_depth", json::num(g.queue_depth as f64)),
            ("slots_total", json::num(g.slots_total as f64)),
            ("slots_busy", json::num(g.slots_busy as f64)),
            ("slots_free", json::num(g.slots_free as f64)),
            ("utilization", json::num(g.utilization)),
            ("jobs", Self::jobs_obj(&g.counts)),
        ])
    }

    /// Prometheus text exposition. Metric names and label keys here are
    /// a stability promise (README §Observability): extended, never
    /// renamed or removed.
    fn prometheus_text(&self, g: &Gauges) -> String {
        let mut p = PromBuf::new();
        p.family("repro_uptime_seconds");
        p.sample("repro_uptime_seconds", &[], g.uptime);
        p.family("repro_requests_total");
        p.sample("repro_requests_total", &[], g.requests_total as f64);
        p.family("repro_queue_depth");
        p.sample("repro_queue_depth", &[], g.queue_depth as f64);
        p.family("repro_slots_total");
        p.sample("repro_slots_total", &[], g.slots_total as f64);
        p.family("repro_slots_busy");
        p.sample("repro_slots_busy", &[], g.slots_busy as f64);
        p.family("repro_slots_free");
        p.sample("repro_slots_free", &[], g.slots_free as f64);
        p.family("repro_utilization_ratio");
        p.sample("repro_utilization_ratio", &[], g.utilization);
        p.family("repro_pool_workers_busy");
        p.sample("repro_pool_workers_busy", &[], g.pool_busy as f64);
        p.family("repro_pool_tasks_pending");
        p.sample("repro_pool_tasks_pending", &[], g.pool_pending as f64);
        // resilience families (protocol v8): always headered and fully
        // sampled (zeros included) so alerting rules never see a family
        // appear out of nowhere
        p.family("repro_health_status");
        p.sample("repro_health_status", &[], if self.healthy_now() { 1.0 } else { 0.0 });
        p.family("repro_rejected_total");
        for (reason, n) in REJECT_REASONS.iter().zip(self.rejected.iter()) {
            p.sample(
                "repro_rejected_total",
                &[("reason", reason)],
                n.load(Ordering::Relaxed) as f64,
            );
        }
        p.family("repro_connections_open");
        p.sample("repro_connections_open", &[], self.connections_open() as f64);
        p.family("repro_jobs_total");
        for (state, n) in [
            ("queued", g.counts.queued),
            ("running", g.counts.running),
            ("done", g.counts.done),
            ("failed", g.counts.failed),
            ("cancelled", g.counts.cancelled),
        ] {
            p.sample("repro_jobs_total", &[("state", state)], n as f64);
        }
        p.family("repro_request_latency_seconds");
        for (name, h) in OP_NAMES.iter().zip(self.op_lat.iter()) {
            let h = h.snapshot();
            if !h.is_empty() {
                p.histogram_ns("repro_request_latency_seconds", &[("op", *name)], &h);
            }
        }
        let rollup = self.registry.rollup();
        p.family("repro_policy_jobs_total");
        for r in &rollup {
            p.sample("repro_policy_jobs_total", &[("policy", r.policy.name())], r.jobs as f64);
        }
        p.family("repro_policy_backward_flops_total");
        for r in &rollup {
            p.sample(
                "repro_policy_backward_flops_total",
                &[("policy", r.policy.name())],
                r.backward_flops as f64,
            );
        }
        p.family("repro_policy_exact_flops_total");
        for r in &rollup {
            p.sample(
                "repro_policy_exact_flops_total",
                &[("policy", r.policy.name())],
                r.exact_flops as f64,
            );
        }
        p.family("repro_policy_saved_ratio");
        for r in &rollup {
            p.sample("repro_policy_saved_ratio", &[("policy", r.policy.name())], r.saved_frac());
        }
        // gradient-fidelity gauges (protocol v6): each job's most recent
        // audit, one sample per layer. Jobs that never audited (no
        // `audit` cadence in their config) export nothing.
        let audits = self.registry.audit_snapshots();
        p.family("repro_audit_epoch");
        for (id, epoch, _) in &audits {
            p.sample("repro_audit_epoch", &[("job", &id.to_string())], *epoch as f64);
        }
        // HELP/TYPE text lives in `obs::prom::METRIC_FAMILIES` (rule R5)
        let audit_family =
            |p: &mut PromBuf, name: &str, get: &dyn Fn(&crate::obs::AuditLayerRecord) -> f64| {
                p.family(name);
                for (id, _, recs) in &audits {
                    let jid = id.to_string();
                    for r in recs {
                        let layer = r.layer.to_string();
                        p.sample(name, &[("job", &jid), ("layer", &layer)], get(r));
                    }
                }
            };
        audit_family(&mut p, "repro_audit_cosine", &|r| r.cosine);
        audit_family(&mut p, "repro_audit_rel_err", &|r| r.rel_err);
        audit_family(&mut p, "repro_audit_mem_bias", &|r| r.mem_bias);
        // mixed-precision footprint (protocol v7): backward-read bytes
        // of each job's stored forward traces at batch M, summed over
        // the resolved (post-pin) layer plan. All-f32 jobs export
        // nothing — they are the uncompressed baseline.
        p.family("repro_trace_bytes");
        for v in self.registry.views() {
            let plan = v.config.layer_plan();
            if plan.iter().any(|rl| rl.trace != TraceMode::F32) {
                let m = v.config.m();
                let bytes: usize =
                    plan.iter().map(|rl| rl.trace.trace_bytes(m, rl.fan_out)).sum();
                p.sample("repro_trace_bytes", &[("job", &v.id.to_string())], bytes as f64);
            }
        }
        p.finish()
    }
}

/// One consistent read of the scalar gauges shared by all three
/// `metrics` renderings.
struct Gauges {
    uptime: f64,
    requests_total: u64,
    queue_depth: usize,
    slots_total: usize,
    slots_busy: usize,
    slots_free: usize,
    utilization: f64,
    pool_busy: usize,
    pool_pending: usize,
    jobs_per_sec: f64,
    counts: crate::serve::registry::StateCounts,
}

/// Convenience used by the TCP layer: format a protocol-level read error
/// (bad JSON on a line) as a response frame.
pub fn frame_error(e: &anyhow::Error) -> Json {
    protocol::err_response(&format!("{e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::protocol::is_ok;
    use std::time::Duration;

    fn state() -> ServerState {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 2, 32);
        ServerState::new(reg, sched)
    }

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = Policy::TopK;
        cfg.k = crate::coordinator::config::KSchedule::Constant(18);
        cfg.memory = true;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    fn submit_req(seed: u64) -> Json {
        json::obj(vec![
            ("op", json::s("submit")),
            ("config", quick_cfg(seed).to_json()),
            ("tag", json::s("unit")),
        ])
    }

    fn wait_done(st: &ServerState, id: u64) -> Json {
        let status = json::obj(vec![("op", json::s("status")), ("id", json::num(id as f64))]);
        for _ in 0..2000 {
            let resp = st.handle(&status);
            assert!(is_ok(&resp), "{}", resp.dump());
            let state = resp
                .get("job")
                .and_then(|j| j.get("state"))
                .and_then(|s| s.as_str())
                .unwrap()
                .to_string();
            if state == "done" || state == "failed" || state == "cancelled" {
                return resp.get("job").unwrap().clone();
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("job {id} never finished");
    }

    #[test]
    fn submit_status_result_roundtrip() {
        let st = state();
        let resp = st.handle(&submit_req(0));
        assert!(is_ok(&resp), "{}", resp.dump());
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        let job = wait_done(&st, id);
        assert_eq!(job.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(job.get("tag").unwrap().as_str().unwrap(), "unit");

        let result = st.handle(&json::obj(vec![
            ("op", json::s("result")),
            ("id", json::num(id as f64)),
        ]));
        assert!(is_ok(&result));
        let curve = result.get("curve").unwrap();
        assert_eq!(curve.get("epochs").unwrap().as_arr().unwrap().len(), 2);
        // decoded config matches what was submitted
        let cfg = ExperimentConfig::from_json(result.get("config").unwrap()).unwrap();
        assert_eq!(cfg.seed, 0);
        assert_eq!(cfg.policy, Policy::TopK);
        st.scheduler.shutdown();
    }

    #[test]
    fn errors_are_envelopes_not_panics() {
        let st = state();
        // bad op
        let r = st.handle(&json::obj(vec![("op", json::s("explode"))]));
        assert!(!is_ok(&r));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown op"));
        // unknown job
        let r = st.handle(&json::obj(vec![("op", json::s("status")), ("id", json::num(77))]));
        assert!(!is_ok(&r));
        // result before completion / for missing job
        let r = st.handle(&json::obj(vec![("op", json::s("result")), ("id", json::num(77))]));
        assert!(!is_ok(&r));
        // malformed submit
        let r = st.handle(&json::obj(vec![("op", json::s("submit"))]));
        assert!(!is_ok(&r));
        st.scheduler.shutdown();
    }

    #[test]
    fn list_metrics_and_shutdown_flag() {
        let st = state();
        let a = st.handle(&submit_req(1));
        let b = st.handle(&submit_req(2));
        let ida = a.get("id").unwrap().as_f64().unwrap() as u64;
        let idb = b.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, ida);
        wait_done(&st, idb);

        let list = st.handle(&json::obj(vec![("op", json::s("list"))]));
        assert!(is_ok(&list));
        assert_eq!(list.get("jobs").unwrap().as_arr().unwrap().len(), 2);

        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        assert!(is_ok(&m), "{}", m.dump());
        let jobs = m.get("jobs").unwrap();
        assert_eq!(jobs.get("done").unwrap().as_usize().unwrap(), 2);
        let pols = m.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(pols.len(), 1);
        assert_eq!(pols[0].get("policy").unwrap().as_str().unwrap(), "topk");
        // topk K=18 of M=144 ⇒ 7/8 of the backward FLOPs saved
        let saved = pols[0].get("saved_frac").unwrap().as_f64().unwrap();
        assert!((saved - 0.875).abs() < 1e-9, "{saved}");

        assert!(!st.shutdown_requested());
        let s = st.handle(&json::obj(vec![("op", json::s("shutdown"))]));
        assert!(is_ok(&s));
        assert_eq!(s.get("state").unwrap().as_str().unwrap(), "shutting-down");
        assert!(st.shutdown_requested());
        st.scheduler.shutdown();
    }

    #[test]
    fn degenerate_layer_specs_are_protocol_errors_not_panics() {
        // regression: an empty or zero-width `layers` spec (or a
        // degenerate k schedule) must come back as an ok:false envelope
        // at submit — it must never reach a worker thread where the
        // Graph constructor would panic and kill it
        let st = state();
        let submit_with = |mutate: &dyn Fn(&mut Vec<(String, Json)>)| -> Json {
            let mut cfg_json = quick_cfg(0).to_json();
            if let Json::Obj(pairs) = &mut cfg_json {
                mutate(pairs);
            }
            st.handle(&json::obj(vec![
                ("op", json::s("submit")),
                ("config", cfg_json),
            ]))
        };
        // empty layers array
        let r = submit_with(&|pairs| pairs.push(("layers".to_string(), Json::Arr(vec![]))));
        assert!(!is_ok(&r), "{}", r.dump());
        assert!(r.get("error").unwrap().as_str().unwrap().contains("layers"));
        // zero-width layer
        let r = submit_with(&|pairs| {
            pairs.push((
                "layers".to_string(),
                Json::Arr(vec![json::obj(vec![("width", json::num(0.0))])]),
            ));
        });
        assert!(!is_ok(&r), "{}", r.dump());
        // degenerate k schedule string
        let r = submit_with(&|pairs| {
            pairs.retain(|(k, _)| k != "k");
            pairs.push(("k".to_string(), json::s("step:18:0:0.5")));
        });
        assert!(!is_ok(&r), "{}", r.dump());
        // the server is still alive and serving
        let p = st.handle(&json::obj(vec![("op", json::s("ping"))]));
        assert!(is_ok(&p));
        assert_eq!(st.registry.counts().total(), 0, "nothing was enqueued");
        st.scheduler.shutdown();
    }

    #[test]
    fn oversized_threads_request_is_a_protocol_error() {
        let st = state(); // 2-slot scheduler
        let mut cfg = quick_cfg(0);
        cfg.threads = 8;
        let r = st.handle(&json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
        ]));
        assert!(!is_ok(&r));
        let err = r.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("threads=8"), "{err}");

        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        assert!(is_ok(&m));
        assert_eq!(m.get("slots_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(m.get("slots_free").unwrap().as_usize().unwrap(), 2);
        st.scheduler.shutdown();
    }

    #[test]
    fn per_op_accounting_and_metric_formats() {
        let st = state();
        // a known request mix: 3 pings, 1 unparseable frame, 1 bad-id
        // status (parses fine — counts as a status op, not an error)
        for _ in 0..3 {
            assert!(is_ok(&st.handle(&json::obj(vec![("op", json::s("ping"))]))));
        }
        assert!(!is_ok(&st.handle(&json::obj(vec![("op", json::s("explode"))]))));
        assert!(!is_ok(&st.handle(&json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(404.0)),
        ]))));

        // full JSON: the metrics request records itself before rendering,
        // so op counts sum exactly to requests_total
        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        assert!(is_ok(&m), "{}", m.dump());
        let total = m.get("requests_total").unwrap().as_usize().unwrap();
        assert_eq!(total, 6);
        let ops = m.get("ops").unwrap().as_arr().unwrap();
        let count_of = |name: &str| {
            ops.iter()
                .find(|o| o.get("op").unwrap().as_str().unwrap() == name)
                .map(|o| o.get("count").unwrap().as_usize().unwrap())
                .unwrap_or(0)
        };
        assert_eq!(count_of("ping"), 3);
        assert_eq!(count_of("error"), 1);
        assert_eq!(count_of("status"), 1);
        assert_eq!(count_of("metrics"), 1);
        let sum: usize = ops
            .iter()
            .map(|o| o.get("count").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(sum, total);
        assert_eq!(m.get("slots_busy").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("utilization").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            m.get("pool").unwrap().get("tasks_pending").unwrap().as_usize().unwrap(),
            0
        );

        // compact: gauges only
        let c = st.handle(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("compact")),
        ]));
        assert!(is_ok(&c), "{}", c.dump());
        assert!(c.get("ops").is_none());
        assert!(c.get("policies").is_none());
        assert!(c.get("pool").is_none());
        assert_eq!(c.get("requests_total").unwrap().as_usize().unwrap(), 7);
        assert_eq!(c.get("slots_total").unwrap().as_usize().unwrap(), 2);

        // prometheus: text exposition in the envelope
        let pr = st.handle(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("prometheus")),
        ]));
        assert!(is_ok(&pr), "{}", pr.dump());
        assert_eq!(pr.get("format").unwrap().as_str().unwrap(), "prometheus");
        let text = pr.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE repro_requests_total counter\n"), "{text}");
        assert!(text.contains("repro_requests_total 8\n"), "{text}");
        assert!(text.contains("repro_slots_total 2\n"), "{text}");
        assert!(text.contains("repro_jobs_total{state=\"done\"} 0\n"), "{text}");
        assert!(
            text.contains("repro_request_latency_seconds_count{op=\"ping\"} 3\n"),
            "{text}"
        );
        // histogram family is complete: buckets end at +Inf with the count
        assert!(
            text.contains("repro_request_latency_seconds_bucket{op=\"ping\",le=\"+Inf\"} 3\n"),
            "{text}"
        );
        st.scheduler.shutdown();
    }

    #[test]
    fn watch_op_streams_epochs_and_exports_audit_gauges() {
        let st = state();
        let mut cfg = quick_cfg(3);
        cfg.audit = Some(1); // audit every epoch
        let resp = st.handle(&json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
            ("tag", json::s("w")),
        ]));
        assert!(is_ok(&resp), "{}", resp.dump());
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        let mut cursor = 0usize;
        let mut seen: Vec<Json> = Vec::new();
        loop {
            let r = st.handle(&json::obj(vec![
                ("op", json::s("watch")),
                ("id", json::num(id as f64)),
                ("cursor", json::num(cursor as f64)),
                ("wait_ms", json::num(1000.0)),
            ]));
            assert!(is_ok(&r), "{}", r.dump());
            let batch = r.get("epochs").unwrap().as_arr().unwrap().to_vec();
            cursor = r.get("cursor").unwrap().as_usize().unwrap();
            let state = r.get("state").unwrap().as_str().unwrap().to_string();
            let terminal = matches!(state.as_str(), "done" | "failed" | "cancelled");
            let empty = batch.is_empty();
            seen.extend(batch);
            if terminal && empty {
                break;
            }
            assert!(seen.len() <= 2, "watch delivered duplicate epochs");
        }
        assert_eq!(seen.len(), 2);
        for (i, ep) in seen.iter().enumerate() {
            assert_eq!(ep.get("epoch").unwrap().as_usize().unwrap(), i + 1);
            let audit = ep.get("audit").unwrap().as_arr().unwrap();
            assert_eq!(audit.len(), 1, "one record per layer");
            let cos = audit[0].get("cosine").unwrap().as_f64().unwrap();
            let rel = audit[0].get("rel_err").unwrap().as_f64().unwrap();
            assert!(cos.is_finite() && cos.abs() <= 1.0 + 1e-9);
            assert!(rel.is_finite() && rel > 0.0, "K=18 of 144 is approximate");
        }
        // watching an unknown job is an envelope error, not a hang
        let r = st.handle(&json::obj(vec![
            ("op", json::s("watch")),
            ("id", json::num(404.0)),
        ]));
        assert!(!is_ok(&r));
        // the job's last audit is exported as labelled gauges
        let pr = st.handle(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("prometheus")),
        ]));
        let text = pr.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE repro_audit_cosine gauge\n"), "{text}");
        assert!(
            text.contains(&format!("repro_audit_epoch{{job=\"{id}\"}} 2\n")),
            "{text}"
        );
        for fam in ["repro_audit_cosine", "repro_audit_rel_err", "repro_audit_mem_bias"] {
            assert!(
                text.contains(&format!("{fam}{{job=\"{id}\",layer=\"0\"}}")),
                "missing {fam} sample\n{text}"
            );
        }
        st.scheduler.shutdown();
    }

    #[test]
    fn every_prometheus_sample_family_has_help_and_type_headers() {
        use std::collections::BTreeSet;
        let st = state();
        let resp = st.handle(&submit_req(5));
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, id); // populate job/policy/op families
        let pr = st.handle(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("prometheus")),
        ]));
        assert!(is_ok(&pr), "{}", pr.dump());
        let text = pr.get("text").unwrap().as_str().unwrap();
        let mut typed = BTreeSet::new();
        let mut helped = BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                typed.insert(rest.split(' ').next().unwrap().to_string());
            } else if let Some(rest) = line.strip_prefix("# HELP ") {
                helped.insert(rest.split(' ').next().unwrap().to_string());
            }
        }
        assert_eq!(typed, helped, "HELP and TYPE must come in pairs");
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let name = line.split(['{', ' ']).next().unwrap();
            let family = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            assert!(
                typed.contains(family) || typed.contains(name),
                "sample '{name}' has no # TYPE header"
            );
        }
        // the v6 audit families are declared even with no audited jobs,
        // as is the v7 trace-footprint gauge with no quantized jobs
        for fam in [
            "repro_audit_epoch",
            "repro_audit_cosine",
            "repro_audit_rel_err",
            "repro_audit_mem_bias",
            "repro_trace_bytes",
        ] {
            assert!(typed.contains(fam), "missing header for {fam}");
        }
        st.scheduler.shutdown();
    }

    #[test]
    fn quantized_trace_jobs_export_their_footprint_gauge() {
        use crate::coordinator::config::LayerSpec;
        use crate::tensor::quant::TraceMode;
        let st = state();
        // all-f32 job: no repro_trace_bytes sample
        let a = st.handle(&submit_req(11));
        let ida = a.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, ida);
        // bf16-trace job over a layered graph: 16→8→1 at M=144; only
        // layer 0's output is compressible (the head is pinned f32)
        let mut cfg = quick_cfg(12);
        cfg.trace = TraceMode::Bf16;
        cfg.layers = Some(vec![LayerSpec::plain(8), LayerSpec::plain(1)]);
        let r = st.handle(&json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
            ("tag", json::s("bf16")),
        ]));
        assert!(is_ok(&r), "{}", r.dump());
        let idb = r.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, idb);
        let pr = st.handle(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("prometheus")),
        ]));
        let text = pr.get("text").unwrap().as_str().unwrap();
        // bf16 layer 0 (144×8 halves to 2 B/elt) + pinned-f32 head (144×1)
        let want = 2 * 144 * 8 + 4 * 144;
        assert!(
            text.contains(&format!("repro_trace_bytes{{job=\"{idb}\"}} {want}\n")),
            "{text}"
        );
        assert!(
            !text.contains(&format!("repro_trace_bytes{{job=\"{ida}\"}}")),
            "all-f32 job must not export a footprint\n{text}"
        );
        st.scheduler.shutdown();
    }

    #[test]
    fn compact_status_and_list_drop_the_config_echo() {
        let st = state();
        let resp = st.handle(&submit_req(9));
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        wait_done(&st, id);
        let full = st.handle(&json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(id as f64)),
        ]));
        let job = full.get("job").unwrap();
        assert!(job.get("layers").is_some());
        assert!(job.get("phases").map(|p| !matches!(p, Json::Null)).unwrap_or(false));
        let compact = st.handle(&json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(id as f64)),
            ("compact", Json::Bool(true)),
        ]));
        let job = compact.get("job").unwrap();
        assert!(job.get("layers").is_none());
        assert!(job.get("phases").is_none());
        assert_eq!(job.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(job.get("epochs_done").unwrap().as_usize().unwrap(), 2);
        let list = st.handle(&json::obj(vec![
            ("op", json::s("list")),
            ("compact", Json::Bool(true)),
        ]));
        let jobs = list.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 1);
        assert!(jobs[0].get("layers").is_none());
        st.scheduler.shutdown();
    }

    fn state_with_limits(l: Limits) -> ServerState {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 2, 32);
        ServerState::with_limits(reg, sched, l)
    }

    #[test]
    fn health_op_reports_ok_then_degraded() {
        let st = state();
        let h = st.handle(&json::obj(vec![("op", json::s("health"))]));
        assert!(is_ok(&h), "{}", h.dump());
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
        assert_eq!(h.get("pool_alive").unwrap().as_bool().unwrap(), true);
        assert!(h.get("probe_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(h.get("queue_capacity").unwrap().as_usize().unwrap(), 32);
        assert_eq!(h.get("slots_total").unwrap().as_usize().unwrap(), 2);
        // a stopped pool can't answer the probe: degraded, no probe_ms
        st.scheduler.shutdown();
        let h = st.handle(&json::obj(vec![
            ("op", json::s("health")),
            ("wait_ms", json::num(50.0)),
        ]));
        assert!(is_ok(&h), "{}", h.dump());
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "degraded");
        assert_eq!(h.get("pool_alive").unwrap().as_bool().unwrap(), false);
        assert!(h.get("probe_ms").is_none());
    }

    #[test]
    fn rate_limiter_rejects_bursts_per_client_and_recovers() {
        let st = state_with_limits(Limits { rate_limit_per_sec: 5.0, rate_limit_burst: 2.0 });
        let peer: IpAddr = "10.0.0.1".parse().unwrap();
        let other: IpAddr = "10.0.0.2".parse().unwrap();
        let a = st.handle_from(&submit_req(21), Some(peer));
        let b = st.handle_from(&submit_req(22), Some(peer));
        assert!(is_ok(&a) && is_ok(&b), "a burst of 2 is admitted");
        let r = st.handle_from(&submit_req(23), Some(peer));
        assert!(!is_ok(&r), "{}", r.dump());
        assert_eq!(r.get("reason").unwrap().as_str().unwrap(), "rate_limited");
        let hint = r.get("retry_after_ms").unwrap().as_usize().unwrap();
        assert!(hint >= 1 && hint <= 200, "hint {hint}ms at 5 tokens/s");
        // other clients and in-process callers have their own budget
        assert!(is_ok(&st.handle_from(&submit_req(24), Some(other))));
        assert!(is_ok(&st.handle(&submit_req(25))));
        // the bucket refills: at 5 tokens/s a ~300ms wait covers the hint
        std::thread::sleep(Duration::from_millis(300));
        let r = st.handle_from(&submit_req(26), Some(peer));
        assert!(is_ok(&r), "{}", r.dump());
        st.scheduler.shutdown();
    }

    #[test]
    fn rejections_export_reason_counters_and_health_gauge() {
        let st = state();
        let scrape = |st: &ServerState| -> String {
            let pr = st.handle(&json::obj(vec![
                ("op", json::s("metrics")),
                ("format", json::s("prometheus")),
            ]));
            assert!(is_ok(&pr), "{}", pr.dump());
            pr.get("text").unwrap().as_str().unwrap().to_string()
        };
        // families are fully sampled (zeros included) from the start
        let text = scrape(&st);
        assert!(text.contains("# TYPE repro_rejected_total counter\n"), "{text}");
        for reason in REJECT_REASONS {
            assert!(
                text.contains(&format!("repro_rejected_total{{reason=\"{reason}\"}} 0\n")),
                "{text}"
            );
        }
        assert!(text.contains("repro_health_status 1\n"), "{text}");
        assert!(text.contains("repro_connections_open 0\n"), "{text}");
        // an oversized submit is counted under its reason
        let mut cfg = quick_cfg(31);
        cfg.threads = 8;
        let r = st.handle(&json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
        ]));
        assert!(!is_ok(&r));
        assert_eq!(r.get("reason").unwrap().as_str().unwrap(), "oversized");
        // a shutdown drops the health gauge and counts its rejections
        st.scheduler.shutdown();
        let r = st.handle(&submit_req(32));
        assert!(!is_ok(&r));
        assert_eq!(r.get("reason").unwrap().as_str().unwrap(), "shutting_down");
        let text = scrape(&st);
        assert!(text.contains("repro_rejected_total{reason=\"oversized\"} 1\n"), "{text}");
        assert!(
            text.contains("repro_rejected_total{reason=\"shutting_down\"} 1\n"),
            "{text}"
        );
        assert!(text.contains("repro_health_status 0\n"), "{text}");
        // the JSON rendering carries the same counters
        let m = st.handle(&json::obj(vec![("op", json::s("metrics"))]));
        let rej = m.get("rejected").unwrap();
        assert_eq!(rej.get("oversized").unwrap().as_usize().unwrap(), 1);
        assert_eq!(rej.get("queue_full").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn ping_reports_protocol() {
        let st = state();
        let p = st.handle(&json::obj(vec![("op", json::s("ping"))]));
        assert!(is_ok(&p));
        assert_eq!(
            p.get("protocol").unwrap().as_usize().unwrap() as u64,
            PROTOCOL_VERSION
        );
        st.scheduler.shutdown();
    }
}
