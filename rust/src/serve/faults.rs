//! Deterministic, seed-keyed fault injection for chaos-testing the
//! serve tier (ISSUE 9 tentpole).
//!
//! A [`FaultPlan`] describes *where the serve tier is allowed to break*:
//! worker panics at epoch boundaries, torn registry writes (a file
//! persisted corrupt, as if the process died mid-write before the
//! atomic rename landed), and dropped client connections. Every fault
//! decision is a **pure function** of the plan's seed and the stable
//! identity of the event (job id + epoch, job id, connection id +
//! frame index) via the same counter-based [`Rng::for_stream`] streams
//! the trainer uses — so a chaos run is exactly reproducible, and a
//! test can rerun the identical fault schedule against a fix.
//!
//! Contract (mirrors `ObsConfig::off()`): [`FaultPlan::off`] means the
//! predicates short-circuit to `false` without constructing an RNG —
//! fault injection costs nothing when disabled, and production builds
//! never pay for it.
//!
//! Faults never touch the math. They kill jobs, connections, and
//! files, but a job that *completes* under faults ran the exact same
//! deterministic training loop as its fault-free twin — which is what
//! lets the chaos soak assert bit-identical curves rather than
//! probabilistic health.

use anyhow::{bail, Result};

use crate::tensor::rng::domains::{FLT_DROP, FLT_PANIC, FLT_TORN};
use crate::tensor::rng::Rng;

// The three fault-family stream-domain tags live in the central
// registry (`tensor::rng::domains`, repro-lint rule R1) — the same
// values as the historical local constants, now collision-checked
// against every trainer stream.

/// A deterministic fault-injection schedule. Rates are per-mille
/// (0..=1000) per opportunity: `panic` per (job, epoch boundary),
/// `torn` per persisted job file, `drop` per (connection, response).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed keying every fault roll; two runs with the same seed and
    /// the same event identities inject the same faults.
    pub seed: u64,
    /// Probability (per mille) a worker panics at an epoch boundary.
    pub panic_per_mille: u32,
    /// Probability (per mille) a registry persist writes a torn file.
    pub torn_per_mille: u32,
    /// Probability (per mille) a connection drops before a response.
    pub drop_per_mille: u32,
}

impl FaultPlan {
    /// No faults — every predicate returns `false` without touching an
    /// RNG. This is the production default.
    pub const fn off() -> FaultPlan {
        FaultPlan { seed: 0, panic_per_mille: 0, torn_per_mille: 0, drop_per_mille: 0 }
    }

    /// True when no fault family is armed (the fast path).
    pub const fn is_off(&self) -> bool {
        self.panic_per_mille == 0 && self.torn_per_mille == 0 && self.drop_per_mille == 0
    }

    /// Parse a CLI spec like `"seed=7,panic=50,torn=100,drop=25"`.
    /// Omitted keys default to 0; an empty spec is [`FaultPlan::off`].
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::off();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, value)) = part.split_once('=') else {
                bail!("bad fault spec part {part:?} (expected key=value)");
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad fault seed {value:?}"))?;
                }
                "panic" | "torn" | "drop" => {
                    let rate: u32 = value.parse().map_err(|_| {
                        anyhow::anyhow!("bad fault rate {value:?} for {key} (per mille, 0..=1000)")
                    })?;
                    if rate > 1000 {
                        bail!("fault rate {key}={rate} out of range (per mille, 0..=1000)");
                    }
                    match key {
                        "panic" => plan.panic_per_mille = rate,
                        "torn" => plan.torn_per_mille = rate,
                        _ => plan.drop_per_mille = rate,
                    }
                }
                _ => bail!(
                    "unknown fault key {key:?} (expected one of: seed, panic, torn, drop)"
                ),
            }
        }
        Ok(plan)
    }

    /// One deterministic per-mille roll on an independent stream.
    fn roll(&self, domain: u64, a: u64, b: u64, per_mille: u32) -> bool {
        if per_mille == 0 {
            return false; // compiled-out fast path: no RNG construction
        }
        let mut rng = Rng::for_stream(self.seed ^ domain, a, b);
        rng.next_u64() % 1000 < u64::from(per_mille)
    }

    /// Should the worker running `job_id` panic at the end of `epoch`?
    pub fn worker_panic(&self, job_id: u64, epoch: u64) -> bool {
        self.roll(FLT_PANIC, job_id, epoch, self.panic_per_mille)
    }

    /// Should the registry persist of `job_id` write a torn file?
    pub fn torn_write(&self, job_id: u64) -> bool {
        self.roll(FLT_TORN, job_id, 0, self.torn_per_mille)
    }

    /// Should connection `conn_id` drop before writing response `frame`?
    pub fn drop_connection(&self, conn_id: u64, frame: u64) -> bool {
        self.roll(FLT_DROP, conn_id, frame, self.drop_per_mille)
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::off()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_off() {
            return write!(f, "off");
        }
        write!(
            f,
            "seed={},panic={},torn={},drop={}",
            self.seed, self.panic_per_mille, self.torn_per_mille, self.drop_per_mille
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_never_fires_and_needs_no_rng() {
        let plan = FaultPlan::off();
        assert!(plan.is_off());
        for id in 0..64 {
            assert!(!plan.worker_panic(id, id * 3));
            assert!(!plan.torn_write(id));
            assert!(!plan.drop_connection(id, id + 1));
        }
    }

    #[test]
    fn rolls_are_pure_functions_of_seed_and_identity() {
        let plan = FaultPlan { seed: 7, panic_per_mille: 500, torn_per_mille: 500, drop_per_mille: 500 };
        let twin = plan;
        let mut fired = 0;
        for job in 0..200u64 {
            for epoch in 0..4u64 {
                assert_eq!(plan.worker_panic(job, epoch), twin.worker_panic(job, epoch));
                fired += usize::from(plan.worker_panic(job, epoch));
            }
            assert_eq!(plan.torn_write(job), twin.torn_write(job));
            assert_eq!(plan.drop_connection(job, 0), twin.drop_connection(job, 0));
        }
        // ~50% rate over 800 independent rolls: loose bounds, no flake.
        assert!(fired > 250 && fired < 550, "panic rolls wildly off rate: {fired}/800");
    }

    #[test]
    fn fault_families_are_independent_streams() {
        let plan = FaultPlan { seed: 3, panic_per_mille: 500, torn_per_mille: 500, drop_per_mille: 500 };
        // If the streams were shared, these three vectors would agree
        // everywhere; distinct domains must decorrelate them.
        let n = 256u64;
        let panics: Vec<bool> = (0..n).map(|i| plan.worker_panic(i, 0)).collect();
        let torns: Vec<bool> = (0..n).map(|i| plan.torn_write(i)).collect();
        let drops: Vec<bool> = (0..n).map(|i| plan.drop_connection(i, 0)).collect();
        assert_ne!(panics, torns);
        assert_ne!(panics, drops);
        assert_ne!(torns, drops);
    }

    #[test]
    fn seed_changes_the_schedule() {
        let a = FaultPlan { seed: 1, panic_per_mille: 500, ..FaultPlan::off() };
        let b = FaultPlan { seed: 2, panic_per_mille: 500, ..FaultPlan::off() };
        let fa: Vec<bool> = (0..256u64).map(|i| a.worker_panic(i, 0)).collect();
        let fb: Vec<bool> = (0..256u64).map(|i| b.worker_panic(i, 0)).collect();
        assert_ne!(fa, fb);
    }

    #[test]
    fn parse_grammar_roundtrips_and_rejects_malformed_specs() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::off());
        assert_eq!(FaultPlan::parse("off").is_err(), true);
        let plan = FaultPlan::parse("seed=7,panic=50,torn=100,drop=25").unwrap();
        assert_eq!(
            plan,
            FaultPlan { seed: 7, panic_per_mille: 50, torn_per_mille: 100, drop_per_mille: 25 }
        );
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert_eq!(FaultPlan::off().to_string(), "off");
        assert!(FaultPlan::parse("panic=1001").is_err());
        assert!(FaultPlan::parse("panic=-1").is_err());
        assert!(FaultPlan::parse("jitter=5").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("seed=x").is_err());
    }
}
