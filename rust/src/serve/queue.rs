//! Bounded job scheduler: a fixed pool of worker threads draining a
//! FIFO queue of registry job ids.
//!
//! The design mirrors `util::pool`'s scoped workers but for a long-lived
//! service: workers block on a condvar, pop ids in submission order, and
//! drive [`experiment::run_with`](crate::coordinator::experiment::run_with)
//! with an observer that streams per-epoch progress into the registry and
//! honours cancellation at epoch boundaries. Submission is bounded — a
//! full queue rejects rather than buffering without limit — and
//! [`Scheduler::shutdown`] is graceful: it drains every queued job, then
//! joins the workers, so no accepted job is ever dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment;
use crate::serve::registry::Registry;

/// Worker pool + bounded FIFO of job ids.
pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    n_workers: usize,
}

struct Shared {
    registry: Arc<Registry>,
    queue: Mutex<VecDeque<u64>>,
    cv: Condvar,
    shutdown: AtomicBool,
    capacity: usize,
}

impl Scheduler {
    /// Spawn `workers` (≥1) threads over `registry`, with at most
    /// `capacity` (≥1) jobs queued at any time.
    pub fn start(registry: Arc<Registry>, workers: usize, capacity: usize) -> Scheduler {
        let n_workers = workers.max(1);
        let shared = Arc::new(Shared {
            registry,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            capacity: capacity.max(1),
        });
        let handles = (0..n_workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning scheduler worker")
            })
            .collect();
        Scheduler {
            shared,
            workers: Mutex::new(handles),
            n_workers,
        }
    }

    /// Register and enqueue a job; rejects when shutting down or full.
    pub fn submit(&self, config: ExperimentConfig, tag: &str) -> Result<u64> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            bail!("server is shutting down, not accepting jobs");
        }
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.shared.capacity {
            bail!(
                "job queue full ({} queued, capacity {})",
                q.len(),
                self.shared.capacity
            );
        }
        let id = self.shared.registry.submit(config, tag);
        q.push_back(id);
        self.shared.cv.notify_one();
        Ok(id)
    }

    /// Jobs currently waiting for a worker.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn worker_count(&self) -> usize {
        self.n_workers
    }

    /// Graceful shutdown: refuse new submissions, drain every queued job,
    /// join the workers. Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let id = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(id) = q.pop_front() {
                    break Some(id);
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let Some(id) = id else { return };
        run_job(sh, id);
    }
}

/// Execute one job end-to-end, streaming progress into the registry.
fn run_job(sh: &Shared, id: u64) {
    // Cancelled-while-queued jobs are finalized inside mark_running.
    let Some((cfg, cancel)) = sh.registry.mark_running(id) else {
        return;
    };
    let registry = &sh.registry;
    // Classify by whether the run actually stopped early, not by the
    // cancel flag at finish time: a cancel that lands after the final
    // epoch arrived too late — the run completed and must be recorded
    // (and persisted) as done, and a genuine failure keeps its error.
    let mut stopped_early = false;
    let result = experiment::run_with(&cfg, &mut |m| {
        registry.update_progress(id, m.epoch);
        if cancel.load(Ordering::Relaxed) {
            stopped_early = true;
            return false;
        }
        true
    });
    match result {
        Ok(r) if stopped_early => registry.finish_cancelled(id, Some(&r)),
        Ok(r) => registry.finish_ok(id, &r),
        Err(e) => registry.finish_err(id, format!("{e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::registry::JobState;

    fn quick_cfg(seed: u64, policy: Policy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = policy;
        cfg.k = if policy == Policy::Exact { cfg.m() } else { 9 };
        cfg.memory = policy != Policy::Exact;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn drains_all_jobs_on_shutdown_without_drops() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 3, 64);
        let mut ids = Vec::new();
        for (i, p) in [Policy::Exact, Policy::TopK, Policy::RandK, Policy::WeightedK]
            .iter()
            .cycle()
            .take(10)
            .enumerate()
        {
            ids.push(sched.submit(quick_cfg(i as u64, *p), "drain").unwrap());
        }
        // immediate graceful shutdown: every accepted job still completes
        sched.shutdown();
        for id in ids {
            let v = reg.view(id).unwrap();
            assert_eq!(v.state, JobState::Done, "job {id}");
            assert_eq!(v.epochs_done, 2, "job {id}");
        }
        assert_eq!(sched.queue_depth(), 0);
        // post-shutdown submissions are refused
        assert!(sched.submit(quick_cfg(99, Policy::TopK), "").is_err());
    }

    #[test]
    fn capacity_bounds_the_queue() {
        let reg = Arc::new(Registry::new(None).unwrap());
        // exercise the bound directly: fill faster than 1 worker can
        // drain a deliberately slow first job
        let sched = Scheduler::start(reg.clone(), 1, 2);
        let mut slow = quick_cfg(0, Policy::TopK);
        slow.task = Task::Mnist;
        slow.k = 16;
        slow.data_scale = 0.05;
        slow.epochs = 10;
        sched.submit(slow, "slow").unwrap();
        // fill the queue behind the slow job; the bound must kick in
        let mut rejected = false;
        for i in 0..8 {
            if sched.submit(quick_cfg(i, Policy::RandK), "").is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "queue accepted unbounded submissions");
        sched.shutdown();
    }
}
