//! Bounded job scheduler: training jobs drained FIFO by the shared
//! [`util::pool::TaskPool`](crate::util::pool::TaskPool) worker pool,
//! with *thread-slot* accounting for data-parallel jobs.
//!
//! The server's `--workers` value is a budget of **slots** — total
//! training threads across concurrently running jobs. A job with
//! `config.threads = t` occupies `t` slots for its whole run (its
//! `exec` pool spawns `t - 1` extra threads beside the pool worker
//! driving it), so an 8-slot server runs eight `threads=1` jobs, or two
//! `threads=4` jobs, at a time. Jobs that could never fit
//! (`threads > slots_total`) are rejected at submission with a clear
//! protocol error instead of deadlocking the queue; jobs that fit but
//! must wait park on a condvar until running jobs release their slots.
//!
//! Submission is bounded — a full queue rejects rather than buffering
//! without limit — and [`Scheduler::shutdown`] is graceful: it drains
//! every queued job, then joins the workers, so no accepted job is ever
//! dropped.
//!
//! Rejections are **typed** (protocol v8): [`Scheduler::submit`] returns
//! a [`Reject`] carrying a stable machine-readable `reason`
//! (`queue_full` / `shutting_down` / `oversized`) and, for transient
//! conditions, a `retry_after_ms` backoff hint scaled by queue depth —
//! the handler forwards both on the wire and feeds the
//! `repro_rejected_total{reason}` counters. The scheduler also enforces
//! each job's optional `timeout_s` wall-clock budget at epoch
//! boundaries (overruns finalize as `failed: timeout`, releasing the
//! slots) and injects [`FaultPlan`] worker panics for chaos testing.

// Clock reads are deliberate here (queue-wait accounting) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment;
use crate::serve::faults::FaultPlan;
use crate::serve::registry::Registry;
use crate::util::pool::TaskPool;

/// A typed admission rejection (protocol v8). `reason` is the stable
/// wire/metrics label; `retry_after_ms` is `Some` only for transient
/// conditions a client should back off and retry.
#[derive(Debug, Clone)]
pub struct Reject {
    pub reason: &'static str,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl Reject {
    fn permanent(reason: &'static str, message: String) -> Reject {
        Reject { reason, message, retry_after_ms: None }
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Reject {}

/// Worker pool + bounded FIFO of job ids + slot accounting.
pub struct Scheduler {
    shared: Arc<Shared>,
    pool: TaskPool,
    capacity: usize,
}

struct Shared {
    registry: Arc<Registry>,
    /// Slot ledger + admission counter; waiters park on `slot_cv`.
    slots: Mutex<SlotState>,
    slot_cv: Condvar,
    slots_total: usize,
    /// Chaos schedule ([`FaultPlan::off`] in production): worker panics
    /// injected at epoch boundaries, keyed by (job id, epoch).
    faults: FaultPlan,
}

struct SlotState {
    /// Training-thread slots not held by a running job.
    free: usize,
    /// Jobs accepted but not yet running (queued for a worker, or
    /// claimed by one and waiting for slots) — the capacity bound and
    /// `queue_depth` both count these, so a job blocked on slots can
    /// neither vanish from the metrics nor sneak past the bound.
    admitted: usize,
    /// FIFO tickets for slot acquisition: `next_ticket` is issued when a
    /// worker reaches `SlotGuard::acquire`, `now_serving` gates who may
    /// take slots. Without this a high-`threads` job waiting for N
    /// simultaneously-free slots could be overtaken forever by a stream
    /// of small jobs (starvation); with it, acquisition follows the
    /// order in which workers pick jobs up (≈ queue order, not a strict
    /// submission-order guarantee when several workers race), at the
    /// cost of head-of-line blocking while a wide job waits.
    next_ticket: u64,
    now_serving: u64,
}

impl Scheduler {
    /// Spawn a pool of `workers` (≥1) threads over `registry` — also the
    /// slot budget — with at most `capacity` (≥1) jobs queued at a time.
    pub fn start(registry: Arc<Registry>, workers: usize, capacity: usize) -> Scheduler {
        Self::start_with_faults(registry, workers, capacity, FaultPlan::off())
    }

    /// [`Scheduler::start`] with a chaos schedule (tests / `--faults`).
    pub fn start_with_faults(
        registry: Arc<Registry>,
        workers: usize,
        capacity: usize,
        faults: FaultPlan,
    ) -> Scheduler {
        let slots_total = workers.max(1);
        let shared = Arc::new(Shared {
            registry,
            slots: Mutex::new(SlotState {
                free: slots_total,
                admitted: 0,
                next_ticket: 0,
                now_serving: 0,
            }),
            slot_cv: Condvar::new(),
            slots_total,
            faults,
        });
        Scheduler {
            shared,
            pool: TaskPool::new("serve-worker", slots_total),
            capacity: capacity.max(1),
        }
    }

    /// Register and enqueue a job; rejects when shutting down, when the
    /// queue is full, or when the job's `threads` exceeds the pool's
    /// slot budget (it could never be scheduled — failing fast here is
    /// the fix for the historical queue deadlock).
    pub fn submit(&self, config: ExperimentConfig, tag: &str) -> Result<u64, Reject> {
        if self.pool.is_shutdown() {
            return Err(Reject::permanent(
                "shutting_down",
                "server is shutting down, not accepting jobs".into(),
            ));
        }
        let threads = config.threads.max(1);
        if threads > self.shared.slots_total {
            return Err(Reject::permanent(
                "oversized",
                format!(
                    "job requires threads={threads} but the server pool has only {} slot(s); \
                     lower the config's 'threads' or restart the server with more --workers",
                    self.shared.slots_total
                ),
            ));
        }
        {
            // check-and-admit atomically: concurrent submits cannot both
            // squeeze into the last capacity slot
            let mut st = self.shared.slots.lock().unwrap();
            if st.admitted >= self.capacity {
                return Err(Reject {
                    reason: "queue_full",
                    message: format!(
                        "job queue full ({} queued, capacity {})",
                        st.admitted, self.capacity
                    ),
                    // deeper queue → longer hint, so a retrying burst
                    // spreads out instead of hammering a full server
                    retry_after_ms: Some((100 + 25 * st.admitted as u64).min(5_000)),
                });
            }
            st.admitted += 1;
        }
        let id = self.shared.registry.submit(config, tag);
        let sh = self.shared.clone();
        let accepted = self.pool.submit(move || {
            let Some(cancel) = sh.registry.cancel_flag(id) else {
                sh.slots.lock().unwrap().admitted -= 1;
                return;
            };
            // blocks this pool worker until the job's thread budget is
            // free; a job cancelled while queued/waiting steps aside at
            // the head of the line instead of waiting for slots it will
            // never use (Registry::cancel already finalized it)
            let Some(_slots) = SlotGuard::acquire(&sh, threads, &cancel) else {
                return;
            };
            run_job(&sh.registry, id, &sh.faults);
        });
        if !accepted {
            // shutdown raced the entry check: the job was registered but
            // can never run — finalize it instead of leaking a zombie
            self.shared.slots.lock().unwrap().admitted -= 1;
            self.shared
                .registry
                .finish_err(id, "server shut down before the job could start".into());
            return Err(Reject::permanent(
                "shutting_down",
                "server is shutting down, not accepting jobs".into(),
            ));
        }
        Ok(id)
    }

    /// Jobs accepted but not yet running (waiting for a worker or for
    /// slots).
    pub fn queue_depth(&self) -> usize {
        self.shared.slots.lock().unwrap().admitted
    }

    /// Total training-thread slots (the `--workers` budget).
    pub fn worker_count(&self) -> usize {
        self.shared.slots_total
    }

    /// Slots not currently held by running jobs.
    pub fn slots_free(&self) -> usize {
        self.shared.slots.lock().unwrap().free
    }

    /// Slots held by running jobs (`slots_total - slots_free`; a
    /// `threads = t` job holds `t`, so this counts **slots**, not jobs —
    /// the honest utilization numerator under multi-thread jobs).
    pub fn slots_busy(&self) -> usize {
        let st = self.shared.slots.lock().unwrap();
        self.shared.slots_total.saturating_sub(st.free)
    }

    /// Pool workers currently driving a job (obs gauge; each running job
    /// occupies one pool worker regardless of its `threads`).
    pub fn pool_busy(&self) -> usize {
        self.pool.busy()
    }

    /// Jobs queued in the pool but not yet picked up by a worker.
    pub fn pool_pending(&self) -> usize {
        self.pool.pending()
    }

    /// The admission bound: max jobs queued at a time.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Whether the scheduler has begun (or finished) shutting down.
    pub fn is_shutting_down(&self) -> bool {
        self.pool.is_shutdown()
    }

    /// Health probe (protocol v8): round-trip a no-op task through the
    /// worker pool, waiting up to `timeout`. `Some(latency)` proves a
    /// worker picked work up; `None` means the pool is shut down or so
    /// saturated/stuck that nothing drained the probe in time.
    pub fn probe(&self, timeout: Duration) -> Option<Duration> {
        self.pool.probe(timeout)
    }

    /// Graceful shutdown: refuse new submissions, drain every queued job,
    /// join the workers. Idempotent.
    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}

/// RAII slot lease: blocks until `n` slots are free *and* it is this
/// waiter's FIFO turn, returns the slots on drop (also on panic, so a
/// crashed job can't shrink the budget). Acquisition also retires the
/// job from the admission count — it is now running, not queued.
struct SlotGuard<'a> {
    shared: &'a Shared,
    n: usize,
}

impl<'a> SlotGuard<'a> {
    /// `None` means the job was cancelled before it could take its
    /// slots: the ticket line is advanced past it and nothing is held.
    fn acquire(shared: &'a Shared, n: usize, cancel: &AtomicBool) -> Option<SlotGuard<'a>> {
        debug_assert!(n <= shared.slots_total);
        let mut st = shared.slots.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        loop {
            if st.now_serving == ticket {
                if cancel.load(Ordering::Relaxed) {
                    // dead job: step aside without waiting for slots
                    st.now_serving += 1;
                    st.admitted -= 1;
                    shared.slot_cv.notify_all();
                    return None;
                }
                if st.free >= n {
                    break;
                }
            }
            st = shared.slot_cv.wait(st).unwrap();
        }
        st.free -= n;
        st.now_serving += 1;
        st.admitted -= 1;
        // wake the next ticket holder (it may only need now_serving to
        // advance, not slots)
        shared.slot_cv.notify_all();
        Some(SlotGuard { shared, n })
    }
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.slots.lock().unwrap();
        st.free += self.n;
        self.shared.slot_cv.notify_all();
    }
}

/// Execute one job end-to-end, streaming progress into the registry.
fn run_job(registry: &Arc<Registry>, id: u64, faults: &FaultPlan) {
    // Cancelled-while-queued jobs are finalized inside mark_running.
    let Some((cfg, cancel)) = registry.mark_running(id) else {
        return;
    };
    // Classify by whether the run actually stopped early, not by the
    // cancel flag at finish time: a cancel that lands after the final
    // epoch arrived too late — the run completed and must be recorded
    // (and persisted) as done, and a genuine failure keeps its error.
    let mut stopped_early = false;
    // Wall-clock budget (protocol v8): checked between epochs only, so
    // the budget bounds slot occupancy without ever touching the math
    // of the epochs that complete.
    let deadline = cfg
        .timeout_s
        .map(|s| (s, Instant::now() + Duration::from_secs_f64(s)));
    let mut timed_out = false;
    // A panicking run must still finalize the job: TaskPool's worker
    // survives a panic, so without this catch the registry entry would
    // sit in `running` forever while clients poll it.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        experiment::run_with(&cfg, &mut |m| {
            // full epoch frame (protocol v6): advances progress, feeds
            // the watch ring, and refreshes the audit gauges
            registry.record_epoch(id, m);
            if faults.worker_panic(id, m.epoch as u64) {
                panic!("injected worker panic (job {id}, epoch {})", m.epoch);
            }
            if let Some((_, dl)) = deadline {
                if Instant::now() >= dl {
                    timed_out = true;
                    return false;
                }
            }
            if cancel.load(Ordering::Relaxed) {
                stopped_early = true;
                return false;
            }
            true
        })
    }));
    match result {
        Ok(Ok(_)) if timed_out => {
            let (budget, _) = deadline.unwrap();
            registry.finish_err(
                id,
                format!("timeout: exceeded the wall-clock budget of {budget}s"),
            );
        }
        Ok(Ok(r)) if stopped_early => registry.finish_cancelled(id, Some(&r)),
        Ok(Ok(r)) => registry.finish_ok(id, &r),
        Ok(Err(e)) => registry.finish_err(id, format!("{e:#}")),
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            registry.finish_err(id, format!("training panicked: {msg}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::registry::JobState;

    fn quick_cfg(seed: u64, policy: Policy) -> ExperimentConfig {
        use crate::coordinator::config::KSchedule;
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = policy;
        cfg.k = KSchedule::constant(if policy == Policy::Exact { cfg.m() } else { 9 });
        cfg.memory = policy != Policy::Exact;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn drains_all_jobs_on_shutdown_without_drops() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 3, 64);
        let mut ids = Vec::new();
        for (i, p) in [Policy::Exact, Policy::TopK, Policy::RandK, Policy::WeightedK]
            .iter()
            .cycle()
            .take(10)
            .enumerate()
        {
            ids.push(sched.submit(quick_cfg(i as u64, *p), "drain").unwrap());
        }
        // immediate graceful shutdown: every accepted job still completes
        sched.shutdown();
        for id in ids {
            let v = reg.view(id).unwrap();
            assert_eq!(v.state, JobState::Done, "job {id}");
            assert_eq!(v.epochs_done, 2, "job {id}");
        }
        assert_eq!(sched.queue_depth(), 0);
        assert_eq!(sched.slots_free(), 3);
        // post-shutdown submissions are refused
        assert!(sched.submit(quick_cfg(99, Policy::TopK), "").is_err());
    }

    #[test]
    fn capacity_bounds_the_queue() {
        let reg = Arc::new(Registry::new(None).unwrap());
        // exercise the bound directly: fill faster than 1 worker can
        // drain a deliberately slow first job
        let sched = Scheduler::start(reg.clone(), 1, 2);
        let mut slow = quick_cfg(0, Policy::TopK);
        slow.task = Task::Mnist;
        slow.k = crate::coordinator::config::KSchedule::Constant(16);
        slow.data_scale = 0.05;
        slow.epochs = 10;
        sched.submit(slow, "slow").unwrap();
        // fill the queue behind the slow job; the bound must kick in
        let mut rejected = false;
        for i in 0..8 {
            if sched.submit(quick_cfg(i, Policy::RandK), "").is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "queue accepted unbounded submissions");
        sched.shutdown();
    }

    #[test]
    fn oversized_thread_requests_are_rejected_not_deadlocked() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 2, 16);
        let mut cfg = quick_cfg(0, Policy::TopK);
        cfg.threads = 3; // > 2 slots: could never be scheduled
        let err = sched.submit(cfg, "big").unwrap_err().to_string();
        assert!(err.contains("threads=3"), "{err}");
        assert!(err.contains("2 slot"), "{err}");
        // nothing was registered for the rejected job
        assert_eq!(reg.counts().total(), 0);
        // a job that exactly fits the budget still runs
        let mut ok = quick_cfg(1, Policy::TopK);
        ok.threads = 2;
        let id = sched.submit(ok, "fits").unwrap();
        sched.shutdown();
        assert_eq!(reg.view(id).unwrap().state, JobState::Done);
    }

    #[test]
    fn cancelled_queued_wide_job_does_not_block_the_line() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 2, 16);
        // occupy both slots with a slow job
        let mut slow = quick_cfg(0, Policy::TopK);
        slow.threads = 2;
        slow.task = Task::Mnist;
        slow.k = crate::coordinator::config::KSchedule::Constant(16);
        slow.data_scale = 0.05;
        slow.epochs = 4;
        let slow_id = sched.submit(slow, "slow").unwrap();
        // wait until the slow job provably holds both slots, so the wide
        // job below cannot race it to the front and start before the
        // cancel lands
        for _ in 0..2000 {
            if sched.slots_free() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(sched.slots_free(), 0, "slow job never took its slots");
        // a wide job queued behind it, cancelled while queued: it must
        // step aside at the head instead of waiting for 2 free slots
        let mut wide = quick_cfg(1, Policy::TopK);
        wide.threads = 2;
        let wide_id = sched.submit(wide, "wide").unwrap();
        reg.cancel(wide_id).unwrap();
        let mut small_ids = Vec::new();
        for i in 0..3 {
            small_ids.push(sched.submit(quick_cfg(i + 2, Policy::RandK), "small").unwrap());
        }
        sched.shutdown();
        assert_eq!(reg.view(slow_id).unwrap().state, JobState::Done);
        assert_eq!(reg.view(wide_id).unwrap().state, JobState::Cancelled);
        for id in small_ids {
            assert_eq!(reg.view(id).unwrap().state, JobState::Done, "job {id}");
        }
        assert_eq!(sched.queue_depth(), 0, "admitted count leaked");
        assert_eq!(sched.slots_free(), 2, "slots leaked");
    }

    #[test]
    fn rejections_are_typed_with_retry_hints() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 1, 2);
        // oversized: permanent, no retry hint
        let mut cfg = quick_cfg(0, Policy::TopK);
        cfg.threads = 3;
        let rej = sched.submit(cfg, "big").unwrap_err();
        assert_eq!(rej.reason, "oversized");
        assert!(rej.retry_after_ms.is_none());
        // queue_full: transient, hint present and bounded
        let mut slow = quick_cfg(0, Policy::TopK);
        slow.task = Task::Mnist;
        slow.k = crate::coordinator::config::KSchedule::Constant(16);
        slow.data_scale = 0.05;
        slow.epochs = 10;
        sched.submit(slow, "slow").unwrap();
        let mut full = None;
        for i in 0..8 {
            if let Err(rej) = sched.submit(quick_cfg(i, Policy::RandK), "") {
                full = Some(rej);
                break;
            }
        }
        let rej = full.expect("queue never filled");
        assert_eq!(rej.reason, "queue_full");
        let hint = rej.retry_after_ms.expect("queue_full must carry retry_after_ms");
        assert!((1..=5_000).contains(&hint), "{hint}");
        assert!(rej.to_string().contains("queue full"), "{rej}");
        sched.shutdown();
        // shutting_down: permanent
        let rej = sched.submit(quick_cfg(9, Policy::TopK), "").unwrap_err();
        assert_eq!(rej.reason, "shutting_down");
        assert!(rej.retry_after_ms.is_none());
    }

    #[test]
    fn wall_clock_timeout_finalizes_as_failed() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let sched = Scheduler::start(reg.clone(), 1, 8);
        // a multi-epoch job with a budget no epoch count can meet: the
        // first epoch-boundary check after 1ms must finalize it
        let mut cfg = quick_cfg(0, Policy::TopK);
        cfg.epochs = 50;
        cfg.timeout_s = Some(0.001);
        let id = sched.submit(cfg, "budgeted").unwrap();
        sched.shutdown();
        let v = reg.view(id).unwrap();
        assert_eq!(v.state, JobState::Failed, "{:?}", v.error);
        let err = v.error.expect("failed job must carry an error");
        assert!(err.contains("timeout"), "{err}");
        assert!(err.contains("0.001"), "{err}");
        // the timed-out job released its slot
        assert_eq!(sched.slots_free(), 1);
        // an untimed twin still completes: the budget is opt-in
        let reg2 = Arc::new(Registry::new(None).unwrap());
        let sched2 = Scheduler::start(reg2.clone(), 1, 8);
        let id2 = sched2.submit(quick_cfg(0, Policy::TopK), "untimed").unwrap();
        sched2.shutdown();
        assert_eq!(reg2.view(id2).unwrap().state, JobState::Done);
    }

    #[test]
    fn injected_panics_finalize_jobs_and_spare_the_pool() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let always = FaultPlan { seed: 1, panic_per_mille: 1000, ..FaultPlan::off() };
        let sched = Scheduler::start_with_faults(reg.clone(), 2, 16, always);
        let ids: Vec<u64> = (0..4)
            .map(|i| sched.submit(quick_cfg(i, Policy::TopK), "chaos").unwrap())
            .collect();
        sched.shutdown();
        for id in ids {
            let v = reg.view(id).unwrap();
            assert_eq!(v.state, JobState::Failed, "job {id}");
            let err = v.error.expect("panicked job must carry an error");
            assert!(err.contains("injected worker panic"), "{err}");
        }
        // the panics killed jobs, not workers: every slot came back
        assert_eq!(sched.slots_free(), 2, "slots leaked across injected panics");
        assert_eq!(sched.queue_depth(), 0);
    }

    #[test]
    fn slot_accounting_multiplies_by_job_threads() {
        let reg = Arc::new(Registry::new(None).unwrap());
        // 4 slots: a threads=4 job must exclude everything else while it
        // runs, then the singles all complete
        let sched = Scheduler::start(reg.clone(), 4, 32);
        let mut big = quick_cfg(0, Policy::TopK);
        big.threads = 4;
        big.task = Task::Mnist;
        big.k = crate::coordinator::config::KSchedule::Constant(16);
        big.data_scale = 0.05;
        big.epochs = 4;
        let big_id = sched.submit(big, "big").unwrap();
        let mut ids = vec![big_id];
        for i in 0..6 {
            let mut c = quick_cfg(i + 1, Policy::RandK);
            c.threads = 1;
            ids.push(sched.submit(c, "small").unwrap());
        }
        sched.shutdown();
        for id in ids {
            assert_eq!(reg.view(id).unwrap().state, JobState::Done, "job {id}");
        }
        assert_eq!(sched.slots_free(), 4, "slots leaked");
    }
}
