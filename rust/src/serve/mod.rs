//! Trainer-as-a-service: a long-lived TCP server multiplexing Mem-AOP-GD
//! training jobs over the coordinator's worker pool.
//!
//! The paper's economics — approximate the outer-product gradient, bank
//! the residual in memory, spend a fraction of the FLOPs — pay off when
//! *many* cheap runs share hardware. This subsystem turns the one-shot
//! CLI coordinator into that shared service:
//!
//! * [`protocol`] — newline-delimited JSON over TCP (`submit` / `status` /
//!   `result` / `list` / `cancel` / `metrics` / `watch` / `ping` /
//!   `shutdown`), plus the blocking [`Client`] used by
//!   `examples/serve_client.rs`;
//! * [`registry`] — the authoritative job table
//!   (`queued → running → done | failed | cancelled`), persisted through
//!   `coordinator::checkpoint` so completed runs survive restarts; holds
//!   each live job's bounded per-epoch frame ring behind the `watch`
//!   long-poll (protocol v6) and the `repro_audit_*` gauge snapshots;
//! * [`queue`] — bounded FIFO over the shared `util::pool::TaskPool`
//!   driving `experiment::run_with` with per-epoch progress streaming,
//!   epoch-boundary cancellation, and thread-slot accounting for
//!   data-parallel jobs (a `threads = t` job holds `t` of the server's
//!   `--workers` slots; oversized jobs are rejected, never deadlocked);
//!   graceful shutdown drains every accepted job;
//! * [`handlers`] — socket-free request dispatch ([`ServerState`]);
//! * [`server`] — the accept loop ([`Server`] / [`ServeOptions`]);
//! * [`faults`] — deterministic, seed-keyed fault injection
//!   ([`FaultPlan`]: worker panics, torn registry writes, dropped
//!   connections) for the chaos tests; compiled down to nothing on the
//!   hot path when off.
//!
//! Resilience (protocol v8): submissions are admission-controlled — a
//! bounded queue and an optional per-client token bucket reject with
//! typed reasons and `retry_after_ms` hints instead of hanging; stalled
//! connections hit read deadlines; jobs can carry a wall-clock
//! `timeout_s` budget; a `health` op round-trips a probe through the
//! worker pool. The [`Client`]'s `submit_with_retry` honors the hints
//! with deterministic seeded backoff.
//!
//! Determinism is preserved end-to-end: a job's curve is bit-identical to
//! a direct [`experiment::run`](crate::coordinator::experiment::run) of
//! the same config, which `rust/tests/serve.rs` asserts seed-for-seed —
//! including under injected faults, where every *completed* job's curve
//! still matches its fault-free twin.
//!
//! Start one with `repro serve --addr 127.0.0.1:7070 --registry-dir runs`
//! and drive it with `cargo run --example serve_client` (see README.md
//! for the wire schema and an example session).

pub mod faults;
pub mod handlers;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod server;

pub use faults::FaultPlan;
pub use handlers::{Limits, ServerState};
pub use protocol::{Client, RetryPolicy, PROTOCOL_VERSION};
pub use queue::{Reject, Scheduler};
pub use registry::{JobState, JobView, Registry};
pub use server::{ServeOptions, Server};
