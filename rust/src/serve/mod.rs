//! Trainer-as-a-service: a long-lived TCP server multiplexing Mem-AOP-GD
//! training jobs over the coordinator's worker pool.
//!
//! The paper's economics — approximate the outer-product gradient, bank
//! the residual in memory, spend a fraction of the FLOPs — pay off when
//! *many* cheap runs share hardware. This subsystem turns the one-shot
//! CLI coordinator into that shared service:
//!
//! * [`protocol`] — newline-delimited JSON over TCP (`submit` / `status` /
//!   `result` / `list` / `cancel` / `metrics` / `watch` / `ping` /
//!   `shutdown`), plus the blocking [`Client`] used by
//!   `examples/serve_client.rs`;
//! * [`registry`] — the authoritative job table
//!   (`queued → running → done | failed | cancelled`), persisted through
//!   `coordinator::checkpoint` so completed runs survive restarts; holds
//!   each live job's bounded per-epoch frame ring behind the `watch`
//!   long-poll (protocol v6) and the `repro_audit_*` gauge snapshots;
//! * [`queue`] — bounded FIFO over the shared `util::pool::TaskPool`
//!   driving `experiment::run_with` with per-epoch progress streaming,
//!   epoch-boundary cancellation, and thread-slot accounting for
//!   data-parallel jobs (a `threads = t` job holds `t` of the server's
//!   `--workers` slots; oversized jobs are rejected, never deadlocked);
//!   graceful shutdown drains every accepted job;
//! * [`handlers`] — socket-free request dispatch ([`ServerState`]);
//! * [`server`] — the accept loop ([`Server`] / [`ServeOptions`]).
//!
//! Determinism is preserved end-to-end: a job's curve is bit-identical to
//! a direct [`experiment::run`](crate::coordinator::experiment::run) of
//! the same config, which `rust/tests/serve.rs` asserts seed-for-seed.
//!
//! Start one with `repro serve --addr 127.0.0.1:7070 --registry-dir runs`
//! and drive it with `cargo run --example serve_client` (see README.md
//! for the wire schema and an example session).

pub mod handlers;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod server;

pub use handlers::ServerState;
pub use protocol::{Client, PROTOCOL_VERSION};
pub use queue::Scheduler;
pub use registry::{JobState, JobView, Registry};
pub use server::{ServeOptions, Server};
