//! Wire protocol of the training-job server: newline-delimited JSON over
//! TCP (one request object per line, one response object per line, using
//! the in-tree `util::json` — no external dependencies).
//!
//! Requests are `{"op": <name>, ...}` objects:
//!
//! | op         | fields                      | response payload                   |
//! |------------|-----------------------------|------------------------------------|
//! | `submit`   | `config` (experiment JSON), | `id` — job id                      |
//! |            | `tag` (optional)            |                                    |
//! | `status`   | `id`, `compact` (optional)  | `job` — job view                   |
//! | `result`   | `id`                        | `job`, `config`, `curve`           |
//! | `list`     | `compact` (optional)        | `jobs` — array of job views        |
//! | `cancel`   | `id`                        | `state` — `cancelled`/`cancelling` |
//! | `metrics`  | `format` (optional)         | queue/job/FLOP/latency metrics     |
//! | `watch`    | `id`, `cursor` (optional),  | `epochs`, `cursor`, `state`        |
//! |            | `wait_ms` (optional)        |                                    |
//! | `health`   | `wait_ms` (optional)        | `status`, pool/queue gauges        |
//! | `ping`     | —                           | `protocol`, `uptime_s`             |
//! | `shutdown` | —                           | `state: shutting-down`             |
//!
//! Every response carries `"ok": true` or `"ok": false` + `"error"`.
//! The `config` object is exactly `ExperimentConfig::to_json` (task,
//! policy, k, memory, epochs, lr, schedule, seed, backend, data_scale,
//! threads); the `curve` object is `RunCurve::to_json` (per-epoch
//! losses, accuracy, memory mass, cumulative backward FLOPs from
//! `aop::flops`, rows/sec throughput).
//!
//! `threads` (protocol v2, optional — v1 frames default to 1) is the
//! job's data-parallel worker count: the scheduler accounts `threads`
//! pool slots for it while it runs, and rejects at submission any job
//! whose `threads` exceeds the server's slot budget. Determinism
//! guarantee: `threads` never changes a job's curve or final weights,
//! only its wall-clock (see the `exec` subsystem docs).
//!
//! Protocol v3 adds the layer-graph surface: `config` may carry a
//! `layers` array (per-layer `width`/`activation` plus optional
//! `{k, policy, memory}` overrides, native backend only), job views
//! report the resolved per-layer config under `layers`, and every curve
//! epoch carries a `layers` array with that layer's mean `k_effective`
//! and cumulative `backward_flops`. v1/v2 frames (no `layers`) remain
//! accepted and mean the flat single-layer model.
//!
//! Protocol v4 makes every `k` (flat and per-layer) a **K schedule**: a
//! plain number still means a constant budget — constant configs emit
//! exactly the v1-v3 frame shape — while a spec string
//! (`step:<k0>:<every>:<gamma>` | `cosine:<k0>:<min-frac>` |
//! `linear:<from>:<to>`) anneals the budget per epoch, clamped to
//! `[1, M]`. Job views echo the schedule per layer plus its resolved
//! first/last-epoch budgets (`k_first`/`k_last`); the realized per-epoch
//! budget is in each curve epoch's `layers[].k_effective`. Degenerate
//! schedule parameters (zero step period, gamma outside (0, 1],
//! min_frac outside [0, 1], zero budgets) are rejected at submit with an
//! `ok:false` protocol error.
//!
//! Protocol v5 is the observability surface (`obs` subsystem). `status`
//! and `list` accept an optional `compact: true` flag returning only the
//! fields pollers watch (id/tag/state/epochs/error/cancel) — no config
//! echo, resolved layer plan, or phase rollup. Full job views of
//! finished jobs carry a `phases` object (per-phase count/total-ns/
//! p50/p99 plus per-layer realized-K and backward-FLOP sums; `null`
//! until done and for jobs restored from disk). `metrics` accepts
//! `format`: `"json"` (default, the full v2+ object extended with
//! `slots_busy`, `utilization`, pool gauges and a per-op `ops` block),
//! `"compact"` (the handful of gauges pollers scrape, no policy
//! rollups or op histograms), or `"prometheus"` (text exposition in
//! the response's `text` field — metric names are a stability promise,
//! see README §Observability). Older frames remain accepted and mean
//! the non-compact JSON forms.
//!
//! Protocol v6 is the training-dynamics streaming surface. `watch` is a
//! long-poll: it returns every epoch record of job `id` with epoch
//! number > `cursor` (default 0 = from the start) as soon as at least
//! one exists, blocking up to `wait_ms` (default 10s, server-clamped)
//! when none do yet; the response carries `epochs` (full per-epoch
//! metric objects, including per-layer selection diagnostics and —
//! when the job's config set an `audit` cadence — per-layer
//! gradient-fidelity `audit` records), the `cursor` to pass next, and
//! the job's current `state` so clients stop cleanly on
//! `done`/`failed`/`cancelled`. Epoch records are held in a bounded
//! per-job ring: a cursor older than the ring's tail resumes from the
//! oldest retained epoch (no error, no duplicates). Audit fidelity for
//! the last audited epoch of each job is also exported as
//! `repro_audit_*` Prometheus gauges.
//!
//! Protocol v7 is the mixed-precision surface. `config` may carry flat
//! `trace` (`f32` | `bf16` | `q8` forward-trace storage) and `accum`
//! (`f32` | `f64` | `kahan` backward accumulation) fields, and each
//! `layers[]` entry an optional `trace` override (native backend only;
//! unknown mode strings are `ok:false` protocol errors with the valid
//! spellings listed). Job views echo the *resolved* per-layer precision
//! — `trace`/`accum` plus the backward-read `trace_bytes` footprint —
//! after the head/exact-policy f32 pins, and audit records carry the
//! input-trace mode they measured under. The total footprint is
//! exported as the `repro_trace_bytes` Prometheus gauge. All-f32
//! configs and their job views serialize without any of the new keys:
//! pre-v7 frames remain accepted and byte-identical.
//!
//! Protocol v8 is the resilience surface. Rejections become
//! *structured*: an admission-control refusal (`queue_full`,
//! `rate_limited`, `shutting_down`, `oversized`) still carries the
//! human-readable `error` but adds a machine-readable `reason` and —
//! when the condition is transient — a `retry_after_ms` hint that
//! well-behaved clients honor before retrying ([`Client::submit_with_retry`]
//! implements bounded exponential backoff with deterministic seeded
//! jitter around it). `config` may carry a `timeout_s` wall-clock
//! budget finalizing overrunning jobs as `failed: timeout`. The new
//! `health` op round-trips a probe task through the worker pool and
//! reports `status` (`"ok"`/`"degraded"`), pool liveness, and queue
//! depth; the same signals are exported as the `repro_health_status`
//! gauge and `repro_rejected_total{reason}` counters. Pre-v8 frames
//! remain accepted and byte-identical: successful responses carry no
//! new keys, and `reason`/`retry_after_ms` appear only on rejections.
//!
//! [`Client`] is a small blocking client used by `examples/serve_client.rs`
//! and the integration tests.

// Clock reads are deliberate here (client-side retry backoff timing) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::metrics::RunCurve;
use crate::util::json::{self, Json};

/// Version stamp reported by `ping` (bump on wire-format changes).
/// v2: `config.threads` field + scheduler slot accounting (`metrics`
/// reports `slots_total`/`slots_free`). v3: layer-graph configs
/// (`config.layers`), resolved per-layer config in job views, and
/// per-layer `k_effective`/FLOPs in curve epochs. v4: `k` fields accept
/// K-schedule strings (numbers still mean constants) and job views echo
/// resolved `k_first`/`k_last` per layer. v5: observability — `compact`
/// views on `status`/`list`, `phases` rollups in full job views, and
/// `metrics` format selection (json/compact/prometheus) with per-op
/// latency histograms. v6: training-dynamics streaming — the `watch`
/// long-poll op (per-epoch metric frames with selection diagnostics and
/// gradient-fidelity audit records, cursor-resumable), the config
/// `audit` cadence field, and `repro_audit_*` Prometheus gauges. v7:
/// mixed precision — config `trace`/`accum` knobs (flat + per-layer
/// trace overrides), resolved per-layer `trace`/`accum`/`trace_bytes`
/// in job views, the `trace` field on audit records, and the
/// `repro_trace_bytes` Prometheus gauge. v8: resilience — structured
/// rejections (`reason` + `retry_after_ms` on admission refusals), the
/// config `timeout_s` wall-clock budget, the `health` probe op, and
/// the `repro_health_status`/`repro_rejected_total` Prometheus
/// families. Older frames remain accepted.
pub const PROTOCOL_VERSION: u64 = 8;

/// Rendering of the `metrics` response (protocol v5 `format` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsFormat {
    /// Full JSON object (the historical shape, extended).
    #[default]
    Json,
    /// Only the gauges pollers scrape — no rollups or op histograms.
    Compact,
    /// Prometheus text exposition carried in the `text` field.
    Prometheus,
}

impl MetricsFormat {
    pub fn parse(name: &str) -> Result<MetricsFormat> {
        match name {
            "json" => Ok(MetricsFormat::Json),
            "compact" => Ok(MetricsFormat::Compact),
            "prometheus" => Ok(MetricsFormat::Prometheus),
            other => bail!(
                "unknown metrics format '{other}' (expected json, compact or prometheus)"
            ),
        }
    }
}

/// A parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    Submit { config: ExperimentConfig, tag: String },
    Status { id: u64, compact: bool },
    Result { id: u64 },
    List { compact: bool },
    Cancel { id: u64 },
    Metrics { format: MetricsFormat },
    Watch { id: u64, cursor: usize, wait_ms: u64 },
    Health { wait_ms: u64 },
    Ping,
    Shutdown,
}

impl Request {
    /// Parse one request frame; errors are protocol-level (reported back
    /// to the client as `ok:false`, never closing the connection).
    pub fn from_json(v: &Json) -> Result<Request> {
        let op = v
            .get("op")
            .and_then(|o| o.as_str())
            .ok_or_else(|| anyhow!("missing string field 'op'"))?;
        let id = || -> Result<u64> {
            v.get("id")
                .and_then(|n| n.as_f64())
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| anyhow!("op '{op}' requires an integer 'id' field"))
        };
        // v5 optional flags; absent fields mean the historical forms
        let compact = || v.get("compact").and_then(|b| b.as_bool()).unwrap_or(false);
        Ok(match op {
            "submit" => {
                let cfg = v
                    .get("config")
                    .ok_or_else(|| anyhow!("submit requires a 'config' object"))?;
                let config = ExperimentConfig::from_json(cfg)
                    .map_err(|e| anyhow!("bad config: {e:#}"))?;
                let tag = v
                    .get("tag")
                    .and_then(|t| t.as_str())
                    .unwrap_or("")
                    .to_string();
                Request::Submit { config, tag }
            }
            "status" => Request::Status { id: id()?, compact: compact() },
            "result" => Request::Result { id: id()? },
            "list" => Request::List { compact: compact() },
            "cancel" => Request::Cancel { id: id()? },
            "metrics" => {
                let format = match v.get("format").and_then(|f| f.as_str()) {
                    Some(name) => MetricsFormat::parse(name)?,
                    None => MetricsFormat::Json,
                };
                Request::Metrics { format }
            }
            "watch" => {
                // v6 long-poll; optional fields keep the frame minimal
                let opt_int = |k: &str, default: f64| -> Result<f64> {
                    match v.get(k) {
                        None => Ok(default),
                        Some(n) => n
                            .as_f64()
                            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                            .ok_or_else(|| anyhow!("watch field '{k}' must be a non-negative integer")),
                    }
                };
                Request::Watch {
                    id: id()?,
                    cursor: opt_int("cursor", 0.0)? as usize,
                    wait_ms: opt_int("wait_ms", 10_000.0)? as u64,
                }
            }
            "health" => {
                // v8 probe; wait_ms bounds the pool round-trip wait
                let wait_ms = match v.get("wait_ms") {
                    None => 1_000.0,
                    Some(n) => n
                        .as_f64()
                        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                        .ok_or_else(|| {
                            anyhow!("health field 'wait_ms' must be a non-negative integer")
                        })?,
                };
                Request::Health { wait_ms: wait_ms as u64 }
            }
            "ping" => Request::Ping,
            "shutdown" => Request::Shutdown,
            other => bail!(
                "unknown op '{other}' (expected one of: submit, status, result, \
                 list, cancel, metrics, watch, health, ping, shutdown)"
            ),
        })
    }
}

/// `{"ok": true, ...fields}`.
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.append(&mut fields);
    json::obj(pairs)
}

/// `{"ok": false, "error": msg}`.
pub fn err_response(msg: &str) -> Json {
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::s(msg))])
}

/// Structured admission rejection (protocol v8): the plain error
/// envelope plus a machine-readable `reason` and, for transient
/// conditions, a `retry_after_ms` hint clients back off by.
pub fn err_rejection(msg: &str, reason: &str, retry_after_ms: Option<u64>) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", json::s(msg)),
        ("reason", json::s(reason)),
    ];
    if let Some(ms) = retry_after_ms {
        pairs.push(("retry_after_ms", json::num(ms as f64)));
    }
    json::obj(pairs)
}

/// Whether a response frame reports success.
pub fn is_ok(v: &Json) -> bool {
    v.get("ok").and_then(|b| b.as_bool()) == Some(true)
}

/// Write one frame (compact JSON + `\n`) and flush.
pub fn write_json<W: Write>(w: &mut W, v: &Json) -> std::io::Result<()> {
    let mut line = v.dump();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Read one frame; `Ok(None)` on clean EOF. Blank lines are skipped.
pub fn read_json<R: BufRead>(r: &mut R) -> Result<Option<Json>> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = r.read_line(&mut line).context("reading frame")?;
        if n == 0 {
            return Ok(None);
        }
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        return json::parse(t)
            .map(Some)
            .map_err(|e| anyhow!("bad json frame: {e}"));
    }
}

/// Client-side retry policy for [`Client::submit_with_retry`] (protocol
/// v8): bounded exponential backoff with deterministic seeded jitter.
/// The server's `retry_after_ms` hint, when present, replaces the
/// exponential base for that attempt — the jitter still applies so a
/// burst of identical clients doesn't re-collide on the hinted instant.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Maximum retries after the first attempt before giving up.
    pub attempts: u32,
    /// First backoff delay; doubles per retry up to `max_ms`.
    pub base_ms: u64,
    /// Backoff ceiling.
    pub max_ms: u64,
    /// Seed for the deterministic jitter stream (counter-based, so
    /// retry N of a given client always jitters identically).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { attempts: 6, base_ms: 50, max_ms: 2_000, seed: 0 }
    }
}

// The retry-jitter stream-domain tag lives in the central registry
// (`tensor::rng::domains::STREAM_RETRY`, repro-lint rule R1) — same
// value as the historical local constant, now collision-checked.

/// Delay before retry number `attempt` (1-based): the server's
/// `retry_after_ms` hint when given, else exponential backoff from
/// `base_ms`, capped at `max_ms`, plus deterministic jitter in
/// `[0, delay/2]`. Pure function of `(policy, attempt, hint)`.
pub fn retry_delay(policy: &RetryPolicy, attempt: u32, retry_after_ms: Option<u64>) -> Duration {
    let exp = policy
        .base_ms
        .saturating_mul(1u64 << attempt.saturating_sub(1).min(20))
        .min(policy.max_ms);
    let base = retry_after_ms.unwrap_or(exp).min(policy.max_ms.max(exp));
    let jitter = if base == 0 {
        0
    } else {
        let mut rng = crate::tensor::rng::Rng::for_stream(
            policy.seed ^ crate::tensor::rng::domains::STREAM_RETRY,
            0,
            u64::from(attempt),
        );
        rng.next_u64() % (base / 2 + 1)
    };
    Duration::from_millis(base + jitter)
}

/// Blocking protocol client (one TCP connection, serial request/response).
/// Remembers its address so [`Client::reconnect`] and the retrying
/// submit path can re-dial after a dropped connection.
pub struct Client {
    addr: String,
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client {
            addr: addr.to_string(),
            writer: stream,
            reader,
        })
    }

    /// Drop the current connection and dial the same address again.
    pub fn reconnect(&mut self) -> Result<()> {
        *self = Client::connect(&self.addr)?;
        Ok(())
    }

    /// Send one frame and read the response (no `ok` check).
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        write_json(&mut self.writer, req).context("sending request")?;
        read_json(&mut self.reader)?
            .ok_or_else(|| anyhow!("server closed the connection"))
    }

    fn call_ok(&mut self, req: &Json) -> Result<Json> {
        let resp = self.call(req)?;
        if !is_ok(&resp) {
            bail!(
                "server error: {}",
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("<no message>")
            );
        }
        Ok(resp)
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.call_ok(&json::obj(vec![("op", json::s("ping"))]))
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, cfg: &ExperimentConfig, tag: &str) -> Result<u64> {
        let req = json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
            ("tag", json::s(tag)),
        ]);
        let resp = self.call_ok(&req)?;
        resp.get("id")
            .and_then(|n| n.as_f64())
            .map(|n| n as u64)
            .ok_or_else(|| anyhow!("submit response missing 'id'"))
    }

    /// Submit with client-side resilience (protocol v8): transient
    /// rejections (`queue_full`, `rate_limited`) back off per `policy`
    /// honoring the server's `retry_after_ms` hint; a dropped
    /// connection re-dials and retries (deterministic configs make a
    /// duplicate submit harmless — the twin trains the same curve).
    /// Permanent rejections (bad config, oversized threads) fail
    /// immediately. Returns `(job_id, retries_used)`.
    pub fn submit_with_retry(
        &mut self,
        cfg: &ExperimentConfig,
        tag: &str,
        policy: &RetryPolicy,
    ) -> Result<(u64, u32)> {
        let req = json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
            ("tag", json::s(tag)),
        ]);
        let mut retries = 0u32;
        loop {
            let mut hint = None;
            match self.call(&req) {
                Ok(resp) if is_ok(&resp) => {
                    let id = resp
                        .get("id")
                        .and_then(|n| n.as_f64())
                        .map(|n| n as u64)
                        .ok_or_else(|| anyhow!("submit response missing 'id'"))?;
                    return Ok((id, retries));
                }
                Ok(resp) => {
                    let reason = resp.get("reason").and_then(|r| r.as_str()).unwrap_or("");
                    if !matches!(reason, "queue_full" | "rate_limited") {
                        bail!(
                            "server error: {}",
                            resp.get("error")
                                .and_then(|e| e.as_str())
                                .unwrap_or("<no message>")
                        );
                    }
                    hint = resp
                        .get("retry_after_ms")
                        .and_then(|n| n.as_f64())
                        .map(|n| n as u64);
                }
                Err(e) => {
                    // io-level failure (dropped/reset connection):
                    // re-dial before the next attempt
                    if retries >= policy.attempts {
                        return Err(e.context(format!("submit gave up after {retries} retries")));
                    }
                    self.reconnect()?;
                }
            }
            if retries >= policy.attempts {
                bail!("submit gave up after {retries} retries (server still rejecting)");
            }
            retries += 1;
            std::thread::sleep(retry_delay(policy, retries, hint));
        }
    }

    /// Health probe (protocol v8): round-trips a no-op task through the
    /// worker pool. Returns the full response (`status`, gauges).
    pub fn health(&mut self) -> Result<Json> {
        self.call_ok(&json::obj(vec![("op", json::s("health"))]))
    }

    /// Job view for one id.
    pub fn status(&mut self, id: u64) -> Result<Json> {
        let req = json::obj(vec![("op", json::s("status")), ("id", json::num(id as f64))]);
        let resp = self.call_ok(&req)?;
        resp.get("job")
            .cloned()
            .ok_or_else(|| anyhow!("status response missing 'job'"))
    }

    /// Compact job view (protocol v5): only the polled fields.
    pub fn status_compact(&mut self, id: u64) -> Result<Json> {
        let req = json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(id as f64)),
            ("compact", Json::Bool(true)),
        ]);
        let resp = self.call_ok(&req)?;
        resp.get("job")
            .cloned()
            .ok_or_else(|| anyhow!("status response missing 'job'"))
    }

    /// Poll until the job reaches a terminal state; returns the final view.
    pub fn wait(&mut self, id: u64, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let job = self.status(id)?;
            let state = job
                .get("state")
                .and_then(|s| s.as_str())
                .unwrap_or("")
                .to_string();
            if matches!(state.as_str(), "done" | "failed" | "cancelled") {
                return Ok(job);
            }
            if Instant::now() > deadline {
                bail!("timed out waiting for job {id} (last state '{state}')");
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Fetch a completed job's config + loss curve.
    pub fn result(&mut self, id: u64) -> Result<(ExperimentConfig, RunCurve)> {
        let req = json::obj(vec![("op", json::s("result")), ("id", json::num(id as f64))]);
        let resp = self.call_ok(&req)?;
        let cfg = ExperimentConfig::from_json(
            resp.get("config")
                .ok_or_else(|| anyhow!("result response missing 'config'"))?,
        )?;
        let curve = RunCurve::from_json(
            resp.get("curve")
                .ok_or_else(|| anyhow!("result response missing 'curve'"))?,
        )?;
        Ok((cfg, curve))
    }

    /// All job views.
    pub fn list(&mut self) -> Result<Vec<Json>> {
        let resp = self.call_ok(&json::obj(vec![("op", json::s("list"))]))?;
        Ok(resp
            .get("jobs")
            .and_then(|a| a.as_arr())
            .unwrap_or(&[])
            .to_vec())
    }

    /// Cancel a job; returns `cancelled` (was queued) or `cancelling`
    /// (running — takes effect at the next epoch boundary).
    pub fn cancel(&mut self, id: u64) -> Result<String> {
        let req = json::obj(vec![("op", json::s("cancel")), ("id", json::num(id as f64))]);
        let resp = self.call_ok(&req)?;
        Ok(resp
            .get("state")
            .and_then(|s| s.as_str())
            .unwrap_or("")
            .to_string())
    }

    /// Long-poll one batch of epoch records past `cursor` (protocol v6).
    /// Returns `(epochs, next_cursor, state)`; an empty batch after
    /// `wait_ms` of quiet is not an error. Stop once `state` is
    /// terminal (`done`/`failed`/`cancelled`) and the batch is empty.
    pub fn watch(
        &mut self,
        id: u64,
        cursor: usize,
        wait_ms: u64,
    ) -> Result<(Vec<Json>, usize, String)> {
        let req = json::obj(vec![
            ("op", json::s("watch")),
            ("id", json::num(id as f64)),
            ("cursor", json::num(cursor as f64)),
            ("wait_ms", json::num(wait_ms as f64)),
        ]);
        let resp = self.call_ok(&req)?;
        let epochs = resp
            .get("epochs")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("watch response missing 'epochs'"))?
            .to_vec();
        let next = resp
            .get("cursor")
            .and_then(|n| n.as_usize())
            .ok_or_else(|| anyhow!("watch response missing 'cursor'"))?;
        let state = resp
            .get("state")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow!("watch response missing 'state'"))?
            .to_string();
        Ok((epochs, next, state))
    }

    /// Server metrics snapshot.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call_ok(&json::obj(vec![("op", json::s("metrics"))]))
    }

    /// Compact metrics snapshot (protocol v5): gauges only.
    pub fn metrics_compact(&mut self) -> Result<Json> {
        self.call_ok(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("compact")),
        ]))
    }

    /// Prometheus text exposition (protocol v5): the rendered scrape
    /// body carried in the response's `text` field.
    pub fn metrics_prometheus(&mut self) -> Result<String> {
        let resp = self.call_ok(&json::obj(vec![
            ("op", json::s("metrics")),
            ("format", json::s("prometheus")),
        ]))?;
        resp.get("text")
            .and_then(|t| t.as_str())
            .map(|t| t.to_string())
            .ok_or_else(|| anyhow!("prometheus metrics response missing 'text'"))
    }

    /// Ask the server to shut down gracefully.
    pub fn shutdown(&mut self) -> Result<()> {
        self.call_ok(&json::obj(vec![("op", json::s("shutdown"))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Task;

    #[test]
    fn parses_every_op() {
        let cfg = ExperimentConfig::preset(Task::Energy);
        let submit = json::obj(vec![
            ("op", json::s("submit")),
            ("config", cfg.to_json()),
            ("tag", json::s("t1")),
        ]);
        match Request::from_json(&submit).unwrap() {
            Request::Submit { config, tag } => {
                assert_eq!(config.task, Task::Energy);
                assert_eq!(tag, "t1");
            }
            other => panic!("{other:?}"),
        }
        for (op, want_id) in [
            ("status", true),
            ("result", true),
            ("cancel", true),
            ("watch", true),
            ("list", false),
            ("metrics", false),
            ("health", false),
            ("ping", false),
            ("shutdown", false),
        ] {
            let mut pairs = vec![("op", json::s(op))];
            if want_id {
                pairs.push(("id", json::num(7.0)));
            }
            assert!(
                Request::from_json(&json::obj(pairs)).is_ok(),
                "op {op} failed"
            );
        }
    }

    #[test]
    fn parses_v5_observability_fields() {
        // absent flags mean the historical forms
        let st = json::obj(vec![("op", json::s("status")), ("id", json::num(1.0))]);
        assert!(matches!(
            Request::from_json(&st).unwrap(),
            Request::Status { compact: false, .. }
        ));
        let st = json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(1.0)),
            ("compact", Json::Bool(true)),
        ]);
        assert!(matches!(
            Request::from_json(&st).unwrap(),
            Request::Status { id: 1, compact: true }
        ));
        let ls = json::obj(vec![("op", json::s("list")), ("compact", Json::Bool(true))]);
        assert!(matches!(Request::from_json(&ls).unwrap(), Request::List { compact: true }));
        for (name, want) in [
            ("json", MetricsFormat::Json),
            ("compact", MetricsFormat::Compact),
            ("prometheus", MetricsFormat::Prometheus),
        ] {
            let m = json::obj(vec![("op", json::s("metrics")), ("format", json::s(name))]);
            match Request::from_json(&m).unwrap() {
                Request::Metrics { format } => assert_eq!(format, want),
                other => panic!("{other:?}"),
            }
        }
        assert!(matches!(
            Request::from_json(&json::obj(vec![("op", json::s("metrics"))])).unwrap(),
            Request::Metrics { format: MetricsFormat::Json }
        ));
        // unknown formats are protocol errors, not silently defaulted
        let bad = json::obj(vec![("op", json::s("metrics")), ("format", json::s("xml"))]);
        let err = Request::from_json(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("unknown metrics format"), "{err:#}");
    }

    #[test]
    fn parses_v6_watch_fields() {
        // minimal frame: cursor defaults to 0, wait_ms to the 10s default
        let w = json::obj(vec![("op", json::s("watch")), ("id", json::num(3.0))]);
        assert!(matches!(
            Request::from_json(&w).unwrap(),
            Request::Watch { id: 3, cursor: 0, wait_ms: 10_000 }
        ));
        let w = json::obj(vec![
            ("op", json::s("watch")),
            ("id", json::num(3.0)),
            ("cursor", json::num(5.0)),
            ("wait_ms", json::num(250.0)),
        ]);
        assert!(matches!(
            Request::from_json(&w).unwrap(),
            Request::Watch { id: 3, cursor: 5, wait_ms: 250 }
        ));
        // id stays mandatory; malformed optionals are protocol errors
        assert!(Request::from_json(&json::obj(vec![("op", json::s("watch"))])).is_err());
        for (k, v) in [("cursor", -1.0), ("cursor", 1.5), ("wait_ms", -2.0)] {
            let bad = json::obj(vec![
                ("op", json::s("watch")),
                ("id", json::num(3.0)),
                (k, json::num(v)),
            ]);
            assert!(Request::from_json(&bad).is_err(), "{k}={v}");
        }
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(Request::from_json(&json::obj(vec![])).is_err());
        assert!(Request::from_json(&json::obj(vec![("op", json::s("bogus"))])).is_err());
        // id required
        assert!(Request::from_json(&json::obj(vec![("op", json::s("status"))])).is_err());
        // fractional id rejected
        assert!(Request::from_json(&json::obj(vec![
            ("op", json::s("status")),
            ("id", json::num(1.5)),
        ]))
        .is_err());
        // submit without config
        assert!(Request::from_json(&json::obj(vec![("op", json::s("submit"))])).is_err());
        // submit with invalid config (k out of range)
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.k = crate::coordinator::config::KSchedule::Constant(0);
        let bad = json::obj(vec![("op", json::s("submit")), ("config", cfg.to_json())]);
        let err = Request::from_json(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("bad config"), "{err:#}");
        // submit with a degenerate k schedule string (protocol v4)
        let mut j = ExperimentConfig::preset(Task::Energy).to_json();
        if let Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != "k");
            pairs.push(("k".to_string(), json::s("step:18:0:0.5")));
        }
        let bad = json::obj(vec![("op", json::s("submit")), ("config", j)]);
        let err = Request::from_json(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("bad config"), "{err:#}");
        // submit with an unknown precision mode (protocol v7): rejected
        // with the valid spellings listed, not silently defaulted
        for (key, val) in [("trace", "int8"), ("accum", "f128")] {
            let mut j = ExperimentConfig::preset(Task::Energy).to_json();
            if let Json::Obj(pairs) = &mut j {
                pairs.push((key.to_string(), json::s(val)));
            }
            let bad = json::obj(vec![("op", json::s("submit")), ("config", j)]);
            let err = format!("{:#}", Request::from_json(&bad).unwrap_err());
            assert!(err.contains("bad config"), "{err}");
            assert!(err.contains("expected one of"), "{err}");
        }
    }

    #[test]
    fn response_envelopes() {
        let ok = ok_response(vec![("id", json::num(3.0))]);
        assert!(is_ok(&ok));
        assert_eq!(ok.get("id").unwrap().as_usize().unwrap(), 3);
        let err = err_response("nope");
        assert!(!is_ok(&err));
        assert_eq!(err.get("error").unwrap().as_str().unwrap(), "nope");
    }

    #[test]
    fn parses_v8_health_fields() {
        let h = json::obj(vec![("op", json::s("health"))]);
        assert!(matches!(
            Request::from_json(&h).unwrap(),
            Request::Health { wait_ms: 1_000 }
        ));
        let h = json::obj(vec![("op", json::s("health")), ("wait_ms", json::num(50.0))]);
        assert!(matches!(
            Request::from_json(&h).unwrap(),
            Request::Health { wait_ms: 50 }
        ));
        let bad = json::obj(vec![("op", json::s("health")), ("wait_ms", json::num(-1.0))]);
        assert!(Request::from_json(&bad).is_err());
    }

    #[test]
    fn rejection_envelopes_carry_reason_and_retry_hint() {
        let r = err_rejection("queue full", "queue_full", Some(250));
        assert!(!is_ok(&r));
        assert_eq!(r.get("error").unwrap().as_str().unwrap(), "queue full");
        assert_eq!(r.get("reason").unwrap().as_str().unwrap(), "queue_full");
        assert_eq!(r.get("retry_after_ms").unwrap().as_usize().unwrap(), 250);
        // no hint for permanent rejections: the key is simply absent
        let r = err_rejection("too wide", "oversized", None);
        assert_eq!(r.get("reason").unwrap().as_str().unwrap(), "oversized");
        assert!(r.get("retry_after_ms").is_none());
    }

    #[test]
    fn retry_delay_is_bounded_deterministic_and_honors_the_hint() {
        let p = RetryPolicy { attempts: 6, base_ms: 50, max_ms: 2_000, seed: 9 };
        // deterministic: same (policy, attempt) → same delay
        for attempt in 1..=6 {
            assert_eq!(retry_delay(&p, attempt, None), retry_delay(&p, attempt, None));
        }
        // exponential base with jitter in [0, base/2]: delay ∈ [base, 1.5*base]
        let mut prev_base = 0;
        for attempt in 1..=6u32 {
            let base = (50u64 << (attempt - 1)).min(2_000);
            let d = retry_delay(&p, attempt, None).as_millis() as u64;
            assert!(d >= base && d <= base + base / 2, "attempt {attempt}: {d}ms");
            assert!(base >= prev_base);
            prev_base = base;
        }
        // the ceiling holds for late attempts
        assert!(retry_delay(&p, 30, None).as_millis() as u64 <= 3_000);
        // a server hint replaces the exponential base
        let d = retry_delay(&p, 1, Some(400)).as_millis() as u64;
        assert!((400..=600).contains(&d), "{d}ms");
        // different seeds jitter differently somewhere in the schedule
        let q = RetryPolicy { seed: 10, ..p };
        assert!((1..=6).any(|a| retry_delay(&p, a, None) != retry_delay(&q, a, None)));
    }

    #[test]
    fn frame_io_roundtrip() {
        let mut buf = Vec::new();
        write_json(&mut buf, &ok_response(vec![("x", json::num(1.0))])).unwrap();
        write_json(&mut buf, &err_response("bad")).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_json(&mut r).unwrap().unwrap();
        assert!(is_ok(&a));
        let b = read_json(&mut r).unwrap().unwrap();
        assert!(!is_ok(&b));
        assert!(read_json(&mut r).unwrap().is_none()); // EOF
    }
}
