//! The TCP front door: accept loop, per-connection threads, graceful
//! shutdown.
//!
//! One thread per connection reads newline-delimited JSON frames and
//! answers through [`ServerState::handle`]; a malformed line gets an
//! `ok:false` response and the connection stays open (framing is
//! line-based, so the stream re-synchronizes at the next newline). The
//! listener runs non-blocking so the accept loop can poll the shutdown
//! flag set by the `shutdown` op; on shutdown it stops accepting, drains
//! every queued job through [`Scheduler::shutdown`], and returns.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::handlers::{frame_error, ServerState};
use crate::serve::protocol;
use crate::serve::queue::Scheduler;
use crate::serve::registry::Registry;
use crate::util::pool;

/// Configuration of one server instance.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// Training-thread slots (0 = available parallelism). A running job
    /// holds `config.threads` slots, so this bounds total training
    /// threads, not job count; jobs with `threads` above this are
    /// rejected at submission.
    pub workers: usize,
    /// Max jobs waiting for a worker before submissions are rejected.
    pub queue_capacity: usize,
    /// Persist completed runs here (None = in-memory registry only).
    pub registry_dir: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7070".to_string(),
            workers: 0,
            queue_capacity: 256,
            registry_dir: None,
        }
    }
}

/// A bound (but not yet running) server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind the listener, load/create the registry, start the scheduler.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        let registry = Arc::new(Registry::new(opts.registry_dir.clone())?);
        let workers = if opts.workers == 0 {
            pool::default_workers()
        } else {
            opts.workers
        };
        let scheduler = Scheduler::start(registry.clone(), workers, opts.queue_capacity.max(1));
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        Ok(Server {
            listener,
            state: Arc::new(ServerState::new(registry, scheduler)),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading local addr")
    }

    /// Shared state handle (metrics inspection in tests and benches).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Serve until a client sends `shutdown`. Graceful: stops accepting,
    /// then drains every queued job before returning — no accepted job is
    /// ever dropped. Connection threads exit on client EOF.
    pub fn run(self) -> Result<()> {
        loop {
            if self.state.shutdown_requested() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // accepted sockets must block: connection threads do
                    // plain line-buffered reads
                    stream
                        .set_nonblocking(false)
                        .context("setting connection blocking")?;
                    let state = self.state.clone();
                    std::thread::Builder::new()
                        .name("serve-conn".to_string())
                        .spawn(move || serve_connection(&state, stream))
                        .context("spawning connection thread")?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
        }
        // Drain: every accepted job completes before we return. Open
        // connections see submission errors and EOF once the process (or
        // the caller holding the listener) goes away.
        self.state.scheduler.shutdown();
        Ok(())
    }
}

/// Serve one connection until EOF. Never panics; I/O failures close the
/// connection, request-level failures are `ok:false` responses.
fn serve_connection(state: &ServerState, stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        match protocol::read_json(&mut reader) {
            Ok(Some(frame)) => {
                let resp = state.handle(&frame);
                if protocol::write_json(&mut writer, &resp).is_err() {
                    return;
                }
            }
            // clean EOF: the client hung up
            Ok(None) => return,
            // bad JSON on one line: report and keep the connection — the
            // next line is a fresh frame
            Err(e) => {
                if protocol::write_json(&mut writer, &frame_error(&e)).is_err() {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::protocol::Client;
    use crate::util::json;

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = Policy::RandK;
        cfg.k = crate::coordinator::config::KSchedule::Constant(9);
        cfg.memory = true;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    fn spawn_server() -> (String, std::thread::JoinHandle<Result<()>>) {
        let opts = ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_capacity: 16,
            registry_dir: None,
        };
        let server = Server::bind(&opts).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.run());
        (addr, handle)
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (addr, handle) = spawn_server();
        let mut c = Client::connect(&addr).unwrap();
        let pong = c.ping().unwrap();
        assert!(pong.get("protocol").is_some());

        let id = c.submit(&quick_cfg(3), "tcp").unwrap();
        let job = c.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(job.get("state").unwrap().as_str().unwrap(), "done");
        let (cfg, curve) = c.result(id).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(curve.epochs.len(), 2);

        c.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn malformed_line_keeps_connection_alive() {
        use std::io::{BufRead, Write};
        let (addr, handle) = spawn_server();

        // raw non-JSON line → error response, connection stays usable
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(b"{{{ not json\n").unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert!(!crate::serve::protocol::is_ok(&resp));
        // a valid frame on the same connection still works
        raw.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(crate::serve::protocol::is_ok(&json::parse(line.trim()).unwrap()));
        // a well-formed frame with a bad op is also just an envelope
        raw.write_all(b"{\"op\":\"bogus\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(!crate::serve::protocol::is_ok(&json::parse(line.trim()).unwrap()));
        drop(raw);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }
}
