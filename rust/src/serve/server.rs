//! The TCP front door: accept loop, per-connection threads, graceful
//! shutdown.
//!
//! One thread per connection reads newline-delimited JSON frames and
//! answers through [`ServerState::handle_from`]; a malformed line gets
//! an `ok:false` response and the connection stays open (framing is
//! line-based, so the stream re-synchronizes at the next newline). The
//! listener runs non-blocking so the accept loop can poll the shutdown
//! flag set by the `shutdown` op; on shutdown it stops accepting, drains
//! every queued job through [`Scheduler::shutdown`], and returns.
//!
//! Resilience (protocol v8): the accept loop stops accepting at
//! `max_connections` open sockets instead of spawning unboundedly;
//! connection reads tick on a short timeout so a stalled client cannot
//! pin its thread forever (`frame_timeout` abandons a half-sent frame,
//! `idle_timeout` optionally closes quiet keep-alives) and so idle
//! connections notice a graceful shutdown and close themselves. A
//! [`FaultPlan`] can deterministically drop connections before a reply
//! is written, for chaos testing the client retry path.

// Clock reads are deliberate here (connection deadlines and graceful-shutdown timing) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::io::{BufReader, ErrorKind};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::faults::FaultPlan;
use crate::serve::handlers::{Limits, ServerState};
use crate::serve::protocol;
use crate::serve::queue::Scheduler;
use crate::serve::registry::Registry;
use crate::util::json;
use crate::util::pool;

/// How often a blocked connection read wakes up to check the shutdown
/// flag and the frame/idle deadlines.
const READ_TICK: Duration = Duration::from_millis(200);

/// Configuration of one server instance.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// Training-thread slots (0 = available parallelism). A running job
    /// holds `config.threads` slots, so this bounds total training
    /// threads, not job count; jobs with `threads` above this are
    /// rejected at submission.
    pub workers: usize,
    /// Max jobs waiting for a worker before submissions are rejected.
    pub queue_capacity: usize,
    /// Persist completed runs here (None = in-memory registry only).
    pub registry_dir: Option<PathBuf>,
    /// Max simultaneous client connections; at the cap the accept loop
    /// pauses instead of spawning more threads (TCP backlog applies
    /// the backpressure).
    pub max_connections: usize,
    /// Sustained `submit` rate allowed per client IP (0.0 = unlimited).
    pub rate_limit_per_sec: f64,
    /// Submits a client may burst after sitting idle.
    pub rate_limit_burst: f64,
    /// Close a connection whose frame stays half-sent this long
    /// (slow-loris defense; `Duration::ZERO` disables).
    pub frame_timeout: Duration,
    /// Close a connection with no traffic at all for this long
    /// (`Duration::ZERO`, the default, keeps idle connections forever).
    pub idle_timeout: Duration,
    /// Deterministic fault injection (chaos tests); `FaultPlan::off()`
    /// costs nothing on the hot path.
    pub faults: FaultPlan,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7070".to_string(),
            workers: 0,
            queue_capacity: 256,
            registry_dir: None,
            max_connections: 256,
            rate_limit_per_sec: 0.0,
            rate_limit_burst: 8.0,
            frame_timeout: Duration::from_secs(30),
            idle_timeout: Duration::ZERO,
            faults: FaultPlan::off(),
        }
    }
}

/// A bound (but not yet running) server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    max_connections: usize,
    frame_timeout: Duration,
    idle_timeout: Duration,
    faults: FaultPlan,
}

impl Server {
    /// Bind the listener, load/create the registry, start the scheduler.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        let registry = Arc::new(Registry::with_faults(opts.registry_dir.clone(), opts.faults)?);
        let workers = if opts.workers == 0 {
            pool::default_workers()
        } else {
            opts.workers
        };
        let scheduler = Scheduler::start_with_faults(
            registry.clone(),
            workers,
            opts.queue_capacity.max(1),
            opts.faults,
        );
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let limits = Limits {
            rate_limit_per_sec: opts.rate_limit_per_sec,
            rate_limit_burst: opts.rate_limit_burst,
        };
        Ok(Server {
            listener,
            state: Arc::new(ServerState::with_limits(registry, scheduler, limits)),
            max_connections: opts.max_connections.max(1),
            frame_timeout: opts.frame_timeout,
            idle_timeout: opts.idle_timeout,
            faults: opts.faults,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading local addr")
    }

    /// Shared state handle (metrics inspection in tests and benches).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Serve until a client sends `shutdown`. Graceful: stops accepting,
    /// then drains every queued job before returning — no accepted job is
    /// ever dropped. Connection threads exit on client EOF, on their
    /// read deadlines, or when they notice the shutdown flag.
    pub fn run(self) -> Result<()> {
        let open = Arc::new(AtomicUsize::new(0));
        let mut conn_id: u64 = 0;
        loop {
            if self.state.shutdown_requested() {
                break;
            }
            if open.load(Ordering::SeqCst) >= self.max_connections {
                // at the cap: let the kernel backlog hold new clients
                // instead of spawning a thread per socket
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    // accepted sockets block with a short read timeout:
                    // connection threads poll shutdown + deadlines
                    stream
                        .set_nonblocking(false)
                        .context("setting connection blocking")?;
                    conn_id += 1;
                    let guard = ConnGuard::open(&open, &self.state);
                    let state = self.state.clone();
                    let (ft, it, faults) = (self.frame_timeout, self.idle_timeout, self.faults);
                    let id = conn_id;
                    std::thread::Builder::new()
                        .name("serve-conn".to_string())
                        .spawn(move || {
                            let _guard = guard;
                            serve_connection(&state, stream, peer.ip(), id, ft, it, &faults);
                        })
                        .context("spawning connection thread")?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
        }
        // Drain: every accepted job completes before we return. Open
        // connections notice the shutdown flag at their next read tick
        // and close; late submits get `shutting_down` rejections.
        self.state.scheduler.shutdown();
        Ok(())
    }
}

/// RAII connection accounting: decrements the accept-loop cap counter
/// and the `repro_connections_open` gauge however the thread exits.
struct ConnGuard {
    open: Arc<AtomicUsize>,
    state: Arc<ServerState>,
}

impl ConnGuard {
    fn open(open: &Arc<AtomicUsize>, state: &Arc<ServerState>) -> ConnGuard {
        open.fetch_add(1, Ordering::SeqCst);
        state.connection_opened();
        ConnGuard { open: open.clone(), state: state.clone() }
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.open.fetch_sub(1, Ordering::SeqCst);
        self.state.connection_closed();
    }
}

/// Serve one connection until EOF, deadline, or shutdown. Never panics;
/// I/O failures close the connection, request-level failures are
/// `ok:false` responses.
///
/// Frames are accumulated with `read_until`, which keeps partial bytes
/// in the buffer across read timeouts — a slow sender loses nothing at
/// a tick, but a sender that stalls past `frame_timeout` is cut off.
fn serve_connection(
    state: &ServerState,
    stream: TcpStream,
    peer: IpAddr,
    conn_id: u64,
    frame_timeout: Duration,
    idle_timeout: Duration,
    faults: &FaultPlan,
) {
    use std::io::BufRead;
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut frames: u64 = 0;
    let mut idle_t0 = Instant::now();
    // set at the first read tick that sees a partial frame; cleared
    // when the frame completes
    let mut frame_t0: Option<Instant> = None;
    loop {
        match reader.read_until(b'\n', &mut buf) {
            // clean EOF: the client hung up
            Ok(0) => return,
            Ok(_) if buf.ends_with(b"\n") => {
                frames += 1;
                let line = String::from_utf8_lossy(&buf).into_owned();
                buf.clear();
                frame_t0 = None;
                idle_t0 = Instant::now();
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                // bad JSON on one line: report and keep the connection —
                // the next line is a fresh frame
                let resp = match json::parse(trimmed) {
                    Ok(frame) => state.handle_from(&frame, Some(peer)),
                    Err(e) => protocol::err_response(&format!("parsing frame: {e}")),
                };
                // injected drop: vanish before replying, so the client
                // exercises its reconnect-and-retry path
                if faults.drop_connection(conn_id, frames) {
                    eprintln!("[serve] fault: dropping connection {conn_id} before reply");
                    return;
                }
                if protocol::write_json(&mut writer, &resp).is_err() {
                    return;
                }
            }
            // EOF mid-frame (no trailing newline): nothing to answer
            Ok(_) => return,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // read tick: partial bytes (if any) stayed in `buf`
                if state.shutdown_requested() {
                    return;
                }
                if buf.is_empty() {
                    frame_t0 = None;
                    if idle_timeout > Duration::ZERO && idle_t0.elapsed() >= idle_timeout {
                        return;
                    }
                } else {
                    let t0 = *frame_t0.get_or_insert_with(Instant::now);
                    if frame_timeout > Duration::ZERO && t0.elapsed() >= frame_timeout {
                        let resp = protocol::err_response(
                            "frame timeout: partial frame abandoned, closing connection",
                        );
                        let _ = protocol::write_json(&mut writer, &resp);
                        return;
                    }
                }
            }
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::coordinator::config::{ExperimentConfig, Task};
    use crate::serve::protocol::Client;

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = Policy::RandK;
        cfg.k = crate::coordinator::config::KSchedule::Constant(9);
        cfg.memory = true;
        cfg.epochs = 2;
        cfg.seed = seed;
        cfg
    }

    fn spawn_server() -> (String, std::thread::JoinHandle<Result<()>>) {
        spawn_server_with(ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_capacity: 16,
            ..ServeOptions::default()
        })
    }

    fn spawn_server_with(
        opts: ServeOptions,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        let server = Server::bind(&opts).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.run());
        (addr, handle)
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (addr, handle) = spawn_server();
        let mut c = Client::connect(&addr).unwrap();
        let pong = c.ping().unwrap();
        assert!(pong.get("protocol").is_some());

        let id = c.submit(&quick_cfg(3), "tcp").unwrap();
        let job = c.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(job.get("state").unwrap().as_str().unwrap(), "done");
        let (cfg, curve) = c.result(id).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(curve.epochs.len(), 2);

        c.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn malformed_line_keeps_connection_alive() {
        use std::io::{BufRead, Write};
        let (addr, handle) = spawn_server();

        // raw non-JSON line → error response, connection stays usable
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(b"{{{ not json\n").unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert!(!crate::serve::protocol::is_ok(&resp));
        // a valid frame on the same connection still works
        raw.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(crate::serve::protocol::is_ok(&json::parse(line.trim()).unwrap()));
        // a well-formed frame with a bad op is also just an envelope
        raw.write_all(b"{\"op\":\"bogus\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(!crate::serve::protocol::is_ok(&json::parse(line.trim()).unwrap()));
        drop(raw);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn stalled_partial_frame_is_cut_off_but_slow_complete_frames_survive() {
        use std::io::{BufRead, Write};
        let (addr, handle) = spawn_server_with(ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_capacity: 16,
            frame_timeout: Duration::from_millis(600),
            ..ServeOptions::default()
        });

        // a frame split across writes — but finished well inside the
        // deadline — must not lose its first half at a read tick
        let mut slow = TcpStream::connect(&addr).unwrap();
        slow.write_all(b"{\"op\":").unwrap();
        std::thread::sleep(Duration::from_millis(450));
        slow.write_all(b"\"ping\"}\n").unwrap();
        let mut reader = BufReader::new(slow.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            crate::serve::protocol::is_ok(&json::parse(line.trim()).unwrap()),
            "split frame must reassemble: {line}"
        );

        // a slow-loris sender that never finishes the frame is told off
        // and disconnected
        let mut loris = TcpStream::connect(&addr).unwrap();
        loris.write_all(b"{\"op\":\"pi").unwrap();
        let mut reader = BufReader::new(loris.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert!(!crate::serve::protocol::is_ok(&resp));
        assert!(
            resp.get("error").unwrap().as_str().unwrap().contains("frame timeout"),
            "{line}"
        );
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must be closed");

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn shutdown_closes_idle_keepalive_connections() {
        use std::io::BufRead;
        let (addr, handle) = spawn_server();
        // an idle keep-alive connection that never sends anything
        let idle = TcpStream::connect(&addr).unwrap();
        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        // run() returns even though `idle` never hung up: the connection
        // thread noticed the flag at its next read tick
        handle.join().unwrap().unwrap();
        // and the idle socket sees EOF shortly after
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(idle);
        let mut line = String::new();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "idle conn must get EOF");
    }

    #[test]
    fn connection_cap_applies_accept_backpressure() {
        let (addr, handle) = spawn_server_with(ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_capacity: 16,
            max_connections: 1,
            ..ServeOptions::default()
        });
        // first client occupies the only slot
        let mut a = Client::connect(&addr).unwrap();
        a.ping().unwrap();
        // a second TCP connect succeeds (kernel backlog) but the server
        // won't answer it until the first connection closes
        let mut b = Client::connect(&addr).unwrap();
        let t0 = Instant::now();
        let release = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(700));
            drop(a);
        });
        let pong = b.ping().unwrap();
        assert!(pong.get("protocol").is_some());
        assert!(
            t0.elapsed() >= Duration::from_millis(300),
            "second client was served before the cap freed up ({:?})",
            t0.elapsed()
        );
        release.join().unwrap();
        b.shutdown().unwrap();
        handle.join().unwrap().unwrap();
    }
}
