//! Run registry: the server's authoritative table of jobs and results.
//!
//! Every submitted job lives here through its whole lifecycle
//! (`queued → running → done | failed | cancelled`); the scheduler
//! transitions states, connection handlers read views. Completed runs are
//! persisted through [`coordinator::checkpoint`](crate::coordinator::checkpoint)
//! — one `job_<id>.maop` file per run holding the config + curve (as
//! rank-3 JSON bytes entries) next to the final weights — so a restarted
//! server reloads its history and keeps allocating fresh ids above it.

// Clock reads are deliberate here (job lifecycle timestamps) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::aop::{flops, Policy};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiment::RunResult;
use crate::metrics::{EpochMetrics, RunCurve};
use crate::obs::{AuditLayerRecord, PhaseRollup};
use crate::serve::faults::FaultPlan;
use crate::tensor::quant::{AccumMode, TraceMode};
use crate::util::json::{self, Json};

/// Epoch frames retained per job for `watch` (protocol v6). A cursor
/// older than the ring's tail resumes from the oldest retained epoch —
/// bounded memory per job, no error for slow subscribers.
pub const EPOCH_RING_CAP: usize = 256;

/// Lifecycle state of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Internal job record.
struct Job {
    tag: String,
    config: ExperimentConfig,
    state: JobState,
    epochs_done: usize,
    error: Option<String>,
    curve: Option<RunCurve>,
    /// Per-phase telemetry rollup from the finished run (protocol v5).
    /// In-memory only — not persisted, so restored jobs carry `None`.
    phases: Option<PhaseRollup>,
    /// Rendered per-epoch metric frames for `watch` (protocol v6):
    /// `ring[i]` is epoch `ring_first + i`. Bounded at
    /// [`EPOCH_RING_CAP`]; in-memory only (restored jobs stream nothing).
    ring: VecDeque<Json>,
    ring_first: usize,
    /// Last audited epoch's per-layer fidelity records — the source of
    /// the `repro_audit_*` Prometheus gauges.
    last_audit: Option<(usize, Vec<AuditLayerRecord>)>,
    cancel: Arc<AtomicBool>,
    restored: bool,
}

impl Job {
    /// Append one epoch frame to the watch ring (evicting the oldest
    /// past [`EPOCH_RING_CAP`]) and refresh the audit snapshot.
    fn push_epoch(&mut self, m: &EpochMetrics) {
        if self.ring.is_empty() {
            self.ring_first = m.epoch;
        } else if m.epoch != self.ring_first + self.ring.len() {
            // out-of-order or duplicate epoch (defensive; the observer
            // delivers them sequentially) — ignore rather than corrupt
            // the ring's epoch arithmetic
            return;
        }
        if self.ring.len() == EPOCH_RING_CAP {
            self.ring.pop_front();
            self.ring_first += 1;
        }
        self.ring.push_back(m.to_json());
        self.epochs_done = self.epochs_done.max(m.epoch);
        if !m.audit.is_empty() {
            self.last_audit = Some((m.epoch, m.audit.clone()));
        }
    }
}

/// Read-only snapshot of a job, served to protocol clients.
#[derive(Debug, Clone)]
pub struct JobView {
    pub id: u64,
    pub tag: String,
    pub state: JobState,
    pub epochs_done: usize,
    pub epochs_total: usize,
    pub error: Option<String>,
    pub cancel_requested: bool,
    pub restored: bool,
    pub config: ExperimentConfig,
    /// Phase-timing rollup of the finished run (protocol v5; `None`
    /// while the job is pending and for restored jobs).
    pub phases: Option<PhaseRollup>,
}

impl JobView {
    pub fn to_json(&self) -> Json {
        // resolved per-layer view (protocol v3/v4): what each layer will
        // actually run with after spec defaults are applied — one entry
        // for flat configs. `k` is the schedule (a number for constants,
        // a spec string otherwise); `k_first`/`k_last` echo the resolved
        // epoch-1 and final-epoch budgets so clients see the annealing
        // envelope without re-implementing the resolution.
        let total = self.config.epochs.max(1);
        let m = self.config.m();
        let layers: Vec<Json> = self
            .config
            .layer_plan()
            .iter()
            .map(|rl| {
                let mut pairs = vec![
                    ("width", json::num(rl.fan_out as f64)),
                    ("activation", json::s(rl.activation.name())),
                    ("k", rl.k.to_json()),
                    ("k_first", json::num(rl.k.k_at(1, total, m) as f64)),
                    ("k_last", json::num(rl.k.k_at(total, total, m) as f64)),
                    ("policy", json::s(rl.policy.name())),
                    ("memory", Json::Bool(rl.memory)),
                ];
                // resolved precision (protocol v7), emitted only when
                // non-default so all-f32 views keep the pre-v7 shape:
                // `trace` is post-pin (head/exact-input layers echo
                // nothing even if the spec asked for compression), and
                // `trace_bytes` is the backward-read footprint of this
                // layer's stored output activations at batch M
                if rl.trace != TraceMode::F32 {
                    pairs.push(("trace", json::s(rl.trace.name())));
                    pairs.push((
                        "trace_bytes",
                        json::num(rl.trace.trace_bytes(m, rl.fan_out) as f64),
                    ));
                }
                if rl.accum != AccumMode::F32 {
                    pairs.push(("accum", json::s(rl.accum.name())));
                }
                json::obj(pairs)
            })
            .collect();
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("tag", json::s(&self.tag)),
            ("label", json::s(&self.config.label())),
            ("task", json::s(self.config.task.name())),
            ("policy", json::s(self.config.policy.name())),
            ("backend", json::s(self.config.backend.name())),
            ("k", self.config.k.to_json()),
            ("seed", json::num(self.config.seed as f64)),
            ("threads", json::num(self.config.threads as f64)),
            ("layers", Json::Arr(layers)),
            ("state", json::s(self.state.name())),
            ("epochs_done", json::num(self.epochs_done as f64)),
            ("epochs_total", json::num(self.epochs_total as f64)),
            ("cancel_requested", Json::Bool(self.cancel_requested)),
            ("restored", Json::Bool(self.restored)),
            (
                "error",
                match &self.error {
                    Some(e) => json::s(e),
                    None => Json::Null,
                },
            ),
            (
                "phases",
                match &self.phases {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Compact snapshot (protocol v5 `compact: true`): only the fields
    /// pollers actually watch — no config echo, no resolved layer plan,
    /// no phase rollup. Cuts the per-poll frame to a fraction of the
    /// full view for clients driving progress bars.
    pub fn to_json_compact(&self) -> Json {
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("tag", json::s(&self.tag)),
            ("state", json::s(self.state.name())),
            ("epochs_done", json::num(self.epochs_done as f64)),
            ("epochs_total", json::num(self.epochs_total as f64)),
            ("cancel_requested", Json::Bool(self.cancel_requested)),
            (
                "error",
                match &self.error {
                    Some(e) => json::s(e),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Per-state job counts for the metrics endpoint.
#[derive(Debug, Clone, Copy, Default)]
pub struct StateCounts {
    pub queued: u64,
    pub running: u64,
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
}

impl StateCounts {
    pub fn total(&self) -> u64 {
        self.queued + self.running + self.done + self.failed + self.cancelled
    }
}

/// Per-policy FLOP accounting over completed jobs (`aop::flops` model).
#[derive(Debug, Clone, Copy)]
pub struct PolicyRollup {
    pub policy: Policy,
    pub jobs: u64,
    /// Backward weight-gradient FLOPs actually spent (from the curves).
    pub backward_flops: u64,
    /// What exact back-propagation would have spent on the same steps.
    pub exact_flops: u64,
}

impl PolicyRollup {
    pub fn saved_frac(&self) -> f64 {
        if self.exact_flops == 0 {
            0.0
        } else {
            1.0 - self.backward_flops as f64 / self.exact_flops as f64
        }
    }
}

/// The registry proper. All methods take `&self`; internal locking keeps
/// it shareable across the scheduler and connection threads via `Arc`.
pub struct Registry {
    jobs: Mutex<BTreeMap<u64, Job>>,
    /// Signalled (paired with `jobs`) whenever a job gains an epoch
    /// frame or reaches a terminal state — wakes `watch` long-polls.
    epoch_cv: Condvar,
    next_id: AtomicU64,
    dir: Option<PathBuf>,
    /// Chaos schedule ([`FaultPlan::off`] in production): torn persist
    /// writes injected per job id, exercising the startup
    /// skip-and-recover path the atomic rename normally makes
    /// unreachable.
    faults: FaultPlan,
}

impl Registry {
    /// In-memory registry, optionally persisted under `dir` (created if
    /// missing; existing `job_*.maop` files are reloaded as done jobs).
    pub fn new(dir: Option<PathBuf>) -> Result<Registry> {
        Self::with_faults(dir, FaultPlan::off())
    }

    /// [`Registry::new`] with a chaos schedule (tests / `--faults`).
    pub fn with_faults(dir: Option<PathBuf>, faults: FaultPlan) -> Result<Registry> {
        let mut jobs = BTreeMap::new();
        let mut max_id = 0u64;
        if let Some(d) = &dir {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating registry dir {}", d.display()))?;
            for entry in std::fs::read_dir(d)
                .with_context(|| format!("reading registry dir {}", d.display()))?
            {
                let path = entry?.path();
                let Some(id) = job_id_of(&path) else { continue };
                // count the id even if the file is unreadable, so a
                // corrupt run can never get its id reused (and its file
                // silently overwritten) by a new job
                max_id = max_id.max(id);
                match load_job_file(&path) {
                    Ok(job) => {
                        jobs.insert(id, job);
                    }
                    Err(e) => {
                        eprintln!(
                            "[serve] skipping unreadable run file {}: {e:#}",
                            path.display()
                        );
                    }
                }
            }
        }
        Ok(Registry {
            jobs: Mutex::new(jobs),
            epoch_cv: Condvar::new(),
            next_id: AtomicU64::new(max_id + 1),
            dir,
            faults,
        })
    }

    /// Register a new queued job; returns its id.
    pub fn submit(&self, config: ExperimentConfig, tag: &str) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let job = Job {
            tag: tag.to_string(),
            config,
            state: JobState::Queued,
            epochs_done: 0,
            error: None,
            curve: None,
            phases: None,
            ring: VecDeque::new(),
            ring_first: 1,
            last_audit: None,
            cancel: Arc::new(AtomicBool::new(false)),
            restored: false,
        };
        self.jobs.lock().unwrap().insert(id, job);
        id
    }

    /// Transition a queued job to running; returns its config and cancel
    /// flag. `None` if the job was cancelled while queued (the state is
    /// finalized to `Cancelled` here) or is not in the queued state.
    pub fn mark_running(&self, id: u64) -> Option<(ExperimentConfig, Arc<AtomicBool>)> {
        let mut jobs = self.jobs.lock().unwrap();
        let job = jobs.get_mut(&id)?;
        if job.state != JobState::Queued {
            return None;
        }
        if job.cancel.load(Ordering::Relaxed) {
            job.state = JobState::Cancelled;
            drop(jobs);
            // terminal transition: release any watch long-polls
            self.epoch_cv.notify_all();
            return None;
        }
        job.state = JobState::Running;
        Some((job.config.clone(), job.cancel.clone()))
    }

    /// The job's cancel flag (any state) — lets the scheduler observe a
    /// cancellation while the job is still waiting for thread slots, so
    /// a dead job never blocks live ones.
    pub fn cancel_flag(&self, id: u64) -> Option<Arc<AtomicBool>> {
        self.jobs.lock().unwrap().get(&id).map(|j| j.cancel.clone())
    }

    /// Record per-epoch progress (called from the worker's observer).
    pub fn update_progress(&self, id: u64, epochs_done: usize) {
        if let Some(job) = self.jobs.lock().unwrap().get_mut(&id) {
            job.epochs_done = epochs_done;
        }
    }

    /// Record one finished epoch's full metric frame (protocol v6;
    /// called from the worker's observer). Advances `epochs_done`,
    /// appends to the job's watch ring, refreshes the audit gauges, and
    /// wakes every long-polling `watch`.
    pub fn record_epoch(&self, id: u64, m: &EpochMetrics) {
        {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(job) = jobs.get_mut(&id) else { return };
            job.push_epoch(m);
        }
        self.epoch_cv.notify_all();
    }

    /// Long-poll epoch frames with epoch number > `cursor` (protocol v6
    /// `watch`): returns `(frames, next_cursor, state)` as soon as at
    /// least one frame is available or the job is terminal, else blocks
    /// up to `timeout` and returns an empty batch. Cursors older than
    /// the ring's tail resume from the oldest retained epoch.
    pub fn watch(
        &self,
        id: u64,
        cursor: usize,
        timeout: Duration,
    ) -> Result<(Vec<Json>, usize, JobState)> {
        let deadline = Instant::now() + timeout;
        let mut jobs = self.jobs.lock().unwrap();
        loop {
            let job = jobs.get(&id).ok_or_else(|| anyhow!("no job {id}"))?;
            let mut out = Vec::new();
            let mut next = cursor;
            for (i, frame) in job.ring.iter().enumerate() {
                let ep = job.ring_first + i;
                if ep > cursor {
                    out.push(frame.clone());
                    next = ep;
                }
            }
            if !out.is_empty() || job.state.is_terminal() {
                return Ok((out, next, job.state));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok((out, next, job.state));
            }
            let (guard, _) = self
                .epoch_cv
                .wait_timeout(jobs, deadline - now)
                .unwrap();
            jobs = guard;
        }
    }

    /// Last audited epoch per job, for the `repro_audit_*` Prometheus
    /// gauges: `(job id, epoch, per-layer records)`.
    pub fn audit_snapshots(&self) -> Vec<(u64, usize, Vec<AuditLayerRecord>)> {
        let jobs = self.jobs.lock().unwrap();
        jobs.iter()
            .filter_map(|(id, j)| {
                j.last_audit.as_ref().map(|(e, r)| (*id, *e, r.clone()))
            })
            .collect()
    }

    /// Request cancellation. Queued jobs are finalized immediately;
    /// running jobs stop at the next epoch boundary. Terminal jobs error.
    pub fn cancel(&self, id: u64) -> Result<JobState> {
        let mut jobs = self.jobs.lock().unwrap();
        let job = jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("no job {id}"))?;
        match job.state {
            JobState::Queued => {
                job.cancel.store(true, Ordering::Relaxed);
                job.state = JobState::Cancelled;
                drop(jobs);
                self.epoch_cv.notify_all();
                Ok(JobState::Cancelled)
            }
            JobState::Running => {
                job.cancel.store(true, Ordering::Relaxed);
                Ok(JobState::Running)
            }
            s => bail!("job {id} already {}", s.name()),
        }
    }

    /// Finalize a successful run and persist it (best-effort; persistence
    /// failures are logged, never fail the job).
    pub fn finish_ok(&self, id: u64, r: &RunResult) {
        let persist = {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(job) = jobs.get_mut(&id) else { return };
            job.state = JobState::Done;
            job.epochs_done = r.curve.epochs.len();
            // backfill the watch ring for epochs the observer never
            // delivered (callers driving finish_ok directly); already
            // recorded epochs dedupe inside push_epoch
            for m in &r.curve.epochs {
                job.push_epoch(m);
            }
            job.curve = Some(r.curve.clone());
            job.phases = r.phases.clone();
            job.error = None;
            self.dir
                .as_ref()
                .map(|d| (d.join(job_file_name(id)), job.tag.clone()))
        };
        self.epoch_cv.notify_all();
        if let Some((path, tag)) = persist {
            if let Err(e) = persist_job(&path, id, &tag, r, self.faults.torn_write(id)) {
                eprintln!("[serve] persisting job {id} failed: {e:#}");
            }
        }
    }

    /// Finalize a failed run.
    pub fn finish_err(&self, id: u64, msg: String) {
        if let Some(job) = self.jobs.lock().unwrap().get_mut(&id) {
            job.state = JobState::Failed;
            job.error = Some(msg);
        }
        self.epoch_cv.notify_all();
    }

    /// Finalize a cancelled run; a partial curve (epochs completed before
    /// the cancellation took effect) is kept for inspection.
    pub fn finish_cancelled(&self, id: u64, partial: Option<&RunResult>) {
        if let Some(job) = self.jobs.lock().unwrap().get_mut(&id) {
            job.state = JobState::Cancelled;
            if let Some(r) = partial {
                job.epochs_done = r.curve.epochs.len();
                for m in &r.curve.epochs {
                    job.push_epoch(m);
                }
                job.curve = Some(r.curve.clone());
                job.phases = r.phases.clone();
            }
        }
        self.epoch_cv.notify_all();
    }

    /// Snapshot of one job.
    pub fn view(&self, id: u64) -> Option<JobView> {
        let jobs = self.jobs.lock().unwrap();
        jobs.get(&id).map(|j| view_of(id, j))
    }

    /// Snapshot of every job, in id order.
    pub fn views(&self) -> Vec<JobView> {
        let jobs = self.jobs.lock().unwrap();
        jobs.iter().map(|(id, j)| view_of(*id, j)).collect()
    }

    /// Config + curve of a job that has one (done, or cancelled mid-run).
    pub fn result_of(&self, id: u64) -> Option<(ExperimentConfig, RunCurve)> {
        let jobs = self.jobs.lock().unwrap();
        let job = jobs.get(&id)?;
        job.curve
            .as_ref()
            .map(|c| (job.config.clone(), c.clone()))
    }

    /// Jobs restored from disk at startup (completed in a *previous*
    /// server lifetime — excluded from this process's throughput).
    pub fn restored_count(&self) -> u64 {
        let jobs = self.jobs.lock().unwrap();
        jobs.values().filter(|j| j.restored).count() as u64
    }

    /// Per-state counts.
    pub fn counts(&self) -> StateCounts {
        let jobs = self.jobs.lock().unwrap();
        let mut c = StateCounts::default();
        for j in jobs.values() {
            match j.state {
                JobState::Queued => c.queued += 1,
                JobState::Running => c.running += 1,
                JobState::Done => c.done += 1,
                JobState::Failed => c.failed += 1,
                JobState::Cancelled => c.cancelled += 1,
            }
        }
        c
    }

    /// Per-policy FLOP accounting over completed jobs, attributed at
    /// layer granularity: each resolved layer's actual backward FLOPs
    /// (from the curve's per-layer metrics) and exact-BP equivalent
    /// (`aop::flops::exact_step` × recorded steps) land in the bucket of
    /// *that layer's* policy, so a mixed-policy layer graph is counted
    /// where the work actually happened. A job contributes to `jobs`
    /// once per policy it touches. Curves without per-layer metrics
    /// (pre-layer-graph persisted runs) fall back to whole-job
    /// attribution under the flat policy; 0 recorded steps ⇒ no claimed
    /// savings.
    ///
    /// K schedules (protocol v4): the *actual* side is the curve's
    /// cumulative per-layer FLOPs, which the experiment loop accumulates
    /// step by step from each selection's realized `k_effective` — i.e.
    /// the **integral of the schedule** over the run, never
    /// `aop_step(k) × steps` for any single k (the
    /// `rollup_integrates_annealed_k_schedules` test pins this). The
    /// exact-BP side is k-free by construction, so savings fractions stay
    /// honest for annealed budgets.
    pub fn rollup(&self) -> Vec<PolicyRollup> {
        let jobs = self.jobs.lock().unwrap();
        let mut acc: BTreeMap<&'static str, PolicyRollup> = BTreeMap::new();
        let mut add = |policy: Policy, jobs_inc: u64, actual: u64, exact: u64| {
            let e = acc.entry(policy.name()).or_insert(PolicyRollup {
                policy,
                jobs: 0,
                backward_flops: 0,
                exact_flops: 0,
            });
            e.jobs += jobs_inc;
            e.backward_flops += actual;
            e.exact_flops += exact;
        };
        for j in jobs.values() {
            let (JobState::Done, Some(curve)) = (j.state, j.curve.as_ref()) else {
                continue;
            };
            let steps = curve.total_steps();
            let m = j.config.m();
            let plan = j.config.layer_plan();
            let per_layer: Vec<u64> = curve
                .epochs
                .last()
                .map(|e| e.layers.iter().map(|l| l.backward_flops).collect())
                .unwrap_or_default();
            if per_layer.len() == plan.len() {
                let mut seen: Vec<&'static str> = Vec::new();
                for (rl, &actual) in plan.iter().zip(per_layer.iter()) {
                    let exact = if steps == 0 {
                        actual
                    } else {
                        flops::exact_step(m, rl.fan_in, rl.fan_out).backward_only() * steps
                    };
                    let first = !seen.contains(&rl.policy.name());
                    if first {
                        seen.push(rl.policy.name());
                    }
                    add(rl.policy, first as u64, actual, exact);
                }
            } else {
                // legacy curve: no per-layer metrics recorded
                let actual = curve.total_backward_flops();
                let exact_per_step: u64 = plan
                    .iter()
                    .map(|rl| flops::exact_step(m, rl.fan_in, rl.fan_out).backward_only())
                    .sum();
                let exact = if steps == 0 {
                    actual
                } else {
                    exact_per_step * steps
                };
                add(j.config.policy, 1, actual, exact);
            }
        }
        acc.into_values().collect()
    }
}

fn view_of(id: u64, j: &Job) -> JobView {
    JobView {
        id,
        tag: j.tag.clone(),
        state: j.state,
        epochs_done: j.epochs_done,
        epochs_total: j.config.epochs,
        error: j.error.clone(),
        cancel_requested: j.cancel.load(Ordering::Relaxed),
        restored: j.restored,
        config: j.config.clone(),
        phases: j.phases.clone(),
    }
}

fn job_file_name(id: u64) -> String {
    format!("job_{id:08}.maop")
}

/// `job_<id>.maop` → id (None for unrelated files).
fn job_id_of(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    name.strip_prefix("job_")?
        .strip_suffix(".maop")?
        .parse()
        .ok()
}

fn persist_job(path: &Path, id: u64, tag: &str, r: &RunResult, torn: bool) -> Result<()> {
    let mut cp = Checkpoint::new();
    cp.put_scalar("id", id as f32);
    cp.put_str("tag", tag);
    cp.put_str("config_json", &r.config.to_json().dump());
    cp.put_str("curve_json", &r.curve.to_json().dump());
    cp.put_scalar("n_layers", r.final_layers.len() as f32);
    for (i, (w, b)) in r.final_layers.iter().enumerate() {
        cp.put_matrix(&format!("final_w{i}"), w);
        cp.put_vector(&format!("final_b{i}"), b);
    }
    // write-then-rename so a crash mid-write can never leave a truncated
    // run file at the final path (restart skips `.tmp` leftovers: they
    // don't match the `job_<id>.maop` pattern)
    let tmp = path.with_extension("maop.tmp");
    cp.save(&tmp)?;
    if torn {
        // injected fault: publish the first half of the entry directly
        // to the final path, as a crashed pre-rename writer (or external
        // corruption) would — startup must skip-and-log this file while
        // recovering every healthy sibling
        let bytes = std::fs::read(&tmp)?;
        std::fs::write(path, &bytes[..bytes.len() / 2])?;
        let _ = std::fs::remove_file(&tmp);
        eprintln!("[serve] fault: tore the persisted entry for job {id}");
        return Ok(());
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))
}

fn load_job_file(path: &Path) -> Result<Job> {
    let cp = Checkpoint::load(path)?;
    let config = ExperimentConfig::from_json(&json::parse(cp.str_entry("config_json")?)?)?;
    let curve = RunCurve::from_json(&json::parse(cp.str_entry("curve_json")?)?)?;
    Ok(Job {
        tag: cp.str_entry("tag")?.to_string(),
        config,
        state: JobState::Done,
        epochs_done: curve.epochs.len(),
        error: None,
        curve: Some(curve),
        phases: None,
        ring: VecDeque::new(),
        ring_first: 1,
        last_audit: None,
        cancel: Arc::new(AtomicBool::new(false)),
        restored: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{KSchedule, Task};
    use crate::coordinator::experiment;

    fn quick_cfg(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(Task::Energy);
        cfg.policy = Policy::TopK;
        cfg.k = KSchedule::Constant(18);
        cfg.memory = true;
        cfg.epochs = 3;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn lifecycle_queued_running_done() {
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(quick_cfg(0), "t");
        assert_eq!(reg.view(id).unwrap().state, JobState::Queued);
        let (cfg, _cancel) = reg.mark_running(id).unwrap();
        assert_eq!(reg.view(id).unwrap().state, JobState::Running);
        // double-start is refused
        assert!(reg.mark_running(id).is_none());
        reg.update_progress(id, 2);
        assert_eq!(reg.view(id).unwrap().epochs_done, 2);
        let r = experiment::run(&cfg).unwrap();
        reg.finish_ok(id, &r);
        let v = reg.view(id).unwrap();
        assert_eq!(v.state, JobState::Done);
        assert_eq!(v.epochs_done, 3);
        let (_, curve) = reg.result_of(id).unwrap();
        assert_eq!(curve.epochs.len(), 3);
        assert_eq!(reg.counts().done, 1);
        // terminal jobs can't be cancelled
        assert!(reg.cancel(id).is_err());
    }

    #[test]
    fn finished_jobs_carry_phase_rollups_and_compact_views_drop_them() {
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(quick_cfg(2), "obs");
        let (cfg, _) = reg.mark_running(id).unwrap();
        let r = experiment::run(&cfg).unwrap();
        assert!(r.phases.is_some(), "native runs record telemetry by default");
        reg.finish_ok(id, &r);
        let v = reg.view(id).unwrap();
        let roll = v.phases.as_ref().expect("done job keeps its rollup");
        assert!(roll.steps > 0);
        assert_eq!(roll.layers.len(), 1);
        assert!(roll.layers[0].k_sum > 0);
        // full view renders the rollup; compact view drops it along
        // with the config echo and layer plan
        let full = v.to_json();
        assert!(full.get("phases").map(|p| !matches!(p, Json::Null)).unwrap_or(false));
        assert!(full.get("layers").is_some());
        let compact = v.to_json_compact();
        assert!(compact.get("phases").is_none());
        assert!(compact.get("layers").is_none());
        assert!(compact.get("label").is_none());
        assert_eq!(compact.get("id").unwrap().as_usize().unwrap(), id as usize);
        assert_eq!(compact.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(compact.get("epochs_done").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn job_views_echo_resolved_precision_only_when_nondefault() {
        use crate::coordinator::config::LayerSpec;
        let reg = Registry::new(None).unwrap();
        // all-f32 job: the layer entries carry none of the v7 keys
        let id = reg.submit(quick_cfg(0), "f32");
        let full = reg.view(id).unwrap().to_json();
        let layers = full.get("layers").and_then(|a| a.as_arr()).unwrap();
        assert!(layers[0].get("trace").is_none());
        assert!(layers[0].get("accum").is_none());
        assert!(layers[0].get("trace_bytes").is_none());
        // mixed-precision job: resolved (post-pin) precision per layer
        let mut cfg = quick_cfg(1);
        cfg.trace = TraceMode::Q8;
        cfg.accum = AccumMode::F64;
        cfg.layers = Some(vec![LayerSpec::plain(8), LayerSpec::plain(1)]);
        let id = reg.submit(cfg, "q8");
        let full = reg.view(id).unwrap().to_json();
        let layers = full.get("layers").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(layers[0].get("trace").and_then(|v| v.as_str()), Some("q8"));
        // M=144 rows of 8 cols: codes + one f32 step per row
        assert_eq!(
            layers[0].get("trace_bytes").and_then(|v| v.as_usize()),
            Some(144 * 8 + 4 * 144)
        );
        assert_eq!(layers[0].get("accum").and_then(|v| v.as_str()), Some("f64"));
        // the head is pinned f32 at resolution: no trace echo, but the
        // accum knob (uniform) still shows
        assert!(layers[1].get("trace").is_none());
        assert_eq!(layers[1].get("accum").and_then(|v| v.as_str()), Some("f64"));
    }

    #[test]
    fn watch_streams_epochs_and_resumes_from_cursor() {
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(quick_cfg(4), "w");
        let (cfg, _) = reg.mark_running(id).unwrap();
        // no frames yet: zero-timeout watch returns an empty live batch
        let (e0, c0, s0) = reg.watch(id, 0, Duration::from_millis(0)).unwrap();
        assert!(e0.is_empty());
        assert_eq!(c0, 0);
        assert_eq!(s0, JobState::Running);
        let r = experiment::run_with(&cfg, &mut |m| {
            reg.record_epoch(id, m);
            true
        })
        .unwrap();
        let (e1, c1, _) = reg.watch(id, 0, Duration::from_millis(0)).unwrap();
        assert_eq!(e1.len(), 3);
        assert_eq!(c1, 3);
        // frames are full epoch metric objects
        assert_eq!(e1[0].get("epoch").unwrap().as_usize().unwrap(), 1);
        assert!(e1[0].get("train_loss").is_some());
        // mid-stream cursor resume
        let (e3, c3, _) = reg.watch(id, 1, Duration::from_millis(0)).unwrap();
        assert_eq!(e3.len(), 2);
        assert_eq!(c3, 3);
        // finish_ok backfill dedupes against already-recorded epochs
        reg.finish_ok(id, &r);
        let (e2, c2, s2) = reg.watch(id, c1, Duration::from_millis(0)).unwrap();
        assert!(e2.is_empty());
        assert_eq!(c2, 3);
        assert_eq!(s2, JobState::Done);
        // unknown jobs are an error, not a hang
        assert!(reg.watch(999, 0, Duration::from_millis(0)).is_err());
    }

    #[test]
    fn watch_long_poll_wakes_on_terminal_transition() {
        let reg = Arc::new(Registry::new(None).unwrap());
        let id = reg.submit(quick_cfg(6), "");
        let r2 = reg.clone();
        let h = std::thread::spawn(move || r2.watch(id, 0, Duration::from_secs(10)).unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(reg.cancel(id).unwrap(), JobState::Cancelled);
        let (frames, _, state) = h.join().unwrap();
        assert!(frames.is_empty());
        assert_eq!(state, JobState::Cancelled);
    }

    #[test]
    fn audit_snapshots_track_the_last_audited_epoch() {
        let reg = Registry::new(None).unwrap();
        let mut cfg = quick_cfg(9);
        cfg.audit = Some(2); // 3 epochs → audited at 1 and 3
        let id = reg.submit(cfg, "");
        let (cfg, _) = reg.mark_running(id).unwrap();
        let r = experiment::run_with(&cfg, &mut |m| {
            reg.record_epoch(id, m);
            true
        })
        .unwrap();
        reg.finish_ok(id, &r);
        let snaps = reg.audit_snapshots();
        assert_eq!(snaps.len(), 1);
        let (sid, epoch, recs) = &snaps[0];
        assert_eq!(*sid, id);
        assert_eq!(*epoch, 3);
        assert_eq!(recs.len(), 1);
        assert!(recs[0].cosine.is_finite());
        assert!(recs[0].rel_err > 0.0);
    }

    #[test]
    fn cancel_queued_is_immediate_and_skipped_by_workers() {
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(quick_cfg(1), "");
        assert_eq!(reg.cancel(id).unwrap(), JobState::Cancelled);
        assert_eq!(reg.view(id).unwrap().state, JobState::Cancelled);
        assert!(reg.mark_running(id).is_none());
        assert!(reg.cancel(99).is_err());
    }

    #[test]
    fn persistence_roundtrip_and_id_continuation() {
        let dir = std::env::temp_dir().join(format!("memaop_reg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = quick_cfg(7);
        let r = experiment::run(&cfg).unwrap();
        let first_id;
        {
            let reg = Registry::new(Some(dir.clone())).unwrap();
            first_id = reg.submit(cfg.clone(), "persisted");
            reg.mark_running(first_id).unwrap();
            reg.finish_ok(first_id, &r);
        }
        // fresh registry over the same dir sees the run
        let reg2 = Registry::new(Some(dir.clone())).unwrap();
        let v = reg2.view(first_id).unwrap();
        assert_eq!(v.state, JobState::Done);
        assert!(v.restored);
        assert_eq!(v.tag, "persisted");
        let (cfg2, curve2) = reg2.result_of(first_id).unwrap();
        assert_eq!(cfg2.label(), cfg.label());
        assert_eq!(cfg2.seed, 7);
        for (a, b) in curve2.epochs.iter().zip(r.curve.epochs.iter()) {
            assert_eq!(a.val_loss, b.val_loss);
            assert_eq!(a.backward_flops, b.backward_flops);
        }
        // new ids continue above the restored ones
        let next = reg2.submit(quick_cfg(8), "");
        assert!(next > first_id);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_entries_are_skipped_and_the_rest_recovered() {
        let dir = std::env::temp_dir().join(format!("memaop_torn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = quick_cfg(5);
        let r = experiment::run(&cfg).unwrap();
        let (healthy_id, torn_id);
        {
            let reg = Registry::new(Some(dir.clone())).unwrap();
            healthy_id = reg.submit(cfg.clone(), "healthy");
            reg.mark_running(healthy_id).unwrap();
            reg.finish_ok(healthy_id, &r);
            torn_id = reg.submit(cfg.clone(), "torn");
            reg.mark_running(torn_id).unwrap();
            reg.finish_ok(torn_id, &r);
        }
        // tear the second entry as a mid-write crash would have: keep
        // only half the bytes at the final path
        let torn_path = dir.join(format!("job_{torn_id:08}.maop"));
        let bytes = std::fs::read(&torn_path).unwrap();
        std::fs::write(&torn_path, &bytes[..bytes.len() / 2]).unwrap();
        // restart: the healthy entry loads, the torn one is skipped —
        // the whole registry must NOT fail over one bad file
        let reg2 = Registry::new(Some(dir.clone())).unwrap();
        assert_eq!(reg2.view(healthy_id).unwrap().state, JobState::Done);
        assert!(reg2.view(torn_id).is_none(), "torn entry must not load");
        assert_eq!(reg2.restored_count(), 1);
        // the torn id is still counted: a new job can never reuse it
        // (and silently overwrite the corpse)
        let next = reg2.submit(cfg.clone(), "after");
        assert!(next > torn_id, "id {next} reused under the torn id {torn_id}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_torn_writes_reproduce_the_skip_path() {
        let dir = std::env::temp_dir().join(format!("memaop_fault_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // torn=1000: every persist is torn, deterministically
        let plan = FaultPlan { seed: 2, torn_per_mille: 1000, ..FaultPlan::off() };
        let cfg = quick_cfg(6);
        let r = experiment::run(&cfg).unwrap();
        let id;
        {
            let reg = Registry::with_faults(Some(dir.clone()), plan).unwrap();
            id = reg.submit(cfg.clone(), "chaos");
            reg.mark_running(id).unwrap();
            reg.finish_ok(id, &r);
            // in-memory lifecycle is untouched by the torn persist
            assert_eq!(reg.view(id).unwrap().state, JobState::Done);
            assert_eq!(reg.result_of(id).unwrap().1.epochs.len(), 3);
        }
        // the on-disk entry is torn; restart skips it without failing
        let reg2 = Registry::new(Some(dir.clone())).unwrap();
        assert!(reg2.view(id).is_none());
        assert_eq!(reg2.restored_count(), 0);
        assert!(reg2.submit(cfg, "next") > id);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rollup_accounts_savings_per_policy() {
        let reg = Registry::new(None).unwrap();
        let cfg = quick_cfg(3); // topk, K=18 of M=144 → 1/8 of exact
        let id = reg.submit(cfg.clone(), "");
        let (cfg, _) = reg.mark_running(id).unwrap();
        let r = experiment::run(&cfg).unwrap();
        reg.finish_ok(id, &r);
        let roll = reg.rollup();
        assert_eq!(roll.len(), 1);
        assert_eq!(roll[0].policy, Policy::TopK);
        assert_eq!(roll[0].jobs, 1);
        assert!(roll[0].exact_flops > roll[0].backward_flops);
        assert!((roll[0].saved_frac() - 0.875).abs() < 1e-9, "{}", roll[0].saved_frac());
    }

    #[test]
    fn rollup_integrates_annealed_k_schedules() {
        // linear:18:72 over 4 epochs on the 16→1 energy head: the
        // rollup's actual side must equal the schedule's INTEGRAL —
        // Σ_epochs steps·aop_step(k_e) — not aop_step(k)×steps for any
        // single k
        let mut cfg = quick_cfg(11);
        cfg.epochs = 4;
        cfg.k = KSchedule::parse("linear:18:72").unwrap();
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(cfg.clone(), "");
        let (cfg, _) = reg.mark_running(id).unwrap();
        let r = experiment::run(&cfg).unwrap();
        reg.finish_ok(id, &r);
        let m = cfg.m();
        let steps_per_epoch = r.curve.steps_per_epoch as u64;
        assert!(steps_per_epoch > 0);
        let per_epoch_k: Vec<usize> = (1..=4).map(|e| cfg.k.k_at(e, 4, m)).collect();
        assert_eq!(per_epoch_k, vec![18, 36, 54, 72]);
        let integral: u64 = per_epoch_k
            .iter()
            .map(|&k| flops::aop_step(m, 16, 1, k).backward_only() * steps_per_epoch)
            .sum();
        let single_k = flops::aop_step(m, 16, 1, 18).backward_only() * steps_per_epoch * 4;
        let roll = reg.rollup();
        assert_eq!(roll.len(), 1);
        assert_eq!(roll[0].backward_flops, integral);
        assert_ne!(roll[0].backward_flops, single_k);
        // savings fraction reflects the mean budget (45/144), not the
        // starting one
        let expect_saved = 1.0 - 45.0 / 144.0;
        assert!(
            (roll[0].saved_frac() - expect_saved).abs() < 1e-9,
            "{}",
            roll[0].saved_frac()
        );
    }

    #[test]
    fn rollup_attributes_mixed_policy_layers_per_layer() {
        use crate::coordinator::config::LayerSpec;
        // layer 0: randk override; head: the flat topk — the FLOPs must
        // land in each layer's own policy bucket, not all under topk
        let mut cfg = quick_cfg(5);
        cfg.layers = Some(vec![
            LayerSpec {
                width: 8,
                activation: None,
                k: Some(KSchedule::Constant(36)),
                policy: Some(Policy::RandK),
                memory: None,
            },
            LayerSpec::plain(1),
        ]);
        cfg.validate().unwrap();
        let reg = Registry::new(None).unwrap();
        let id = reg.submit(cfg.clone(), "");
        let (cfg, _) = reg.mark_running(id).unwrap();
        let r = experiment::run(&cfg).unwrap();
        reg.finish_ok(id, &r);
        let roll = reg.rollup();
        assert_eq!(roll.len(), 2, "one bucket per layer policy");
        let by_name = |p: Policy| roll.iter().find(|r| r.policy == p).unwrap();
        let randk = by_name(Policy::RandK);
        let topk = by_name(Policy::TopK);
        assert_eq!(randk.jobs, 1);
        assert_eq!(topk.jobs, 1);
        // layer 0 (16→8, K=36/144): 1/4 of exact; head (8→1, K=18): 1/8
        assert!((randk.saved_frac() - 0.75).abs() < 1e-9, "{}", randk.saved_frac());
        assert!((topk.saved_frac() - 0.875).abs() < 1e-9, "{}", topk.saved_frac());
        // the two buckets together cover the whole job's backward FLOPs
        assert_eq!(
            randk.backward_flops + topk.backward_flops,
            r.curve.total_backward_flops()
        );
    }
}
