//! Shuffling mini-batch iterator.
//!
//! Reproduces the reference Keras loop: reshuffle every epoch, fixed batch
//! size, drop the trailing partial batch (the AOT artifacts are compiled
//! for a static batch dimension, so partial batches cannot be fed to the
//! HLO path anyway).

use super::Dataset;
use crate::tensor::rng::Rng;

/// Epoch-wise batch plan: a shuffled index permutation cut into
/// fixed-size batches.
pub struct Batcher {
    batch_size: usize,
    indices: Vec<usize>,
}

impl Batcher {
    pub fn new(n: usize, batch_size: usize) -> Self {
        assert!(batch_size > 0 && batch_size <= n, "batch {batch_size} vs n {n}");
        Batcher {
            batch_size,
            indices: (0..n).collect(),
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.indices.len() / self.batch_size
    }

    /// Reshuffle and return this epoch's batch index slices.
    pub fn epoch(&mut self, rng: &mut Rng) -> Vec<Vec<usize>> {
        rng.shuffle(&mut self.indices);
        self.indices
            .chunks_exact(self.batch_size)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Convenience: materialize this epoch's batches from a dataset.
    pub fn epoch_batches(&mut self, ds: &Dataset, rng: &mut Rng) -> Vec<Dataset> {
        self.epoch(rng).iter().map(|idx| ds.gather(idx)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    #[test]
    fn batch_counts() {
        let b = Batcher::new(576, 144);
        assert_eq!(b.batches_per_epoch(), 4);
        let b2 = Batcher::new(60_000, 64);
        assert_eq!(b2.batches_per_epoch(), 937); // drop-last
    }

    #[test]
    fn epoch_partitions_without_duplicates() {
        let mut b = Batcher::new(100, 10);
        let mut rng = Rng::new(0);
        let batches = b.epoch(&mut rng);
        assert_eq!(batches.len(), 10);
        let mut all: Vec<usize> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn drop_last_partial() {
        let mut b = Batcher::new(103, 10);
        let mut rng = Rng::new(1);
        let batches = b.epoch(&mut rng);
        assert_eq!(batches.len(), 10);
        let used: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(used, 100);
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut b = Batcher::new(50, 50);
        let mut rng = Rng::new(2);
        let e1 = b.epoch(&mut rng);
        let e2 = b.epoch(&mut rng);
        assert_ne!(e1[0], e2[0]);
    }

    #[test]
    fn epoch_batches_gather_rows() {
        let ds = Dataset::new(
            Matrix::from_fn(9, 2, |r, _| r as f32),
            Matrix::from_fn(9, 1, |r, _| r as f32),
        );
        let mut b = Batcher::new(9, 3);
        let mut rng = Rng::new(3);
        let batches = b.epoch_batches(&ds, &mut rng);
        assert_eq!(batches.len(), 3);
        for batch in &batches {
            assert_eq!(batch.len(), 3);
            for r in 0..3 {
                assert_eq!(batch.x[(r, 0)], batch.y[(r, 0)]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn oversized_batch_rejected() {
        Batcher::new(10, 11);
    }
}
