//! Procedural digit rasterizer — substitute for MNIST [19], which is
//! unavailable offline.
//!
//! Each digit 0-9 is a stroke skeleton (polylines in the unit square)
//! rendered to 28×28 with: random affine jitter (rotation, anisotropic
//! scale, translation), random stroke thickness, smooth-falloff ink
//! deposition (distance-to-segment), and pixel noise — giving the same
//! input dimension (784), class count (10) and rough intra-class
//! variability as MNIST. The paper's model (784×10 dense + softmax)
//! reaches comparable separability on it, which is what the optimizer-
//! dynamics claims of Figs. 3 need.

use super::Dataset;
use crate::tensor::rng::Rng;
use crate::tensor::Matrix;

pub const SIDE: usize = 28;
pub const PIXELS: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

type Seg = ((f32, f32), (f32, f32));

/// Stroke skeletons in [0,1]² (y grows downward). Hand-designed to be
/// visually faithful, distinct, and to exercise curves via polyline
/// approximation.
fn skeleton(digit: usize) -> Vec<Seg> {
    let poly = |pts: &[(f32, f32)]| -> Vec<Seg> {
        pts.windows(2).map(|w| (w[0], w[1])).collect()
    };
    match digit {
        0 => poly(&[
            (0.50, 0.08),
            (0.22, 0.25),
            (0.20, 0.70),
            (0.50, 0.92),
            (0.78, 0.70),
            (0.80, 0.25),
            (0.50, 0.08),
        ]),
        1 => {
            let mut v = poly(&[(0.35, 0.25), (0.55, 0.08), (0.55, 0.92)]);
            v.extend(poly(&[(0.35, 0.92), (0.75, 0.92)]));
            v
        }
        2 => poly(&[
            (0.25, 0.25),
            (0.45, 0.08),
            (0.72, 0.18),
            (0.74, 0.40),
            (0.25, 0.92),
            (0.78, 0.92),
        ]),
        3 => poly(&[
            (0.25, 0.14),
            (0.65, 0.10),
            (0.75, 0.28),
            (0.48, 0.48),
            (0.78, 0.68),
            (0.62, 0.90),
            (0.24, 0.86),
        ]),
        4 => {
            let mut v = poly(&[(0.60, 0.08), (0.22, 0.62), (0.80, 0.62)]);
            v.extend(poly(&[(0.60, 0.08), (0.60, 0.92)]));
            v
        }
        5 => poly(&[
            (0.75, 0.10),
            (0.28, 0.10),
            (0.26, 0.45),
            (0.60, 0.42),
            (0.78, 0.62),
            (0.66, 0.88),
            (0.24, 0.86),
        ]),
        6 => poly(&[
            (0.68, 0.10),
            (0.34, 0.30),
            (0.24, 0.62),
            (0.40, 0.90),
            (0.70, 0.82),
            (0.74, 0.58),
            (0.45, 0.50),
            (0.26, 0.62),
        ]),
        7 => {
            let mut v = poly(&[(0.22, 0.10), (0.78, 0.10), (0.42, 0.92)]);
            v.extend(poly(&[(0.35, 0.50), (0.68, 0.50)]));
            v
        }
        8 => poly(&[
            (0.50, 0.08),
            (0.28, 0.22),
            (0.44, 0.46),
            (0.24, 0.70),
            (0.50, 0.92),
            (0.76, 0.70),
            (0.56, 0.46),
            (0.72, 0.22),
            (0.50, 0.08),
        ]),
        9 => poly(&[
            (0.74, 0.38),
            (0.52, 0.50),
            (0.28, 0.40),
            (0.30, 0.14),
            (0.62, 0.08),
            (0.74, 0.30),
            (0.68, 0.70),
            (0.50, 0.92),
        ]),
        _ => panic!("digit out of range: {digit}"),
    }
}

/// Random affine sample parameters.
struct Affine {
    cos: f32,
    sin: f32,
    sx: f32,
    sy: f32,
    tx: f32,
    ty: f32,
}

impl Affine {
    fn sample(rng: &mut Rng) -> Affine {
        let theta = (rng.uniform() * 2.0 - 1.0) * 0.26; // ±15°
        Affine {
            cos: theta.cos(),
            sin: theta.sin(),
            sx: 0.82 + rng.uniform() * 0.30,
            sy: 0.82 + rng.uniform() * 0.30,
            tx: (rng.uniform() * 2.0 - 1.0) * 0.08,
            ty: (rng.uniform() * 2.0 - 1.0) * 0.08,
        }
    }

    /// Map a skeleton point (about the glyph center) into [0,1]².
    fn apply(&self, (x, y): (f32, f32)) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (rx, ry) = (
            self.cos * cx * self.sx - self.sin * cy * self.sy,
            self.sin * cx * self.sx + self.cos * cy * self.sy,
        );
        (rx + 0.5 + self.tx, ry + 0.5 + self.ty)
    }
}

/// Squared distance from point `p` to segment `(a, b)`.
fn dist2_to_seg(p: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    let (px, py) = p;
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    (px - cx) * (px - cx) + (py - cy) * (py - cy)
}

/// Render one digit sample into a 784-length row (ink in [0,1]).
pub fn render_digit(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    assert_eq!(out.len(), PIXELS);
    out.fill(0.0);
    let affine = Affine::sample(rng);
    let thickness = 0.035 + rng.uniform() * 0.030; // stroke radius
    let t2 = thickness * thickness;
    let falloff = 2.2 * t2; // smooth edge width (squared)
    let segs: Vec<Seg> = skeleton(digit)
        .into_iter()
        .map(|(a, b)| (affine.apply(a), affine.apply(b)))
        .collect();

    let inv = 1.0 / SIDE as f32;
    for (si, &(a, b)) in segs.iter().enumerate() {
        let _ = si;
        // bounding box (in pixels) with margin
        let margin = thickness + 0.08;
        let x0 = ((a.0.min(b.0) - margin) * SIDE as f32).floor().max(0.0) as usize;
        let x1 = ((a.0.max(b.0) + margin) * SIDE as f32).ceil().min(SIDE as f32) as usize;
        let y0 = ((a.1.min(b.1) - margin) * SIDE as f32).floor().max(0.0) as usize;
        let y1 = ((a.1.max(b.1) + margin) * SIDE as f32).ceil().min(SIDE as f32) as usize;
        for py in y0..y1 {
            for px in x0..x1 {
                let p = ((px as f32 + 0.5) * inv, (py as f32 + 0.5) * inv);
                let d2 = dist2_to_seg(p, a, b);
                if d2 < t2 + falloff {
                    // smooth ink: 1 inside the core, cosine falloff outside
                    let ink = if d2 <= t2 {
                        1.0
                    } else {
                        let u = (d2 - t2) / falloff;
                        (1.0 - u).max(0.0)
                    };
                    let idx = py * SIDE + px;
                    out[idx] = out[idx].max(ink);
                }
            }
        }
    }
    // pixel noise + slight global intensity jitter (sensor-ish)
    let gain = 0.9 + rng.uniform() * 0.2;
    for v in out.iter_mut() {
        let noise = 0.02 * rng.normal();
        *v = (*v * gain + noise).clamp(0.0, 1.0);
    }
}

/// Generate a dataset of `n` samples with balanced, shuffled classes.
/// Targets are one-hot rows.
pub fn digits_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut labels: Vec<usize> = (0..n).map(|i| i % CLASSES).collect();
    rng.shuffle(&mut labels);
    let mut x = Matrix::zeros(n, PIXELS);
    for (r, &d) in labels.iter().enumerate() {
        render_digit(d, &mut rng, x.row_mut(r));
    }
    let y = Matrix::from_fn(n, CLASSES, |r, c| (labels[r] == c) as u32 as f32);
    Dataset::new(x, y)
}

/// Tab. I sizes: 60k train / 10k validation. `scale` shrinks both (the
/// figure harness uses scale < 1.0 to keep CPU runtimes tractable; the
/// substitution is recorded in EXPERIMENTS.md).
pub fn mnist_like(scale: f32, seed: u64) -> (Dataset, Dataset) {
    let ntr = ((60_000.0 * scale) as usize).max(CLASSES);
    let nva = ((10_000.0 * scale) as usize).max(CLASSES);
    (
        digits_dataset(ntr, seed),
        digits_dataset(nva, seed ^ 0xD161_7A11),
    )
}

/// ASCII-art preview (debug / quickstart example).
pub fn ascii_art(row: &[f32]) -> String {
    let ramp = [' ', '.', ':', '+', '#'];
    let mut s = String::with_capacity(PIXELS + SIDE);
    for y in 0..SIDE {
        for x in 0..SIDE {
            let v = row[y * SIDE + x].clamp(0.0, 1.0);
            s.push(ramp[((v * 4.0).round() as usize).min(4)]);
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = digits_dataset(50, 3);
        let b = digits_dataset(50, 3);
        assert_eq!(a.x, b.x);
        let c = digits_dataset(50, 4);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn one_hot_targets_balanced() {
        let d = digits_dataset(100, 0);
        let counts = d.y.col_sums();
        assert_eq!(counts.iter().sum::<f32>() as usize, 100);
        for c in counts {
            assert_eq!(c, 10.0); // 100 samples / 10 classes
        }
    }

    #[test]
    fn pixels_in_unit_range_with_ink() {
        let d = digits_dataset(30, 1);
        for r in 0..30 {
            let row = d.x.row(r);
            assert!(row.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ink: f32 = row.iter().sum();
            assert!(ink > 10.0, "row {r} nearly blank: {ink}");
            assert!(ink < 500.0, "row {r} nearly full: {ink}");
        }
    }

    #[test]
    fn all_digits_render_distinctly() {
        // the mean images of different classes must differ substantially
        let mut rng = Rng::new(5);
        let mean_img = |d: usize, rng: &mut Rng| -> Vec<f32> {
            let mut acc = vec![0.0f32; PIXELS];
            let mut buf = vec![0.0f32; PIXELS];
            for _ in 0..20 {
                render_digit(d, rng, &mut buf);
                for (a, &b) in acc.iter_mut().zip(buf.iter()) {
                    *a += b / 20.0;
                }
            }
            acc
        };
        let means: Vec<Vec<f32>> = (0..10).map(|d| mean_img(d, &mut rng)).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                let dist: f32 = means[i]
                    .iter()
                    .zip(means[j].iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(dist > 3.0, "digits {i} and {j} too similar: {dist}");
            }
        }
    }

    #[test]
    fn intra_class_variability_nonzero() {
        let mut rng = Rng::new(6);
        let mut a = vec![0.0f32; PIXELS];
        let mut b = vec![0.0f32; PIXELS];
        render_digit(3, &mut rng, &mut a);
        render_digit(3, &mut rng, &mut b);
        let dist: f32 = a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(dist > 0.5, "augmentation too weak: {dist}");
    }

    #[test]
    fn linear_probe_separates_classes() {
        // a linear softmax probe must beat chance by a wide margin,
        // otherwise Fig. 3's learning dynamics wouldn't transfer
        use crate::aop::{AopEngine, Policy};
        use crate::model::LossKind;
        use crate::tensor::init;
        let tr = digits_dataset(600, 7);
        let mut rng = Rng::new(8);
        let mut e = AopEngine::new(
            init::glorot_uniform(&mut rng, PIXELS, CLASSES),
            LossKind::SoftmaxCrossEntropy,
            600,
            Policy::Exact,
            600,
            false,
        );
        for _ in 0..60 {
            e.step(&tr.x, &tr.y, 0.5, &mut rng);
        }
        let (_, acc) = e.evaluate(&tr.x, &tr.y);
        assert!(acc > 0.7, "linear probe acc={acc}");
    }

    #[test]
    fn mnist_like_sizes() {
        let (tr, va) = mnist_like(0.01, 0);
        assert_eq!(tr.len(), 600);
        assert_eq!(va.len(), 100);
    }

    #[test]
    fn ascii_art_shape() {
        let d = digits_dataset(1, 9);
        let art = ascii_art(d.x.row(0));
        assert_eq!(art.lines().count(), SIDE);
        assert!(art.lines().all(|l| l.chars().count() == SIDE));
    }
}
