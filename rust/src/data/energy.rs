//! Building-thermal simulator — substitute for the UCI Energy-Efficiency
//! dataset [18] (Tsanas & Xifara 2012), which is unavailable offline.
//!
//! The original dataset is itself *simulated* (Ecotect runs over 768
//! building variants: 12 shapes × 4 orientations × 4 glazing areas × ...),
//! so we rebuild the generative process: sample the same 8 design
//! variables on the UCI grids, compute the heating load with a first-order
//! thermal-envelope model (conduction through walls/roof/glazing + solar
//! gain modulated by orientation and glazing distribution + ventilation),
//! and add mild measurement noise.
//!
//! Preprocessing to the paper's 16 features: 6 continuous variables
//! (relative compactness, surface area, wall area, roof area, height,
//! glazing area) + one-hot orientation (4) + one-hot glazing distribution
//! (6) = 16. Features and target are z-scored on the training split.

use super::Dataset;
use crate::tensor::rng::Rng;
use crate::tensor::Matrix;

/// One building design (the UCI X1..X8 grid).
#[derive(Debug, Clone, Copy)]
pub struct Building {
    pub rel_compactness: f32, // X1: 0.62..0.98
    pub surface_area: f32,    // X2: m^2
    pub wall_area: f32,       // X3
    pub roof_area: f32,       // X4
    pub height: f32,          // X5: 3.5 or 7.0
    pub orientation: usize,   // X6: 0..4 (N/E/S/W)
    pub glazing_area: f32,    // X7: 0, .1, .25, .4 (fraction of floor area)
    pub glazing_dist: usize,  // X8: 0..6 (uniform/N/E/S/W/none)
}

/// The 12 UCI base shapes: boxes of volume 771.75 m³ with varying
/// footprint aspect; relative compactness spans 0.62..0.98.
const VOLUME: f32 = 771.75;

fn shape_from_compactness(rc: f32, height: f32) -> (f32, f32, f32) {
    // For a square-footprint box of volume V and height h, footprint side
    // s = sqrt(V / h). Lower compactness = more elongated footprint: keep
    // the area, stretch one side by factor `e`, shrink the other.
    let base = (VOLUME / height).sqrt();
    // map rc∈[0.62,0.98] to elongation e∈[2.6,1.0]
    let e = 1.0 + (0.98 - rc) / (0.98 - 0.62) * 1.6;
    (base * e, base / e, height)
}

impl Building {
    /// Envelope surface areas from the box geometry.
    fn geometry(&self) -> (f32, f32, f32) {
        shape_from_compactness(self.rel_compactness, self.height)
    }

    /// First-order steady-state heating load (kWh/m²-ish scale, matching
    /// the UCI target's 6..43 range).
    pub fn heating_load(&self, rng: &mut Rng) -> f32 {
        let (lx, ly, h) = self.geometry();
        let floor = lx * ly;
        let wall = 2.0 * (lx + ly) * h;
        let roof = floor;
        let glazing = self.glazing_area * floor;

        // U-values (W/m²K): wall 1.8, roof 0.9, window 5.7 (UCI-era
        // constructions), ΔT winter design 20K, scaled to annual kWh/m².
        let u_wall = 1.8f32;
        let u_roof = 0.9f32;
        let u_glass = 5.7f32;
        let conduction = u_wall * wall + u_roof * roof + u_glass * glazing;

        // Solar gain offsets heating; south-facing glazing (orientation 2)
        // with south-weighted distribution (dist 3) gains most.
        let orient_gain = [0.55f32, 0.75, 1.0, 0.75][self.orientation];
        let dist_gain = [0.8f32, 0.7, 0.75, 1.0, 0.75, 0.0][self.glazing_dist];
        let solar = 2.2 * glazing * orient_gain * dist_gain;

        // Ventilation/infiltration scales with volume; taller buildings
        // stratify (small superlinear term in height).
        let ventilation = 0.35 * VOLUME * (1.0 + 0.04 * (h - 3.5));

        // Normalize by floor area to the UCI target scale and add mild
        // simulation noise (Ecotect outputs are deterministic; UCI noise
        // comes from model discretization — 1% here).
        let raw = (conduction + ventilation - solar) / floor;
        let load = 0.55 * raw + 2.0;
        load * (1.0 + 0.01 * rng.normal())
    }

    /// Expand to the 16-dim feature vector (DESIGN.md §3).
    pub fn features(&self) -> [f32; 16] {
        let mut f = [0.0f32; 16];
        f[0] = self.rel_compactness;
        f[1] = self.surface_area;
        f[2] = self.wall_area;
        f[3] = self.roof_area;
        f[4] = self.height;
        f[5] = self.glazing_area;
        f[6 + self.orientation] = 1.0; // 4 slots
        f[10 + self.glazing_dist] = 1.0; // 6 slots
        f
    }
}

/// UCI grids.
const RC_GRID: [f32; 12] = [
    0.62, 0.64, 0.66, 0.69, 0.71, 0.74, 0.76, 0.79, 0.82, 0.86, 0.90, 0.98,
];
const GLAZING_GRID: [f32; 4] = [0.0, 0.10, 0.25, 0.40];

/// Generate `n` buildings by sampling the UCI grid uniformly (seeded).
pub fn generate_buildings(n: usize, seed: u64) -> Vec<Building> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let rc = RC_GRID[rng.below(RC_GRID.len())];
            let height = if rng.below(2) == 0 { 3.5 } else { 7.0 };
            let (lx, ly, h) = shape_from_compactness(rc, height);
            let floor = lx * ly;
            let wall = 2.0 * (lx + ly) * h;
            Building {
                rel_compactness: rc,
                surface_area: 2.0 * floor + wall,
                wall_area: wall,
                roof_area: floor,
                height,
                orientation: rng.below(4),
                glazing_area: GLAZING_GRID[rng.below(GLAZING_GRID.len())],
                glazing_dist: rng.below(6),
            }
        })
        .collect()
}

/// Full dataset: 768 buildings (UCI size) → standardized 16-feature
/// regression; split 576 train / 192 validation per Tab. I.
pub fn energy_dataset(seed: u64) -> (Dataset, Dataset) {
    energy_dataset_sized(768, 576, seed)
}

/// Sized variant for tests/benches.
pub fn energy_dataset_sized(total: usize, train: usize, seed: u64) -> (Dataset, Dataset) {
    assert!(train <= total);
    let buildings = generate_buildings(total, seed);
    let mut rng = Rng::new(seed ^ 0xE17A);
    let x = Matrix::from_fn(total, 16, |r, c| buildings[r].features()[c]);
    let y = Matrix::from_fn(total, 1, |r, _| buildings[r].heating_load(&mut rng));
    let ds = Dataset::new(x, y);
    let (mut tr, mut va) = ds.split_at(train);
    let st = tr.standardize_fit(true);
    st.transform(&mut va);
    (tr, va)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let (a, _) = energy_dataset(7);
        let (b, _) = energy_dataset(7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let (c, _) = energy_dataset(8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn tab1_sizes() {
        let (tr, va) = energy_dataset(0);
        assert_eq!(tr.len(), 576);
        assert_eq!(va.len(), 192);
        assert_eq!(tr.x.cols(), 16);
        assert_eq!(tr.y.cols(), 1);
    }

    #[test]
    fn loads_in_physical_range_before_standardization() {
        let buildings = generate_buildings(768, 3);
        let mut rng = Rng::new(9);
        for b in &buildings {
            let l = b.heating_load(&mut rng);
            assert!(l > 2.0 && l < 60.0, "load={l} for {b:?}");
        }
    }

    #[test]
    fn one_hot_features_valid() {
        for b in generate_buildings(200, 4) {
            let f = b.features();
            let orient: f32 = f[6..10].iter().sum();
            let dist: f32 = f[10..16].iter().sum();
            assert_eq!(orient, 1.0);
            assert_eq!(dist, 1.0);
        }
    }

    #[test]
    fn target_is_learnable_by_linear_model() {
        // ridge-free sanity: least-squares linear fit explains most of the
        // variance (the paper trains a 16×1 linear layer on this).
        use crate::tensor::ops;
        let (tr, _) = energy_dataset(1);
        // normal equations via Gauss-Seidel-ish gradient descent
        let mut w = Matrix::zeros(16, 1);
        for _ in 0..2000 {
            let pred = tr.x.matmul(&w);
            let g = ops::matmul_tn(&tr.x, &pred.sub(&tr.y)).scale(2.0 / tr.len() as f32);
            w.axpy(-0.05, &g);
        }
        let pred = tr.x.matmul(&w);
        let resid = pred.sub(&tr.y).frobenius().powi(2) / tr.len() as f32;
        let var = tr.y.frobenius().powi(2) / tr.len() as f32; // y standardized
        let r2 = 1.0 - resid / var;
        assert!(r2 > 0.7, "R²={r2}");
    }

    #[test]
    fn compactness_raises_efficiency() {
        // more compact buildings (higher RC) lose less per floor area
        let mut rng = Rng::new(5);
        let mk = |rc: f32| Building {
            rel_compactness: rc,
            surface_area: 0.0,
            wall_area: 0.0,
            roof_area: 0.0,
            height: 3.5,
            orientation: 2,
            glazing_area: 0.25,
            glazing_dist: 0,
        };
        let lo: f32 = (0..50).map(|_| mk(0.62).heating_load(&mut rng)).sum::<f32>() / 50.0;
        let hi: f32 = (0..50).map(|_| mk(0.98).heating_load(&mut rng)).sum::<f32>() / 50.0;
        assert!(lo > hi, "elongated {lo} should exceed compact {hi}");
    }

    #[test]
    fn glazing_and_height_effects() {
        let mut rng = Rng::new(6);
        let base = Building {
            rel_compactness: 0.76,
            surface_area: 0.0,
            wall_area: 0.0,
            roof_area: 0.0,
            height: 3.5,
            orientation: 0,
            glazing_area: 0.0,
            glazing_dist: 5,
        };
        let mut glazed = base;
        glazed.glazing_area = 0.4;
        let l0: f32 = (0..50).map(|_| base.heating_load(&mut rng)).sum::<f32>() / 50.0;
        let l1: f32 = (0..50).map(|_| glazed.heating_load(&mut rng)).sum::<f32>() / 50.0;
        assert!(l1 > l0, "glazing (north, no solar) adds loss: {l1} vs {l0}");

        let mut tall = base;
        tall.height = 7.0;
        let l2: f32 = (0..50).map(|_| tall.heating_load(&mut rng)).sum::<f32>() / 50.0;
        assert!(l2 != l0);
    }
}
