//! Dataset substrates.
//!
//! The paper evaluates on the UCI Energy-Efficiency dataset and MNIST;
//! neither is available in this offline environment, so both are rebuilt
//! as seeded simulators with the same learning-problem structure
//! (DESIGN.md §3):
//!
//! * [`energy`] — parametric building-thermal simulator → 16-feature
//!   regression, 576/192 split (Tab. I);
//! * [`digits`] — procedural stroke-font digit rasterizer → 784-feature
//!   10-class classification, 60k/10k split (Tab. I);
//! * [`batcher`] — shuffling mini-batch iterator (drop-last, like the
//!   reference Keras loop).

pub mod batcher;
pub mod digits;
pub mod energy;

use crate::tensor::Matrix;

/// A supervised dataset: row-aligned features and targets.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Matrix,
}

impl Dataset {
    pub fn new(x: Matrix, y: Matrix) -> Self {
        assert_eq!(x.rows(), y.rows(), "feature/target row mismatch");
        Dataset { x, y }
    }

    pub fn len(&self) -> usize {
        self.x.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Split into (first `n`, rest).
    pub fn split_at(&self, n: usize) -> (Dataset, Dataset) {
        assert!(n <= self.len());
        let head: Vec<usize> = (0..n).collect();
        let tail: Vec<usize> = (n..self.len()).collect();
        (self.gather(&head), self.gather(&tail))
    }

    /// Gather rows by index into a new dataset (the batcher's hot path —
    /// row-wise `copy_from_slice`, not per-element indexing).
    pub fn gather(&self, idx: &[usize]) -> Dataset {
        let gather_m = |m: &Matrix| -> Matrix {
            let cols = m.cols();
            let mut out = Matrix::zeros(idx.len(), cols);
            for (r, &src) in idx.iter().enumerate() {
                out.row_mut(r).copy_from_slice(m.row(src));
            }
            out
        };
        Dataset::new(gather_m(&self.x), gather_m(&self.y))
    }

    /// Z-score standardize features (and optionally targets) using stats
    /// computed on `self`; returns the stats so the validation split can be
    /// transformed identically.
    pub fn standardize_fit(&mut self, targets_too: bool) -> Standardizer2 {
        let sx = Standardizer::fit(&self.x);
        sx.apply(&mut self.x);
        let sy = if targets_too {
            let s = Standardizer::fit(&self.y);
            s.apply(&mut self.y);
            Some(s)
        } else {
            None
        };
        Standardizer2 { sx, sy }
    }
}

/// Per-column mean/std transform.
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

/// Combined feature/target standardizer returned by `standardize_fit`.
#[derive(Debug, Clone)]
pub struct Standardizer2 {
    pub sx: Standardizer,
    pub sy: Option<Standardizer>,
}

impl Standardizer2 {
    /// Apply the fitted transform to another dataset (validation split).
    pub fn transform(&self, ds: &mut Dataset) {
        self.sx.apply(&mut ds.x);
        if let Some(sy) = &self.sy {
            sy.apply(&mut ds.y);
        }
    }
}

impl Standardizer {
    pub fn fit(m: &Matrix) -> Standardizer {
        let rows = m.rows() as f32;
        let mut mean = vec![0.0f32; m.cols()];
        for r in 0..m.rows() {
            for (mu, &v) in mean.iter_mut().zip(m.row(r).iter()) {
                *mu += v;
            }
        }
        for mu in &mut mean {
            *mu /= rows;
        }
        let mut var = vec![0.0f32; m.cols()];
        for r in 0..m.rows() {
            for c in 0..m.cols() {
                let d = m[(r, c)] - mean[c];
                var[c] += d * d;
            }
        }
        let std: Vec<f32> = var
            .iter()
            .map(|v| (v / rows).sqrt().max(1e-6))
            .collect();
        Standardizer { mean, std }
    }

    pub fn apply(&self, m: &mut Matrix) {
        for r in 0..m.rows() {
            for c in 0..m.cols() {
                m[(r, c)] = (m[(r, c)] - self.mean[c]) / self.std[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            Matrix::from_fn(n, 3, |r, c| (r * 3 + c) as f32),
            Matrix::from_fn(n, 1, |r, _| r as f32),
        )
    }

    #[test]
    fn split_preserves_rows() {
        let d = ds(10);
        let (a, b) = d.split_at(7);
        assert_eq!(a.len(), 7);
        assert_eq!(b.len(), 3);
        assert_eq!(b.x[(0, 0)], 21.0);
        assert_eq!(b.y[(2, 0)], 9.0);
    }

    #[test]
    fn gather_reorders() {
        let d = ds(5);
        let g = d.gather(&[4, 0, 2]);
        assert_eq!(g.len(), 3);
        assert_eq!(g.y.col(0), vec![4.0, 0.0, 2.0]);
    }

    #[test]
    fn standardize_zero_mean_unit_std() {
        let mut d = ds(50);
        let st = d.standardize_fit(true);
        for c in 0..d.x.cols() {
            let col = d.x.col(c);
            let mean: f32 = col.iter().sum::<f32>() / 50.0;
            let var: f32 = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 50.0;
            assert!(mean.abs() < 1e-4, "mean={mean}");
            assert!((var - 1.0).abs() < 1e-3, "var={var}");
        }
        // transform a second dataset with the same stats
        let mut d2 = ds(10);
        st.transform(&mut d2);
        assert!(d2.x[(0, 0)].abs() > 0.0 || d2.x[(0, 0)] == 0.0); // finite
        assert!(d2.x.is_finite());
    }

    #[test]
    #[should_panic(expected = "feature/target row mismatch")]
    fn mismatched_rows_rejected() {
        Dataset::new(Matrix::zeros(3, 2), Matrix::zeros(4, 1));
    }
}
