//! Metrics: loss curves, per-epoch records, summary statistics, and
//! CSV/JSONL sinks consumed by the figure harness and EXPERIMENTS.md.

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::obs::AuditLayerRecord;
use crate::util::json::{self, Json};

/// Per-layer record within one epoch (protocol v3; selection
/// diagnostics and per-layer memory mass since protocol v6): how much
/// of the approximation budget each layer actually used, what it cost,
/// and how the policy behaved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerEpochMetrics {
    /// Mean distinct outer products evaluated per step at this layer.
    pub k_effective: f64,
    /// Cumulative backward weight-gradient FLOPs spent at this layer.
    pub backward_flops: u64,
    /// Mean consecutive-step selection-index Jaccard overlap across the
    /// epoch's steps (1 = the policy keeps picking the same rows;
    /// 0 = disjoint picks, or unknown for pre-v6 records).
    pub sel_jaccard: f64,
    /// Mean Shannon entropy (nats) of the normalized per-step policy
    /// score distribution (0 for Exact layers and pre-v6 records).
    pub score_entropy: f64,
    /// This layer's deferred-memory Frobenius norm at epoch end. The
    /// epoch-level `mem_fro` is the quadrature sum of these
    /// (`global² = Σ layer²`, pinned in `rust/tests/exec.rs`).
    pub mem_fro: f32,
}

/// One epoch's record for a training run.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f32,
    pub val_loss: f32,
    /// Classification accuracy on the validation split (0 for regression).
    pub val_acc: f32,
    /// Mean ||Ŵ*||_F over the epoch's steps (update magnitude diagnostic).
    pub wstar_fro: f32,
    /// Frobenius mass deferred in memory at epoch end.
    pub mem_fro: f32,
    /// Cumulative FLOPs spent on weight-gradient computation so far.
    pub backward_flops: u64,
    /// Training-row throughput of this epoch (mini-batch rows processed
    /// per second of training time, validation excluded; 0 = unknown).
    /// This is the `exec` subsystem's measured — not asserted — speedup
    /// axis: same curve bits at any `threads`, different rows/sec.
    pub rows_per_sec: f64,
    /// Wall-clock seconds spent on this epoch (training + validation).
    pub wall_s: f64,
    /// Per-layer k_effective/FLOPs/diagnostics (one entry per graph
    /// layer; empty for curves recorded before the layer-graph core or
    /// built by hand).
    pub layers: Vec<LayerEpochMetrics>,
    /// Gradient-fidelity audit records for this epoch (protocol v6):
    /// one entry per layer on audited epochs, empty otherwise — the
    /// `audit` key is omitted from the wire frame when empty, so
    /// un-audited runs keep the exact pre-v6 frame shape.
    pub audit: Vec<AuditLayerRecord>,
}

impl EpochMetrics {
    /// The per-epoch wire frame (one element of a curve's `epochs`
    /// array, and the streaming unit of the serve `watch` op).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("epoch", json::num(self.epoch as f64)),
            ("train_loss", json::num(self.train_loss as f64)),
            ("val_loss", json::num(self.val_loss as f64)),
            ("val_acc", json::num(self.val_acc as f64)),
            ("wstar_fro", json::num(self.wstar_fro as f64)),
            ("mem_fro", json::num(self.mem_fro as f64)),
            ("backward_flops", json::num(self.backward_flops as f64)),
            ("rows_per_sec", json::num(self.rows_per_sec)),
            ("wall_s", json::num(self.wall_s)),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            json::obj(vec![
                                ("k_effective", json::num(l.k_effective)),
                                ("backward_flops", json::num(l.backward_flops as f64)),
                                ("sel_jaccard", json::num(l.sel_jaccard)),
                                ("score_entropy", json::num(l.score_entropy)),
                                ("mem_fro", json::num(l.mem_fro as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !self.audit.is_empty() {
            pairs.push(("audit", Json::Arr(self.audit.iter().map(|a| a.to_json()).collect())));
        }
        json::obj(pairs)
    }

    /// Inverse of [`EpochMetrics::to_json`]. Fields added after v1 are
    /// optional with zero-ish defaults, so records persisted by older
    /// builds keep decoding.
    pub fn from_json(e: &Json) -> Result<EpochMetrics> {
        let num = |k: &str| -> Result<f64> {
            e.get(k)
                .and_then(|n| n.as_f64())
                .ok_or_else(|| anyhow!("epoch record: missing '{k}'"))
        };
        let mut audit = Vec::new();
        if let Some(arr) = e.get("audit").and_then(|a| a.as_arr()) {
            for a in arr {
                audit.push(AuditLayerRecord::from_json(a)?);
            }
        }
        Ok(EpochMetrics {
            epoch: num("epoch")? as usize,
            train_loss: num("train_loss")? as f32,
            val_loss: num("val_loss")? as f32,
            val_acc: num("val_acc")? as f32,
            wstar_fro: num("wstar_fro")? as f32,
            mem_fro: num("mem_fro")? as f32,
            backward_flops: num("backward_flops")? as u64,
            // optional: absent from pre-exec persisted runs
            rows_per_sec: e.get("rows_per_sec").and_then(|n| n.as_f64()).unwrap_or(0.0),
            wall_s: num("wall_s")?,
            // optional (protocol v3): absent from pre-layer-graph runs;
            // the diagnostics inside each entry are optional too (v6)
            layers: e
                .get("layers")
                .and_then(|a| a.as_arr())
                .map(|arr| {
                    arr.iter()
                        .map(|l| {
                            let f = |k: &str| l.get(k).and_then(|n| n.as_f64()).unwrap_or(0.0);
                            LayerEpochMetrics {
                                k_effective: f("k_effective"),
                                backward_flops: f("backward_flops") as u64,
                                sel_jaccard: f("sel_jaccard"),
                                score_entropy: f("score_entropy"),
                                mem_fro: f("mem_fro") as f32,
                            }
                        })
                        .collect()
                })
                .unwrap_or_default(),
            audit,
        })
    }
}

/// A full training curve plus identification.
#[derive(Debug, Clone)]
pub struct RunCurve {
    /// Series label, e.g. `topk-mem` / `baseline`.
    pub label: String,
    /// Optimizer steps per epoch (0 = unknown, e.g. hand-built curves).
    /// Set by the experiment loop; lets metrics consumers (the serve
    /// subsystem's FLOP accounting) reconstruct total step counts.
    pub steps_per_epoch: usize,
    pub epochs: Vec<EpochMetrics>,
}

impl RunCurve {
    pub fn new(label: &str) -> Self {
        RunCurve {
            label: label.to_string(),
            steps_per_epoch: 0,
            epochs: Vec::new(),
        }
    }

    /// Total optimizer steps across the recorded epochs (0 if unknown).
    pub fn total_steps(&self) -> u64 {
        self.steps_per_epoch as u64 * self.epochs.len() as u64
    }

    pub fn push(&mut self, m: EpochMetrics) {
        self.epochs.push(m);
    }

    pub fn final_val_loss(&self) -> f32 {
        self.epochs.last().map(|m| m.val_loss).unwrap_or(f32::NAN)
    }

    pub fn final_val_acc(&self) -> f32 {
        self.epochs.last().map(|m| m.val_acc).unwrap_or(f32::NAN)
    }

    pub fn best_val_loss(&self) -> f32 {
        self.epochs
            .iter()
            .map(|m| m.val_loss)
            .fold(f32::INFINITY, f32::min)
    }

    /// Mean of the last `n` epochs' validation loss (smooths SGD noise
    /// when comparing series, as the paper's curves visually do).
    pub fn tail_mean_val_loss(&self, n: usize) -> f32 {
        let len = self.epochs.len();
        if len == 0 {
            return f32::NAN;
        }
        let take = n.min(len);
        self.epochs[len - take..]
            .iter()
            .map(|m| m.val_loss)
            .sum::<f32>()
            / take as f32
    }

    pub fn total_wall_s(&self) -> f64 {
        self.epochs.iter().map(|m| m.wall_s).sum()
    }

    pub fn total_backward_flops(&self) -> u64 {
        self.epochs.last().map(|m| m.backward_flops).unwrap_or(0)
    }

    /// Mean training-row throughput over epochs that recorded one
    /// (NaN for an empty/unknown curve).
    pub fn mean_rows_per_sec(&self) -> f64 {
        let known: Vec<f64> = self
            .epochs
            .iter()
            .map(|m| m.rows_per_sec)
            .filter(|&r| r > 0.0)
            .collect();
        if known.is_empty() {
            return f64::NAN;
        }
        known.iter().sum::<f64>() / known.len() as f64
    }

    /// Backward weight-gradient FLOP throughput: cumulative backward
    /// FLOPs over total wall time (0 when unknown).
    pub fn backward_flops_per_sec(&self) -> f64 {
        let wall = self.total_wall_s();
        if wall <= 0.0 {
            return 0.0;
        }
        self.total_backward_flops() as f64 / wall
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::s(&self.label)),
            ("steps_per_epoch", json::num(self.steps_per_epoch as f64)),
            ("epochs", Json::Arr(self.epochs.iter().map(|m| m.to_json()).collect())),
        ])
    }

    /// Inverse of [`RunCurve::to_json`] — used by the serve registry when
    /// reloading persisted runs and by protocol clients decoding results.
    /// Per-epoch frames delegate to [`EpochMetrics::from_json`] (the
    /// same decoder `watch` subscribers use on streamed epochs).
    pub fn from_json(v: &Json) -> Result<RunCurve> {
        let label = v
            .get("label")
            .and_then(|l| l.as_str())
            .ok_or_else(|| anyhow!("curve: missing label"))?
            .to_string();
        let steps_per_epoch = v
            .get("steps_per_epoch")
            .and_then(|n| n.as_usize())
            .unwrap_or(0);
        let mut epochs = Vec::new();
        for (i, e) in v
            .get("epochs")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("curve: missing epochs array"))?
            .iter()
            .enumerate()
        {
            epochs.push(
                EpochMetrics::from_json(e).map_err(|err| anyhow!("curve epoch {i}: {err}"))?,
            );
        }
        Ok(RunCurve {
            label,
            steps_per_epoch,
            epochs,
        })
    }
}

/// Write a set of curves as a wide CSV: one `epoch` column plus one
/// `val_loss` column per series — directly plottable as a paper figure
/// panel.
pub fn write_curves_csv(path: &Path, curves: &[RunCurve]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "epoch")?;
    for c in curves {
        write!(f, ",{}", c.label)?;
    }
    writeln!(f)?;
    let max_epochs = curves.iter().map(|c| c.epochs.len()).max().unwrap_or(0);
    for e in 0..max_epochs {
        write!(f, "{}", e + 1)?;
        for c in curves {
            match c.epochs.get(e) {
                Some(m) => write!(f, ",{}", m.val_loss)?,
                None => write!(f, ",")?,
            }
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Append one run's full record to a JSONL log.
pub fn append_jsonl(path: &Path, value: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", value.dump())
}

/// Console table helper: fixed-width row printing for the `table` /
/// `figure` subcommands.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(epoch: usize, val: f32) -> EpochMetrics {
        EpochMetrics {
            epoch,
            train_loss: val * 1.1,
            val_loss: val,
            val_acc: 0.5,
            wstar_fro: 1.0,
            mem_fro: 0.1,
            backward_flops: (epoch as u64) * 100,
            rows_per_sec: 1000.0,
            wall_s: 0.01,
            layers: vec![
                LayerEpochMetrics {
                    k_effective: 4.5,
                    backward_flops: (epoch as u64) * 60,
                    sel_jaccard: 0.75,
                    score_entropy: 1.25,
                    mem_fro: 0.08,
                },
                LayerEpochMetrics {
                    k_effective: 2.0,
                    backward_flops: (epoch as u64) * 40,
                    sel_jaccard: 0.5,
                    score_entropy: 0.0,
                    mem_fro: 0.06,
                },
            ],
            audit: Vec::new(),
        }
    }

    #[test]
    fn curve_summaries() {
        let mut c = RunCurve::new("topk-mem");
        for (e, v) in [(1, 3.0), (2, 2.0), (3, 2.5)] {
            c.push(m(e, v));
        }
        assert_eq!(c.final_val_loss(), 2.5);
        assert_eq!(c.best_val_loss(), 2.0);
        assert!((c.tail_mean_val_loss(2) - 2.25).abs() < 1e-6);
        assert_eq!(c.total_backward_flops(), 300);
        assert!((c.mean_rows_per_sec() - 1000.0).abs() < 1e-9);
        assert!((c.backward_flops_per_sec() - 300.0 / 0.03).abs() < 1e-6);
    }

    #[test]
    fn per_layer_metrics_roundtrip_and_are_optional() {
        let mut c = RunCurve::new("layered");
        c.push(m(1, 1.0));
        let r = RunCurve::from_json(&c.to_json()).unwrap();
        assert_eq!(r.epochs[0].layers.len(), 2);
        assert_eq!(r.epochs[0].layers[0].k_effective, 4.5);
        assert_eq!(r.epochs[0].layers[1].backward_flops, 40);
        // the v6 selection diagnostics ride along per layer
        assert_eq!(r.epochs[0].layers[0].sel_jaccard, 0.75);
        assert_eq!(r.epochs[0].layers[0].score_entropy, 1.25);
        assert_eq!(r.epochs[0].layers[1].mem_fro, 0.06);
        // v3-v5 layer entries (no diagnostics keys) decode to zeros
        let mut j5 = c.to_json();
        if let Json::Obj(pairs) = &mut j5 {
            for (k, v) in pairs.iter_mut() {
                if k == "epochs" {
                    if let Json::Arr(arr) = v {
                        for e in arr.iter_mut() {
                            if let Json::Obj(ep) = e {
                                for (ek, ev) in ep.iter_mut() {
                                    if ek == "layers" {
                                        if let Json::Arr(ls) = ev {
                                            for l in ls.iter_mut() {
                                                if let Json::Obj(lp) = l {
                                                    lp.retain(|(k, _)| {
                                                        k == "k_effective" || k == "backward_flops"
                                                    });
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        let v5 = RunCurve::from_json(&j5).unwrap();
        assert_eq!(v5.epochs[0].layers[0].k_effective, 4.5);
        assert_eq!(v5.epochs[0].layers[0].sel_jaccard, 0.0);
        assert_eq!(v5.epochs[0].layers[0].mem_fro, 0.0);
        // pre-layer-graph records (no `layers` key) decode to empty
        let mut j = c.to_json();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "epochs" {
                    if let Json::Arr(arr) = v {
                        for e in arr.iter_mut() {
                            if let Json::Obj(ep) = e {
                                ep.retain(|(k, _)| k != "layers");
                            }
                        }
                    }
                }
            }
        }
        let old = RunCurve::from_json(&j).unwrap();
        assert!(old.epochs[0].layers.is_empty());
    }

    #[test]
    fn rows_per_sec_is_optional_in_json() {
        // curves persisted before the exec subsystem lack the field
        let mut c = RunCurve::new("old");
        c.push(m(1, 1.0));
        let mut j = c.to_json();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "epochs" {
                    if let Json::Arr(arr) = v {
                        for e in arr.iter_mut() {
                            if let Json::Obj(ep) = e {
                                ep.retain(|(k, _)| k != "rows_per_sec");
                            }
                        }
                    }
                }
            }
        }
        let r = RunCurve::from_json(&j).unwrap();
        assert_eq!(r.epochs[0].rows_per_sec, 0.0);
        assert!(r.mean_rows_per_sec().is_nan());
    }

    #[test]
    fn audit_records_roundtrip_and_are_omitted_when_empty() {
        use crate::obs::AuditLayerRecord;
        let mut c = RunCurve::new("audited");
        let mut e1 = m(1, 2.0);
        use crate::tensor::quant::TraceMode;
        e1.audit = vec![
            AuditLayerRecord {
                layer: 0,
                cosine: 0.98,
                rel_err: 0.12,
                mem_bias: 0.04,
                trace: TraceMode::F32,
            },
            AuditLayerRecord {
                layer: 1,
                cosine: 0.95,
                rel_err: 0.2,
                mem_bias: 0.0,
                trace: TraceMode::Bf16,
            },
        ];
        c.push(e1);
        c.push(m(2, 1.5)); // un-audited epoch
        let j = c.to_json();
        let eps = j.get("epochs").and_then(|a| a.as_arr()).unwrap();
        assert!(eps[0].get("audit").is_some());
        assert!(eps[1].get("audit").is_none(), "empty audit must not emit a key");
        let r = RunCurve::from_json(&j).unwrap();
        assert_eq!(r.epochs[0].audit.len(), 2);
        assert_eq!(r.epochs[0].audit[1].layer, 1);
        assert_eq!(r.epochs[0].audit[0].cosine, 0.98);
        assert!(r.epochs[1].audit.is_empty());
        assert_eq!(r.epochs[0], c.epochs[0]);
    }

    #[test]
    fn empty_curve_is_nan() {
        let c = RunCurve::new("x");
        assert!(c.final_val_loss().is_nan());
        assert!(c.tail_mean_val_loss(5).is_nan());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("memaop_csv_{}", std::process::id()));
        let path = dir.join("curves.csv");
        let mut a = RunCurve::new("baseline");
        let mut b = RunCurve::new("topk");
        a.push(m(1, 1.0));
        a.push(m(2, 0.5));
        b.push(m(1, 1.2));
        write_curves_csv(&path, &[a, b]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "epoch,baseline,topk");
        assert!(lines[1].starts_with("1,1,1.2"));
        assert_eq!(lines[2], "2,0.5,");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn curve_json_roundtrip() {
        let mut c = RunCurve::new("topk-mem");
        c.steps_per_epoch = 4;
        for (e, v) in [(1, 3.0), (2, 2.0)] {
            c.push(m(e, v));
        }
        let r = RunCurve::from_json(&c.to_json()).unwrap();
        assert_eq!(r.label, c.label);
        assert_eq!(r.steps_per_epoch, 4);
        assert_eq!(r.total_steps(), 8);
        assert_eq!(r.epochs.len(), 2);
        assert_eq!(r.epochs[1].val_loss, c.epochs[1].val_loss);
        assert_eq!(r.epochs[1].backward_flops, c.epochs[1].backward_flops);
        // malformed input rejected
        assert!(RunCurve::from_json(&crate::util::json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn jsonl_appends() {
        let dir = std::env::temp_dir().join(format!("memaop_jsonl_{}", std::process::id()));
        let path = dir.join("runs.jsonl");
        let mut c = RunCurve::new("x");
        c.push(m(1, 2.0));
        append_jsonl(&path, &c.to_json()).unwrap();
        append_jsonl(&path, &c.to_json()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let parsed = crate::util::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str().unwrap(), "x");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
