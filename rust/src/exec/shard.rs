//! Per-shard row-range kernels and the disjoint row-block splitter.
//!
//! Every helper here operates on a contiguous row range of a row-major
//! matrix, reading shared inputs and writing into a borrowed output block
//! — the building blocks the training core assembles into sharded
//! `fwd_score`/`apply` phases. Each kernel performs exactly the same
//! per-element floating-point operations as its whole-matrix twin in
//! `tensor::ops` (and follows the same 8-lane split-loop contract — see
//! the `tensor::ops` module docs), so a shard's rows are bit-identical to
//! the rows the serial kernel would have produced (asserted by the tests
//! below).

use std::marker::PhantomData;
use std::ops::Range;

use crate::exec::plan::ShardPlan;
use crate::tensor::{ops, Matrix};

/// Disjoint per-shard mutable views over one output buffer, indexable by
/// shard id from concurrent shard tasks.
///
/// Allocation-free (§Perf pass): the splitter is a stride computation
/// over a raw pointer, not a `Vec<Mutex<&mut [f32]>>` — constructing one
/// per dispatch must not allocate, because a steady-state training step
/// constructs a dozen of them. The price is that handing out `&mut`
/// blocks through a shared `&self` is now an `unsafe fn` with a caller
/// contract instead of a compiler-checked `chunks_mut`:
///
/// > **Safety contract of [`RowBlocks::block`]** — for a given `i`, at
/// > most one returned block may be live at a time. The intended caller
/// > is a shard closure under `Executor::run_each`/`map`, whose dispatch
/// > contract (`exec::pool`) claims every shard index exactly once per
/// > dispatch — each closure invocation touches only its own `i`, so
/// > blocks are never aliased. (Sequential test loops that take one
/// > block at a time satisfy the contract trivially.)
pub struct RowBlocks<'a> {
    ptr: *mut f32,
    len: usize,
    /// f32s per block (`granularity * per_row`); the last block may be
    /// short.
    stride: usize,
    n_blocks: usize,
    _borrow: PhantomData<&'a mut [f32]>,
}

// SAFETY: RowBlocks hands out disjoint sub-slices of one exclusively
// borrowed buffer (see the `block` contract above); the pointer itself
// carries no thread affinity.
unsafe impl Send for RowBlocks<'_> {}
unsafe impl Sync for RowBlocks<'_> {}

impl<'a> RowBlocks<'a> {
    /// Split a matrix into the plan's row blocks (block `i` holds rows
    /// `plan.range(i)`).
    pub fn of(m: &'a mut Matrix, plan: &ShardPlan) -> RowBlocks<'a> {
        let cols = m.cols();
        assert_eq!(m.rows(), plan.rows(), "matrix rows vs plan rows");
        RowBlocks::of_slice(m.data_mut(), cols, plan)
    }

    /// Split a flat row-major buffer with `per_row` entries per row.
    pub fn of_slice(v: &'a mut [f32], per_row: usize, plan: &ShardPlan) -> RowBlocks<'a> {
        assert!(per_row > 0, "per_row must be positive");
        assert_eq!(v.len(), plan.rows() * per_row, "buffer vs plan size");
        RowBlocks {
            ptr: v.as_mut_ptr(),
            len: v.len(),
            stride: plan.granularity() * per_row,
            n_blocks: plan.len(),
            _borrow: PhantomData,
        }
    }

    /// Exclusive access to shard `i`'s block.
    ///
    /// # Safety
    ///
    /// At most one live block per index `i` (see the type-level
    /// contract). Distinct indices are disjoint by construction, so
    /// concurrent access to *different* indices is always sound.
    #[allow(clippy::mut_from_ref)] // &mut from & is the point: disjoint blocks behind one borrow
    pub unsafe fn block(&self, i: usize) -> &'a mut [f32] {
        assert!(i < self.n_blocks, "block {i} out of {}", self.n_blocks);
        let start = i * self.stride;
        let end = (start + self.stride).min(self.len);
        // SAFETY: `start..end` is in-bounds and disjoint from every other
        // index's range; the caller guarantees `i` is not aliased and the
        // PhantomData borrow keeps the underlying buffer alive and
        // exclusively reserved for this splitter.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }

    pub fn len(&self) -> usize {
        self.n_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.n_blocks == 0
    }
}

/// The contiguous row-major block of `rows` of a matrix.
pub fn rows_of(m: &Matrix, rows: Range<usize>) -> &[f32] {
    let cols = m.cols();
    &m.data()[rows.start * cols..rows.end * cols]
}

/// Forward rows: `out[r] = x[r] @ w + b` for `r` in `rows` (`out` is the
/// `rows.len() × w.cols()` block). Same math as
/// `x.matmul(w).add_row_broadcast(b)` restricted to the range.
///
/// Narrow-B shapes transpose `w` on every call; per-step hot paths use
/// [`forward_rows_bt`] with the layer's cached transpose instead.
pub fn forward_rows(x: &Matrix, w: &Matrix, b: &[f32], rows: Range<usize>, out: &mut [f32]) {
    ops::matmul_rows(x, w, rows, out);
    add_bias_rows(b, w.cols(), out);
}

/// [`forward_rows`] with a caller-cached `w_t = w.transpose()` — bitwise
/// identical, but the narrow-B path reads the cache instead of
/// transposing per shard per step.
pub fn forward_rows_bt(
    x: &Matrix,
    w: &Matrix,
    w_t: &Matrix,
    b: &[f32],
    rows: Range<usize>,
    out: &mut [f32],
) {
    ops::matmul_rows_bt(x, w, w_t, rows, out);
    add_bias_rows(b, w.cols(), out);
}

/// Broadcast bias add over a `rows × p` block, 8-lane body per row.
#[inline]
fn add_bias_rows(b: &[f32], p: usize, out: &mut [f32]) {
    assert_eq!(b.len(), p);
    for orow in out.chunks_exact_mut(p) {
        for (v, &bias) in orow.iter_mut().zip(b.iter()) {
            *v += bias;
        }
    }
}

/// Memory folding (alg. lines 3-4) for a row range:
/// `out[r] = scale * src[r] + mem[r]` — the per-element op order matches
/// `src.scale(scale)` + `axpy(1.0, mem)`.
pub fn fold_rows(src: &Matrix, mem: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    fold_block(rows_of(src, rows.clone()), mem, scale, rows, out);
}

/// [`fold_rows`] where the fresh term is already a shard-local block
/// (e.g. the just-computed loss-gradient rows). 8-lane split + tail —
/// elementwise, so the split changes no bits.
pub fn fold_block(
    src_block: &[f32],
    mem: &Matrix,
    scale: f32,
    rows: Range<usize>,
    out: &mut [f32],
) {
    let mem_block = rows_of(mem, rows);
    assert_eq!(src_block.len(), out.len());
    assert_eq!(mem_block.len(), out.len());
    let split = out.len() - out.len() % ops::LANES;
    let (o8, o_tail) = out.split_at_mut(split);
    let (s8, s_tail) = src_block.split_at(split);
    let (m8, m_tail) = mem_block.split_at(split);
    for ((oc, sc), mc) in o8
        .chunks_exact_mut(ops::LANES)
        .zip(s8.chunks_exact(ops::LANES))
        .zip(m8.chunks_exact(ops::LANES))
    {
        for l in 0..ops::LANES {
            oc[l] = scale * sc[l] + mc[l];
        }
    }
    for ((o, &s), &m) in o_tail.iter_mut().zip(s_tail.iter()).zip(m_tail.iter()) {
        *o = scale * s + m;
    }
}

/// Memory-off folding for a row range: `out[r] = scale * src[r]` — the
/// [`fold_rows`] special case with no memory term, so disabled memories
/// fold without ever allocating (or reading) zero matrices.
pub fn scale_rows(src: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    let block = rows_of(src, rows);
    assert_eq!(block.len(), out.len());
    let split = out.len() - out.len() % ops::LANES;
    let (o8, o_tail) = out.split_at_mut(split);
    let (s8, s_tail) = block.split_at(split);
    for (oc, sc) in o8
        .chunks_exact_mut(ops::LANES)
        .zip(s8.chunks_exact(ops::LANES))
    {
        for l in 0..ops::LANES {
            oc[l] = scale * sc[l];
        }
    }
    for (o, &s) in o_tail.iter_mut().zip(s_tail.iter()) {
        *o = scale * s;
    }
}

/// Policy scores for a shard: `out[r] = ||xhat[r]|| * ||ghat[r]||` over
/// the block-local rows (`xhat` is `rows × n`, `ghat` is `rows × p`).
/// Same per-row ops as `ops::norm_product_scores` (8-lane dot).
pub fn score_rows(xhat: &[f32], ghat: &[f32], n: usize, p: usize, out: &mut [f32]) {
    let rows = out.len();
    assert_eq!(xhat.len(), rows * n);
    assert_eq!(ghat.len(), rows * p);
    for ((o, xr), gr) in out
        .iter_mut()
        .zip(xhat.chunks_exact(n))
        .zip(ghat.chunks_exact(p))
    {
        *o = ops::dot(xr, xr).sqrt() * ops::dot(gr, gr).sqrt();
    }
}

/// Column sums of a shard-local block (`rows × cols`), accumulated in
/// row order — the shard partial of `Matrix::col_sums`. Allocating
/// wrapper over [`col_sums_rows_into`].
pub fn col_sums_rows(block: &[f32], cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols];
    col_sums_rows_into(block, cols, &mut out);
    out
}

/// [`col_sums_rows`] into a caller-owned buffer (zeroed first) — the
/// workspace path. Per-column accumulation order is identical, so the
/// result is bitwise the same.
pub fn col_sums_rows_into(block: &[f32], cols: usize, out: &mut [f32]) {
    assert!(cols > 0 && block.len() % cols == 0);
    assert_eq!(out.len(), cols);
    out.fill(0.0);
    for row in block.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
}

/// Memory retention (alg. lines 8-9) for a row range:
/// `out[r] = keep[r] * src[r]` — the shard twin of `ops::row_scale`.
pub fn keep_rows(src: &Matrix, keep: &[f32], rows: Range<usize>, out: &mut [f32]) {
    let cols = src.cols();
    assert_eq!(out.len(), rows.len() * cols);
    for (local, r) in rows.enumerate() {
        let k = keep[r];
        let orow = &mut out[local * cols..(local + 1) * cols];
        for (o, &s) in orow.iter_mut().zip(src.row(r).iter()) {
            *o = s * k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn row_blocks_are_disjoint_and_cover() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut m = Matrix::from_fn(10, 3, |r, c| (r * 3 + c) as f32);
        let blocks = RowBlocks::of(&mut m, &plan);
        assert_eq!(blocks.len(), 3);
        // SAFETY: one block live at a time (sequential loop)
        unsafe {
            assert_eq!(blocks.block(0).len(), 12);
            assert_eq!(blocks.block(2).len(), 6); // short tail block
            // write through every block, then check the matrix saw it all
            for i in 0..blocks.len() {
                for v in blocks.block(i).iter_mut() {
                    *v += 100.0;
                }
            }
        }
        drop(blocks);
        assert!(m.data().iter().all(|&v| v >= 100.0));
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn row_blocks_reject_out_of_range_index() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut m = Matrix::zeros(10, 3);
        let blocks = RowBlocks::of(&mut m, &plan);
        // SAFETY: single access
        unsafe {
            blocks.block(3);
        }
    }

    #[test]
    fn forward_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(0);
        for (m, n, p) in [(20, 8, 3), (64, 784, 10), (7, 40, 2)] {
            let x = randm(&mut rng, m, n);
            let w = randm(&mut rng, n, p);
            let wt = w.transpose();
            let b: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
            let serial = x.matmul(&w).add_row_broadcast(&b);
            let plan = ShardPlan::with_granularity(m, 6);
            let mut out = Matrix::zeros(m, p);
            let mut out_bt = Matrix::zeros(m, p);
            for (i, range) in plan.iter().enumerate() {
                let blocks = RowBlocks::of(&mut out, &plan);
                // SAFETY: one block live at a time
                let blk = unsafe { blocks.block(i) };
                forward_rows(&x, &w, &b, range.clone(), blk);
                let blocks_bt = RowBlocks::of(&mut out_bt, &plan);
                // SAFETY: one block live at a time
                let blk_bt = unsafe { blocks_bt.block(i) };
                forward_rows_bt(&x, &w, &wt, &b, range, blk_bt);
            }
            assert_eq!(out.data(), serial.data(), "({m},{n},{p})");
            assert_eq!(out_bt.data(), serial.data(), "({m},{n},{p}) cached wt");
        }
    }

    #[test]
    fn fold_rows_matches_memory_fold_bitwise() {
        use crate::aop::memory::MemoryState;
        let mut rng = Rng::new(1);
        let (m, n, p) = (18, 5, 2);
        let mut ms = MemoryState::new(m, n, p, true);
        ms.mem_x = randm(&mut rng, m, n);
        ms.mem_g = randm(&mut rng, m, p);
        let x = randm(&mut rng, m, n);
        let g = randm(&mut rng, m, p);
        let eta = 0.04f32;
        let (xhat, ghat) = ms.fold(&x, &g, eta);
        let se = eta.sqrt();
        let plan = ShardPlan::with_granularity(m, 7);
        let mut xh = Matrix::zeros(m, n);
        let mut gh = Matrix::zeros(m, p);
        for (i, range) in plan.iter().enumerate() {
            let xb = RowBlocks::of(&mut xh, &plan);
            // SAFETY: one block live at a time
            fold_rows(&x, &ms.mem_x, se, range.clone(), unsafe { xb.block(i) });
            let gb = RowBlocks::of(&mut gh, &plan);
            // SAFETY: one block live at a time
            fold_block(rows_of(&g, range.clone()), &ms.mem_g, se, range, unsafe {
                gb.block(i)
            });
        }
        assert_eq!(xh.data(), xhat.data());
        assert_eq!(gh.data(), ghat.data());
    }

    #[test]
    fn scale_rows_matches_scale_bitwise() {
        let mut rng = Rng::new(9);
        let src = randm(&mut rng, 14, 5);
        let serial = src.scale(0.3);
        let plan = ShardPlan::with_granularity(14, 6);
        let mut out = Matrix::zeros(14, 5);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            // SAFETY: one block live at a time
            scale_rows(&src, 0.3, range, unsafe { blocks.block(i) });
        }
        assert_eq!(out.data(), serial.data());
    }

    #[test]
    fn score_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(2);
        let (m, n, p) = (23, 9, 4);
        let xhat = randm(&mut rng, m, n);
        let ghat = randm(&mut rng, m, p);
        let serial = ops::norm_product_scores(&xhat, &ghat);
        let plan = ShardPlan::with_granularity(m, 5);
        let mut scores = vec![0.0f32; m];
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of_slice(&mut scores, 1, &plan);
            // SAFETY: one block live at a time
            let blk = unsafe { blocks.block(i) };
            score_rows(
                rows_of(&xhat, range.clone()),
                rows_of(&ghat, range.clone()),
                n,
                p,
                blk,
            );
        }
        assert_eq!(scores, serial);
    }

    #[test]
    fn col_sums_partials_cover_col_sums() {
        let mut rng = Rng::new(3);
        let g = randm(&mut rng, 16, 3);
        // single full-range partial == serial col_sums exactly
        let full = col_sums_rows(rows_of(&g, 0..16), 3);
        assert_eq!(full, g.col_sums());
        // the _into form is bitwise the same (and zeroes stale contents)
        let mut buf = vec![f32::NAN; 3];
        col_sums_rows_into(rows_of(&g, 0..16), 3, &mut buf);
        assert_eq!(buf, full);
        // split partials sum to the same within f32 grouping tolerance
        let a = col_sums_rows(rows_of(&g, 0..9), 3);
        let b = col_sums_rows(rows_of(&g, 9..16), 3);
        for c in 0..3 {
            assert!((a[c] + b[c] - full[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn keep_rows_matches_row_scale_bitwise() {
        let mut rng = Rng::new(4);
        let src = randm(&mut rng, 12, 6);
        let keep: Vec<f32> = (0..12).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let serial = ops::row_scale(&src, &keep);
        let plan = ShardPlan::with_granularity(12, 5);
        let mut out = Matrix::zeros(12, 6);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            // SAFETY: one block live at a time
            keep_rows(&src, &keep, range, unsafe { blocks.block(i) });
        }
        assert_eq!(out.data(), serial.data());
    }
}
