//! Per-shard row-range kernels and the disjoint row-block splitter.
//!
//! Every helper here operates on a contiguous row range of a row-major
//! matrix, reading shared inputs and writing into a borrowed output block
//! — the building blocks `AopEngine`/`Mlp` assemble into sharded
//! `fwd_score`/`apply` phases. Each kernel performs exactly the same
//! per-element floating-point operations as its whole-matrix twin in
//! `tensor::ops`, so a shard's rows are bit-identical to the rows the
//! serial kernel would have produced (asserted by the tests below).

use std::ops::Range;
use std::sync::{Mutex, MutexGuard};

use crate::exec::plan::ShardPlan;
use crate::tensor::{ops, Matrix};

/// Disjoint per-shard mutable views over one output buffer, indexable by
/// shard id from concurrent shard tasks. Built on `chunks_mut`, so the
/// disjointness is checked by the compiler, not by `unsafe`.
pub struct RowBlocks<'a> {
    blocks: Vec<Mutex<&'a mut [f32]>>,
}

impl<'a> RowBlocks<'a> {
    /// Split a matrix into the plan's row blocks (block `i` holds rows
    /// `plan.range(i)`).
    pub fn of(m: &'a mut Matrix, plan: &ShardPlan) -> RowBlocks<'a> {
        let cols = m.cols();
        assert_eq!(m.rows(), plan.rows(), "matrix rows vs plan rows");
        RowBlocks::of_slice(m.data_mut(), cols, plan)
    }

    /// Split a flat row-major buffer with `per_row` entries per row.
    pub fn of_slice(v: &'a mut [f32], per_row: usize, plan: &ShardPlan) -> RowBlocks<'a> {
        assert!(per_row > 0, "per_row must be positive");
        assert_eq!(v.len(), plan.rows() * per_row, "buffer vs plan size");
        let blocks = v
            .chunks_mut(plan.granularity() * per_row)
            .map(Mutex::new)
            .collect();
        RowBlocks { blocks }
    }

    /// Exclusive access to shard `i`'s block. Uncontended by design —
    /// each shard task locks only its own index, the `Mutex` exists to
    /// hand `&mut` access through a shared `&self`.
    pub fn lock(&self, i: usize) -> MutexGuard<'_, &'a mut [f32]> {
        self.blocks[i].lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }
}

/// The contiguous row-major block of `rows` of a matrix.
pub fn rows_of(m: &Matrix, rows: Range<usize>) -> &[f32] {
    let cols = m.cols();
    &m.data()[rows.start * cols..rows.end * cols]
}

/// Forward rows: `out[r] = x[r] @ w + b` for `r` in `rows` (`out` is the
/// `rows.len() × w.cols()` block). Same math as
/// `x.matmul(w).add_row_broadcast(b)` restricted to the range.
pub fn forward_rows(x: &Matrix, w: &Matrix, b: &[f32], rows: Range<usize>, out: &mut [f32]) {
    let p = w.cols();
    assert_eq!(b.len(), p);
    ops::matmul_rows(x, w, rows, out);
    for orow in out.chunks_exact_mut(p) {
        for (v, &bias) in orow.iter_mut().zip(b.iter()) {
            *v += bias;
        }
    }
}

/// Memory folding (alg. lines 3-4) for a row range:
/// `out[r] = scale * src[r] + mem[r]` — the per-element op order matches
/// `src.scale(scale)` + `axpy(1.0, mem)`.
pub fn fold_rows(src: &Matrix, mem: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    fold_block(rows_of(src, rows.clone()), mem, scale, rows, out);
}

/// [`fold_rows`] where the fresh term is already a shard-local block
/// (e.g. the just-computed loss-gradient rows).
pub fn fold_block(
    src_block: &[f32],
    mem: &Matrix,
    scale: f32,
    rows: Range<usize>,
    out: &mut [f32],
) {
    let mem_block = rows_of(mem, rows);
    assert_eq!(src_block.len(), out.len());
    assert_eq!(mem_block.len(), out.len());
    for ((o, &s), &m) in out.iter_mut().zip(src_block.iter()).zip(mem_block.iter()) {
        *o = scale * s + m;
    }
}

/// Memory-off folding for a row range: `out[r] = scale * src[r]` — the
/// [`fold_rows`] special case with no memory term, so disabled memories
/// fold without ever allocating (or reading) zero matrices.
pub fn scale_rows(src: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    let block = rows_of(src, rows);
    assert_eq!(block.len(), out.len());
    for (o, &s) in out.iter_mut().zip(block.iter()) {
        *o = scale * s;
    }
}

/// Policy scores for a shard: `out[r] = ||xhat[r]|| * ||ghat[r]||` over
/// the block-local rows (`xhat` is `rows × n`, `ghat` is `rows × p`).
/// Same per-row ops as `ops::norm_product_scores`.
pub fn score_rows(xhat: &[f32], ghat: &[f32], n: usize, p: usize, out: &mut [f32]) {
    let rows = out.len();
    assert_eq!(xhat.len(), rows * n);
    assert_eq!(ghat.len(), rows * p);
    for ((o, xr), gr) in out
        .iter_mut()
        .zip(xhat.chunks_exact(n))
        .zip(ghat.chunks_exact(p))
    {
        *o = ops::dot(xr, xr).sqrt() * ops::dot(gr, gr).sqrt();
    }
}

/// Column sums of a shard-local block (`rows × cols`), accumulated in
/// row order — the shard partial of `Matrix::col_sums`.
pub fn col_sums_rows(block: &[f32], cols: usize) -> Vec<f32> {
    assert!(cols > 0 && block.len() % cols == 0);
    let mut out = vec![0.0f32; cols];
    for row in block.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
    out
}

/// Memory retention (alg. lines 8-9) for a row range:
/// `out[r] = keep[r] * src[r]` — the shard twin of `ops::row_scale`.
pub fn keep_rows(src: &Matrix, keep: &[f32], rows: Range<usize>, out: &mut [f32]) {
    let cols = src.cols();
    assert_eq!(out.len(), rows.len() * cols);
    for (local, r) in rows.enumerate() {
        let k = keep[r];
        let orow = &mut out[local * cols..(local + 1) * cols];
        for (o, &s) in orow.iter_mut().zip(src.row(r).iter()) {
            *o = s * k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn row_blocks_are_disjoint_and_cover() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut m = Matrix::from_fn(10, 3, |r, c| (r * 3 + c) as f32);
        let blocks = RowBlocks::of(&mut m, &plan);
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks.lock(0).len(), 12);
        assert_eq!(blocks.lock(2).len(), 6); // short tail block
        // write through every block, then check the matrix saw it all
        for i in 0..blocks.len() {
            for v in blocks.lock(i).iter_mut() {
                *v += 100.0;
            }
        }
        drop(blocks);
        assert!(m.data().iter().all(|&v| v >= 100.0));
    }

    #[test]
    fn forward_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(0);
        for (m, n, p) in [(20, 8, 3), (64, 784, 10), (7, 40, 2)] {
            let x = randm(&mut rng, m, n);
            let w = randm(&mut rng, n, p);
            let b: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
            let serial = x.matmul(&w).add_row_broadcast(&b);
            let plan = ShardPlan::with_granularity(m, 6);
            let mut out = Matrix::zeros(m, p);
            for (i, range) in plan.iter().enumerate() {
                let blocks = RowBlocks::of(&mut out, &plan);
                let mut blk = blocks.lock(i);
                forward_rows(&x, &w, &b, range, &mut blk);
            }
            assert_eq!(out.data(), serial.data(), "({m},{n},{p})");
        }
    }

    #[test]
    fn fold_rows_matches_memory_fold_bitwise() {
        use crate::aop::memory::MemoryState;
        let mut rng = Rng::new(1);
        let (m, n, p) = (18, 5, 2);
        let mut ms = MemoryState::new(m, n, p, true);
        ms.mem_x = randm(&mut rng, m, n);
        ms.mem_g = randm(&mut rng, m, p);
        let x = randm(&mut rng, m, n);
        let g = randm(&mut rng, m, p);
        let eta = 0.04f32;
        let (xhat, ghat) = ms.fold(&x, &g, eta);
        let se = eta.sqrt();
        let plan = ShardPlan::with_granularity(m, 7);
        let mut xh = Matrix::zeros(m, n);
        let mut gh = Matrix::zeros(m, p);
        for (i, range) in plan.iter().enumerate() {
            let xb = RowBlocks::of(&mut xh, &plan);
            fold_rows(&x, &ms.mem_x, se, range.clone(), &mut xb.lock(i));
            let gb = RowBlocks::of(&mut gh, &plan);
            fold_block(rows_of(&g, range.clone()), &ms.mem_g, se, range, &mut gb.lock(i));
        }
        assert_eq!(xh.data(), xhat.data());
        assert_eq!(gh.data(), ghat.data());
    }

    #[test]
    fn scale_rows_matches_scale_bitwise() {
        let mut rng = Rng::new(9);
        let src = randm(&mut rng, 14, 5);
        let serial = src.scale(0.3);
        let plan = ShardPlan::with_granularity(14, 6);
        let mut out = Matrix::zeros(14, 5);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            scale_rows(&src, 0.3, range, &mut blocks.lock(i));
        }
        assert_eq!(out.data(), serial.data());
    }

    #[test]
    fn score_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(2);
        let (m, n, p) = (23, 9, 4);
        let xhat = randm(&mut rng, m, n);
        let ghat = randm(&mut rng, m, p);
        let serial = ops::norm_product_scores(&xhat, &ghat);
        let plan = ShardPlan::with_granularity(m, 5);
        let mut scores = vec![0.0f32; m];
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of_slice(&mut scores, 1, &plan);
            let mut blk = blocks.lock(i);
            score_rows(
                rows_of(&xhat, range.clone()),
                rows_of(&ghat, range.clone()),
                n,
                p,
                &mut blk,
            );
        }
        assert_eq!(scores, serial);
    }

    #[test]
    fn col_sums_partials_cover_col_sums() {
        let mut rng = Rng::new(3);
        let g = randm(&mut rng, 16, 3);
        // single full-range partial == serial col_sums exactly
        let full = col_sums_rows(rows_of(&g, 0..16), 3);
        assert_eq!(full, g.col_sums());
        // split partials sum to the same within f32 grouping tolerance
        let a = col_sums_rows(rows_of(&g, 0..9), 3);
        let b = col_sums_rows(rows_of(&g, 9..16), 3);
        for c in 0..3 {
            assert!((a[c] + b[c] - full[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn keep_rows_matches_row_scale_bitwise() {
        let mut rng = Rng::new(4);
        let src = randm(&mut rng, 12, 6);
        let keep: Vec<f32> = (0..12).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let serial = ops::row_scale(&src, &keep);
        let plan = ShardPlan::with_granularity(12, 5);
        let mut out = Matrix::zeros(12, 6);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            keep_rows(&src, &keep, range, &mut blocks.lock(i));
        }
        assert_eq!(out.data(), serial.data());
    }
}
