//! Per-shard row-range kernels and the disjoint row-block splitter.
//!
//! Every helper here operates on a contiguous row range of a row-major
//! matrix, reading shared inputs and writing into a borrowed output block
//! — the building blocks the training core assembles into sharded
//! `fwd_score`/`apply` phases. Each kernel performs exactly the same
//! per-element floating-point operations as its whole-matrix twin in
//! `tensor::ops` (and follows the same 8-lane split-loop contract — see
//! the `tensor::ops` module docs), so a shard's rows are bit-identical to
//! the rows the serial kernel would have produced (asserted by the tests
//! below).

use std::marker::PhantomData;
use std::ops::Range;

use crate::exec::plan::ShardPlan;
use crate::tensor::quant::{self, AccumMode, TraceRef};
use crate::tensor::{ops, Matrix};

/// Disjoint per-shard mutable views over one output buffer, indexable by
/// shard id from concurrent shard tasks.
///
/// Allocation-free (§Perf pass): the splitter is a stride computation
/// over a raw pointer, not a `Vec<Mutex<&mut [f32]>>` — constructing one
/// per dispatch must not allocate, because a steady-state training step
/// constructs a dozen of them. The price is that handing out `&mut`
/// blocks through a shared `&self` is now an `unsafe fn` with a caller
/// contract instead of a compiler-checked `chunks_mut`:
///
/// > **Safety contract of [`RowBlocks::block`]** — for a given `i`, at
/// > most one returned block may be live at a time. The intended caller
/// > is a shard closure under `Executor::run_each`/`map`, whose dispatch
/// > contract (`exec::pool`) claims every shard index exactly once per
/// > dispatch — each closure invocation touches only its own `i`, so
/// > blocks are never aliased. (Sequential test loops that take one
/// > block at a time satisfy the contract trivially.)
/// Generic over the element type (`f32` by default): the quantized
/// forward traces shard-encode into `u16`/`i8` code buffers through the
/// same claim-once splitter.
pub struct RowBlocks<'a, T = f32> {
    ptr: *mut T,
    len: usize,
    /// elements per block (`granularity * per_row`); the last block may
    /// be short.
    stride: usize,
    n_blocks: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: RowBlocks hands out disjoint sub-slices of one exclusively
// borrowed buffer (see the `block` contract above); the pointer itself
// carries no thread affinity. `T: Send` because blocks (`&mut [T]`)
// cross into worker threads.
unsafe impl<T: Send> Send for RowBlocks<'_, T> {}
unsafe impl<T: Send> Sync for RowBlocks<'_, T> {}

impl<'a> RowBlocks<'a> {
    /// Split a matrix into the plan's row blocks (block `i` holds rows
    /// `plan.range(i)`).
    pub fn of(m: &'a mut Matrix, plan: &ShardPlan) -> RowBlocks<'a> {
        let cols = m.cols();
        assert_eq!(m.rows(), plan.rows(), "matrix rows vs plan rows");
        RowBlocks::of_slice(m.data_mut(), cols, plan)
    }
}

impl<'a, T> RowBlocks<'a, T> {
    /// Split a flat row-major buffer with `per_row` entries per row.
    pub fn of_slice(v: &'a mut [T], per_row: usize, plan: &ShardPlan) -> RowBlocks<'a, T> {
        assert!(per_row > 0, "per_row must be positive");
        assert_eq!(v.len(), plan.rows() * per_row, "buffer vs plan size");
        RowBlocks {
            ptr: v.as_mut_ptr(),
            len: v.len(),
            stride: plan.granularity() * per_row,
            n_blocks: plan.len(),
            _borrow: PhantomData,
        }
    }

    /// Exclusive access to shard `i`'s block.
    ///
    /// # Safety
    ///
    /// At most one live block per index `i` (see the type-level
    /// contract). Distinct indices are disjoint by construction, so
    /// concurrent access to *different* indices is always sound.
    #[allow(clippy::mut_from_ref)] // &mut from & is the point: disjoint blocks behind one borrow
    pub unsafe fn block(&self, i: usize) -> &'a mut [T] {
        assert!(i < self.n_blocks, "block {i} out of {}", self.n_blocks);
        let start = i * self.stride;
        let end = (start + self.stride).min(self.len);
        // SAFETY: `start..end` is in-bounds and disjoint from every other
        // index's range; the caller guarantees `i` is not aliased and the
        // PhantomData borrow keeps the underlying buffer alive and
        // exclusively reserved for this splitter.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }

    pub fn len(&self) -> usize {
        self.n_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.n_blocks == 0
    }
}

/// The contiguous row-major block of `rows` of a matrix.
pub fn rows_of(m: &Matrix, rows: Range<usize>) -> &[f32] {
    let cols = m.cols();
    &m.data()[rows.start * cols..rows.end * cols]
}

/// Forward rows: `out[r] = x[r] @ w + b` for `r` in `rows` (`out` is the
/// `rows.len() × w.cols()` block). Same math as
/// `x.matmul(w).add_row_broadcast(b)` restricted to the range.
///
/// Narrow-B shapes transpose `w` on every call; per-step hot paths use
/// [`forward_rows_bt`] with the layer's cached transpose instead.
pub fn forward_rows(x: &Matrix, w: &Matrix, b: &[f32], rows: Range<usize>, out: &mut [f32]) {
    ops::matmul_rows(x, w, rows, out);
    add_bias_rows(b, w.cols(), out);
}

/// [`forward_rows`] with a caller-cached `w_t = w.transpose()` — bitwise
/// identical, but the narrow-B path reads the cache instead of
/// transposing per shard per step.
pub fn forward_rows_bt(
    x: &Matrix,
    w: &Matrix,
    w_t: &Matrix,
    b: &[f32],
    rows: Range<usize>,
    out: &mut [f32],
) {
    ops::matmul_rows_bt(x, w, w_t, rows, out);
    add_bias_rows(b, w.cols(), out);
}

/// Broadcast bias add over a `rows × p` block, 8-lane body per row.
#[inline]
fn add_bias_rows(b: &[f32], p: usize, out: &mut [f32]) {
    assert_eq!(b.len(), p);
    for orow in out.chunks_exact_mut(p) {
        for (v, &bias) in orow.iter_mut().zip(b.iter()) {
            *v += bias;
        }
    }
}

/// Memory folding (alg. lines 3-4) for a row range:
/// `out[r] = scale * src[r] + mem[r]` — the per-element op order matches
/// `src.scale(scale)` + `axpy(1.0, mem)`.
pub fn fold_rows(src: &Matrix, mem: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    fold_block(rows_of(src, rows.clone()), mem, scale, rows, out);
}

/// [`fold_rows`] where the fresh term is already a shard-local block
/// (e.g. the just-computed loss-gradient rows). 8-lane split + tail —
/// elementwise, so the split changes no bits.
pub fn fold_block(
    src_block: &[f32],
    mem: &Matrix,
    scale: f32,
    rows: Range<usize>,
    out: &mut [f32],
) {
    let mem_block = rows_of(mem, rows);
    assert_eq!(src_block.len(), out.len());
    assert_eq!(mem_block.len(), out.len());
    let split = out.len() - out.len() % ops::LANES;
    let (o8, o_tail) = out.split_at_mut(split);
    let (s8, s_tail) = src_block.split_at(split);
    let (m8, m_tail) = mem_block.split_at(split);
    for ((oc, sc), mc) in o8
        .chunks_exact_mut(ops::LANES)
        .zip(s8.chunks_exact(ops::LANES))
        .zip(m8.chunks_exact(ops::LANES))
    {
        for l in 0..ops::LANES {
            oc[l] = scale * sc[l] + mc[l];
        }
    }
    for ((o, &s), &m) in o_tail.iter_mut().zip(s_tail.iter()).zip(m_tail.iter()) {
        *o = scale * s + m;
    }
}

/// Memory-off folding for a row range: `out[r] = scale * src[r]` — the
/// [`fold_rows`] special case with no memory term, so disabled memories
/// fold without ever allocating (or reading) zero matrices.
pub fn scale_rows(src: &Matrix, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    let block = rows_of(src, rows);
    assert_eq!(block.len(), out.len());
    let split = out.len() - out.len() % ops::LANES;
    let (o8, o_tail) = out.split_at_mut(split);
    let (s8, s_tail) = block.split_at(split);
    for (oc, sc) in o8
        .chunks_exact_mut(ops::LANES)
        .zip(s8.chunks_exact(ops::LANES))
    {
        for l in 0..ops::LANES {
            oc[l] = scale * sc[l];
        }
    }
    for (o, &s) in o_tail.iter_mut().zip(s_tail.iter()) {
        *o = scale * s;
    }
}

/// [`fold_rows`] reading a dequant-on-read trace view (§Mixed
/// precision): `out[r] = scale * deq(src[r]) + mem[r]`, with the decode
/// fused into the same 8-lane elementwise loop. The `F32` variant
/// delegates to [`fold_rows`] — bit-identical to the seed path. The
/// decode is a pure per-row function of the stored codes (never of the
/// row range or thread count), so shard position changes no bits.
pub fn fold_trace_rows(
    src: TraceRef<'_>,
    mem: &Matrix,
    scale: f32,
    rows: Range<usize>,
    out: &mut [f32],
) {
    match src {
        TraceRef::F32(m) => fold_rows(m, mem, scale, rows, out),
        TraceRef::Bf16 { cols, codes } => {
            let src_block = &codes[rows.start * cols..rows.end * cols];
            let mem_block = rows_of(mem, rows);
            assert_eq!(src_block.len(), out.len());
            assert_eq!(mem_block.len(), out.len());
            let split = out.len() - out.len() % ops::LANES;
            let (o8, o_tail) = out.split_at_mut(split);
            let (s8, s_tail) = src_block.split_at(split);
            let (m8, m_tail) = mem_block.split_at(split);
            for ((oc, sc), mc) in o8
                .chunks_exact_mut(ops::LANES)
                .zip(s8.chunks_exact(ops::LANES))
                .zip(m8.chunks_exact(ops::LANES))
            {
                for l in 0..ops::LANES {
                    oc[l] = scale * quant::bf16_decode(sc[l]) + mc[l];
                }
            }
            for ((o, &s), &m) in o_tail.iter_mut().zip(s_tail.iter()).zip(m_tail.iter()) {
                *o = scale * quant::bf16_decode(s) + m;
            }
        }
        TraceRef::Q8 { cols, steps, codes } => {
            assert_eq!(out.len(), rows.len() * cols);
            for (local, r) in rows.enumerate() {
                let step = steps[r];
                let crow = &codes[r * cols..(r + 1) * cols];
                let mrow = mem.row(r);
                let orow = &mut out[local * cols..(local + 1) * cols];
                for ((o, &c), &m) in orow.iter_mut().zip(crow.iter()).zip(mrow.iter()) {
                    *o = scale * quant::q8_decode(c, step) + m;
                }
            }
        }
    }
}

/// [`scale_rows`] reading a dequant-on-read trace view:
/// `out[r] = scale * deq(src[r])` — the memory-off fold. `F32`
/// delegates to [`scale_rows`] (bit-identical to the seed path).
pub fn scale_trace_rows(src: TraceRef<'_>, scale: f32, rows: Range<usize>, out: &mut [f32]) {
    match src {
        TraceRef::F32(m) => scale_rows(m, scale, rows, out),
        TraceRef::Bf16 { cols, codes } => {
            let src_block = &codes[rows.start * cols..rows.end * cols];
            assert_eq!(src_block.len(), out.len());
            let split = out.len() - out.len() % ops::LANES;
            let (o8, o_tail) = out.split_at_mut(split);
            let (s8, s_tail) = src_block.split_at(split);
            for (oc, sc) in o8
                .chunks_exact_mut(ops::LANES)
                .zip(s8.chunks_exact(ops::LANES))
            {
                for l in 0..ops::LANES {
                    oc[l] = scale * quant::bf16_decode(sc[l]);
                }
            }
            for (o, &s) in o_tail.iter_mut().zip(s_tail.iter()) {
                *o = scale * quant::bf16_decode(s);
            }
        }
        TraceRef::Q8 { cols, steps, codes } => {
            assert_eq!(out.len(), rows.len() * cols);
            for (local, r) in rows.enumerate() {
                let step = steps[r];
                let crow = &codes[r * cols..(r + 1) * cols];
                let orow = &mut out[local * cols..(local + 1) * cols];
                for (o, &c) in orow.iter_mut().zip(crow.iter()) {
                    *o = scale * quant::q8_decode(c, step);
                }
            }
        }
    }
}

/// Auditor helper (§Mixed precision): add the scaled quantization
/// residual of a trace to a folded block in place —
/// `out[r] += scale * (exact[r] - deq(approx[r]))` — turning a resident
/// `X̂ = scale·deq(x) + mem` into the f32-trace-exact
/// `scale·x + mem` without needing the (already-overwritten) pre-step
/// memory. A no-op for `F32` traces, so all-f32 audits are bit-identical
/// to the seed auditor.
pub fn trace_residual_rows(
    exact: &Matrix,
    approx: TraceRef<'_>,
    scale: f32,
    rows: Range<usize>,
    out: &mut [f32],
) {
    let cols = exact.cols();
    assert_eq!(approx.cols(), cols, "trace vs exact width");
    assert_eq!(out.len(), rows.len() * cols);
    match approx {
        TraceRef::F32(_) => {}
        TraceRef::Bf16 { codes, .. } => {
            let exact_block = rows_of(exact, rows.clone());
            let code_block = &codes[rows.start * cols..rows.end * cols];
            for ((o, &e), &c) in out
                .iter_mut()
                .zip(exact_block.iter())
                .zip(code_block.iter())
            {
                *o += scale * (e - quant::bf16_decode(c));
            }
        }
        TraceRef::Q8 { steps, codes, .. } => {
            for (local, r) in rows.enumerate() {
                let step = steps[r];
                let erow = exact.row(r);
                let crow = &codes[r * cols..(r + 1) * cols];
                let orow = &mut out[local * cols..(local + 1) * cols];
                for ((o, &e), &c) in orow.iter_mut().zip(erow.iter()).zip(crow.iter()) {
                    *o += scale * (e - quant::q8_decode(c, step));
                }
            }
        }
    }
}

/// Shard-encode a just-computed exact block into a trace's code rows
/// (the quantize-on-write half of the mixed-precision trace): `block`
/// holds the shard's exact activations, `codes` the matching code
/// sub-slice. Pure per-element encode — sharded and serial encodes
/// produce the same codes.
pub fn encode_trace_rows_bf16(block: &[f32], codes: &mut [u16]) {
    quant::bf16_encode_block(block, codes);
}

/// The q8 half of [`encode_trace_rows_bf16`]: per-row symmetric scales
/// into `steps` (one per block row), codes into `codes`.
pub fn encode_trace_rows_q8(block: &[f32], cols: usize, steps: &mut [f32], codes: &mut [i8]) {
    assert!(cols > 0 && block.len() % cols == 0);
    assert_eq!(block.len(), codes.len());
    assert_eq!(steps.len(), block.len() / cols);
    for ((srow, crow), st) in block
        .chunks_exact(cols)
        .zip(codes.chunks_exact_mut(cols))
        .zip(steps.iter_mut())
    {
        *st = quant::q8_encode_row(srow, crow);
    }
}

/// Policy scores for a shard: `out[r] = ||xhat[r]|| * ||ghat[r]||` over
/// the block-local rows (`xhat` is `rows × n`, `ghat` is `rows × p`).
/// Same per-row ops as `ops::norm_product_scores` (8-lane dot).
pub fn score_rows(xhat: &[f32], ghat: &[f32], n: usize, p: usize, out: &mut [f32]) {
    let rows = out.len();
    assert_eq!(xhat.len(), rows * n);
    assert_eq!(ghat.len(), rows * p);
    for ((o, xr), gr) in out
        .iter_mut()
        .zip(xhat.chunks_exact(n))
        .zip(ghat.chunks_exact(p))
    {
        *o = ops::dot(xr, xr).sqrt() * ops::dot(gr, gr).sqrt();
    }
}

/// [`score_rows`] under an accumulation mode: the row-norm dots run
/// with f64 or Kahan-compensated lanes (`tensor::ops::dot_acc`).
/// `AccumMode::F32` is bit-identical to [`score_rows`].
pub fn score_rows_acc(
    xhat: &[f32],
    ghat: &[f32],
    n: usize,
    p: usize,
    out: &mut [f32],
    mode: AccumMode,
) {
    if mode == AccumMode::F32 {
        return score_rows(xhat, ghat, n, p, out);
    }
    let rows = out.len();
    assert_eq!(xhat.len(), rows * n);
    assert_eq!(ghat.len(), rows * p);
    for ((o, xr), gr) in out
        .iter_mut()
        .zip(xhat.chunks_exact(n))
        .zip(ghat.chunks_exact(p))
    {
        *o = ops::dot_acc(xr, xr, mode).sqrt() * ops::dot_acc(gr, gr, mode).sqrt();
    }
}

/// Column sums of a shard-local block (`rows × cols`), accumulated in
/// row order — the shard partial of `Matrix::col_sums`. Allocating
/// wrapper over [`col_sums_rows_into`].
pub fn col_sums_rows(block: &[f32], cols: usize) -> Vec<f32> {
    // lint: allow(hot-path-alloc) allocating wrapper; the step path runs col_sums_rows_into on workspace buffers
    let mut out = vec![0.0f32; cols];
    col_sums_rows_into(block, cols, &mut out);
    out
}

/// [`col_sums_rows`] into a caller-owned buffer (zeroed first) — the
/// workspace path. Per-column accumulation order is identical, so the
/// result is bitwise the same.
pub fn col_sums_rows_into(block: &[f32], cols: usize, out: &mut [f32]) {
    assert!(cols > 0 && block.len() % cols == 0);
    assert_eq!(out.len(), cols);
    out.fill(0.0);
    for row in block.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
}

/// [`col_sums_rows_into`] under an accumulation mode: widened (f64 or
/// Kahan) per-column accumulators in [`ops::LANES`]-wide column chunks,
/// rows innermost — same fixed accumulation order per column, widened
/// carry. `AccumMode::F32` is bit-identical to [`col_sums_rows_into`].
pub fn col_sums_rows_into_acc(block: &[f32], cols: usize, out: &mut [f32], mode: AccumMode) {
    if mode == AccumMode::F32 {
        return col_sums_rows_into(block, cols, out);
    }
    assert!(cols > 0 && block.len() % cols == 0);
    assert_eq!(out.len(), cols);
    let mut c0 = 0usize;
    while c0 < cols {
        let w = (cols - c0).min(ops::LANES);
        match mode {
            AccumMode::F64 => {
                let mut acc = [0.0f64; ops::LANES];
                for row in block.chunks_exact(cols) {
                    for l in 0..w {
                        acc[l] += row[c0 + l] as f64;
                    }
                }
                for l in 0..w {
                    out[c0 + l] = acc[l] as f32;
                }
            }
            AccumMode::Kahan => {
                let mut acc = [0.0f32; ops::LANES];
                let mut comp = [0.0f32; ops::LANES];
                for row in block.chunks_exact(cols) {
                    for l in 0..w {
                        let y = row[c0 + l] - comp[l];
                        let t = acc[l] + y;
                        comp[l] = (t - acc[l]) - y;
                        acc[l] = t;
                    }
                }
                for l in 0..w {
                    out[c0 + l] = acc[l];
                }
            }
            AccumMode::F32 => unreachable!(),
        }
        c0 += w;
    }
}

/// Memory retention (alg. lines 8-9) for a row range:
/// `out[r] = keep[r] * src[r]` — the shard twin of `ops::row_scale`.
pub fn keep_rows(src: &Matrix, keep: &[f32], rows: Range<usize>, out: &mut [f32]) {
    let cols = src.cols();
    assert_eq!(out.len(), rows.len() * cols);
    for (local, r) in rows.enumerate() {
        let k = keep[r];
        let orow = &mut out[local * cols..(local + 1) * cols];
        for (o, &s) in orow.iter_mut().zip(src.row(r).iter()) {
            *o = s * k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn row_blocks_are_disjoint_and_cover() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut m = Matrix::from_fn(10, 3, |r, c| (r * 3 + c) as f32);
        let blocks = RowBlocks::of(&mut m, &plan);
        assert_eq!(blocks.len(), 3);
        // SAFETY: one block live at a time (sequential loop)
        unsafe {
            assert_eq!(blocks.block(0).len(), 12);
            assert_eq!(blocks.block(2).len(), 6); // short tail block
            // write through every block, then check the matrix saw it all
            for i in 0..blocks.len() {
                for v in blocks.block(i).iter_mut() {
                    *v += 100.0;
                }
            }
        }
        drop(blocks);
        assert!(m.data().iter().all(|&v| v >= 100.0));
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn row_blocks_reject_out_of_range_index() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut m = Matrix::zeros(10, 3);
        let blocks = RowBlocks::of(&mut m, &plan);
        // SAFETY: single access
        unsafe {
            blocks.block(3);
        }
    }

    #[test]
    fn forward_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(0);
        for (m, n, p) in [(20, 8, 3), (64, 784, 10), (7, 40, 2)] {
            let x = randm(&mut rng, m, n);
            let w = randm(&mut rng, n, p);
            let wt = w.transpose();
            let b: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
            let serial = x.matmul(&w).add_row_broadcast(&b);
            let plan = ShardPlan::with_granularity(m, 6);
            let mut out = Matrix::zeros(m, p);
            let mut out_bt = Matrix::zeros(m, p);
            for (i, range) in plan.iter().enumerate() {
                let blocks = RowBlocks::of(&mut out, &plan);
                // SAFETY: one block live at a time
                let blk = unsafe { blocks.block(i) };
                forward_rows(&x, &w, &b, range.clone(), blk);
                let blocks_bt = RowBlocks::of(&mut out_bt, &plan);
                // SAFETY: one block live at a time
                let blk_bt = unsafe { blocks_bt.block(i) };
                forward_rows_bt(&x, &w, &wt, &b, range, blk_bt);
            }
            assert_eq!(out.data(), serial.data(), "({m},{n},{p})");
            assert_eq!(out_bt.data(), serial.data(), "({m},{n},{p}) cached wt");
        }
    }

    #[test]
    fn fold_rows_matches_memory_fold_bitwise() {
        use crate::aop::memory::MemoryState;
        let mut rng = Rng::new(1);
        let (m, n, p) = (18, 5, 2);
        let mut ms = MemoryState::new(m, n, p, true);
        ms.mem_x = randm(&mut rng, m, n);
        ms.mem_g = randm(&mut rng, m, p);
        let x = randm(&mut rng, m, n);
        let g = randm(&mut rng, m, p);
        let eta = 0.04f32;
        let (xhat, ghat) = ms.fold(&x, &g, eta);
        let se = eta.sqrt();
        let plan = ShardPlan::with_granularity(m, 7);
        let mut xh = Matrix::zeros(m, n);
        let mut gh = Matrix::zeros(m, p);
        for (i, range) in plan.iter().enumerate() {
            let xb = RowBlocks::of(&mut xh, &plan);
            // SAFETY: one block live at a time
            fold_rows(&x, &ms.mem_x, se, range.clone(), unsafe { xb.block(i) });
            let gb = RowBlocks::of(&mut gh, &plan);
            // SAFETY: one block live at a time
            fold_block(rows_of(&g, range.clone()), &ms.mem_g, se, range, unsafe {
                gb.block(i)
            });
        }
        assert_eq!(xh.data(), xhat.data());
        assert_eq!(gh.data(), ghat.data());
    }

    #[test]
    fn scale_rows_matches_scale_bitwise() {
        let mut rng = Rng::new(9);
        let src = randm(&mut rng, 14, 5);
        let serial = src.scale(0.3);
        let plan = ShardPlan::with_granularity(14, 6);
        let mut out = Matrix::zeros(14, 5);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            // SAFETY: one block live at a time
            scale_rows(&src, 0.3, range, unsafe { blocks.block(i) });
        }
        assert_eq!(out.data(), serial.data());
    }

    #[test]
    fn score_rows_matches_serial_bitwise() {
        let mut rng = Rng::new(2);
        let (m, n, p) = (23, 9, 4);
        let xhat = randm(&mut rng, m, n);
        let ghat = randm(&mut rng, m, p);
        let serial = ops::norm_product_scores(&xhat, &ghat);
        let plan = ShardPlan::with_granularity(m, 5);
        let mut scores = vec![0.0f32; m];
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of_slice(&mut scores, 1, &plan);
            // SAFETY: one block live at a time
            let blk = unsafe { blocks.block(i) };
            score_rows(
                rows_of(&xhat, range.clone()),
                rows_of(&ghat, range.clone()),
                n,
                p,
                blk,
            );
        }
        assert_eq!(scores, serial);
    }

    #[test]
    fn col_sums_partials_cover_col_sums() {
        let mut rng = Rng::new(3);
        let g = randm(&mut rng, 16, 3);
        // single full-range partial == serial col_sums exactly
        let full = col_sums_rows(rows_of(&g, 0..16), 3);
        assert_eq!(full, g.col_sums());
        // the _into form is bitwise the same (and zeroes stale contents)
        let mut buf = vec![f32::NAN; 3];
        col_sums_rows_into(rows_of(&g, 0..16), 3, &mut buf);
        assert_eq!(buf, full);
        // split partials sum to the same within f32 grouping tolerance
        let a = col_sums_rows(rows_of(&g, 0..9), 3);
        let b = col_sums_rows(rows_of(&g, 9..16), 3);
        for c in 0..3 {
            assert!((a[c] + b[c] - full[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn keep_rows_matches_row_scale_bitwise() {
        let mut rng = Rng::new(4);
        let src = randm(&mut rng, 12, 6);
        let keep: Vec<f32> = (0..12).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let serial = ops::row_scale(&src, &keep);
        let plan = ShardPlan::with_granularity(12, 5);
        let mut out = Matrix::zeros(12, 6);
        for (i, range) in plan.iter().enumerate() {
            let blocks = RowBlocks::of(&mut out, &plan);
            // SAFETY: one block live at a time
            keep_rows(&src, &keep, range, unsafe { blocks.block(i) });
        }
        assert_eq!(out.data(), serial.data());
    }

    #[test]
    fn generic_row_blocks_split_code_buffers() {
        let plan = ShardPlan::with_granularity(10, 4);
        let mut codes = vec![0u16; 10 * 3];
        let blocks = RowBlocks::of_slice(codes.as_mut_slice(), 3, &plan);
        assert_eq!(blocks.len(), 3);
        // SAFETY: one block live at a time (sequential loop)
        unsafe {
            assert_eq!(blocks.block(0).len(), 12);
            assert_eq!(blocks.block(2).len(), 6);
            for i in 0..blocks.len() {
                for v in blocks.block(i).iter_mut() {
                    *v = i as u16 + 1;
                }
            }
        }
        drop(blocks);
        assert!(codes.iter().all(|&v| v > 0));
    }

    /// Quantize a matrix the way the forward trace does (serial).
    fn quantize_q8(m: &Matrix) -> (Vec<f32>, Vec<i8>) {
        let mut steps = vec![0.0f32; m.rows()];
        let mut codes = vec![0i8; m.rows() * m.cols()];
        encode_trace_rows_q8(m.data(), m.cols(), &mut steps, &mut codes);
        (steps, codes)
    }

    #[test]
    fn sharded_q8_encode_matches_serial_bitwise() {
        let mut rng = Rng::new(21);
        let src = randm(&mut rng, 19, 7);
        let (serial_steps, serial_codes) = quantize_q8(&src);
        let plan = ShardPlan::with_granularity(19, 6);
        let mut steps = vec![f32::NAN; 19];
        let mut codes = vec![0i8; 19 * 7];
        for (i, range) in plan.iter().enumerate() {
            let sb = RowBlocks::of_slice(steps.as_mut_slice(), 1, &plan);
            let cb = RowBlocks::of_slice(codes.as_mut_slice(), 7, &plan);
            // SAFETY: one block live at a time per splitter
            let (sblk, cblk) = unsafe { (sb.block(i), cb.block(i)) };
            encode_trace_rows_q8(rows_of(&src, range), 7, sblk, cblk);
        }
        assert_eq!(steps, serial_steps);
        assert_eq!(codes, serial_codes);
    }

    #[test]
    fn trace_fold_f32_view_is_bitwise_fold_rows() {
        let mut rng = Rng::new(22);
        let (m, n) = (17, 6);
        let src = randm(&mut rng, m, n);
        let mem = randm(&mut rng, m, n);
        let plan = ShardPlan::with_granularity(m, 5);
        let mut a = Matrix::zeros(m, n);
        let mut b = Matrix::zeros(m, n);
        for (i, range) in plan.iter().enumerate() {
            let ab = RowBlocks::of(&mut a, &plan);
            // SAFETY: one block live at a time
            fold_rows(&src, &mem, 0.2, range.clone(), unsafe { ab.block(i) });
            let bb = RowBlocks::of(&mut b, &plan);
            // SAFETY: one block live at a time
            fold_trace_rows(TraceRef::F32(&src), &mem, 0.2, range.clone(), unsafe {
                bb.block(i)
            });
        }
        assert_eq!(a.data(), b.data());
        // scale (memory-off) twin
        let mut c = Matrix::zeros(m, n);
        let mut d = Matrix::zeros(m, n);
        for (i, range) in plan.iter().enumerate() {
            let cb = RowBlocks::of(&mut c, &plan);
            // SAFETY: one block live at a time
            scale_rows(&src, 0.2, range.clone(), unsafe { cb.block(i) });
            let db = RowBlocks::of(&mut d, &plan);
            // SAFETY: one block live at a time
            scale_trace_rows(TraceRef::F32(&src), 0.2, range, unsafe { db.block(i) });
        }
        assert_eq!(c.data(), d.data());
    }

    #[test]
    fn trace_fold_quantized_views_match_dequantized_reference() {
        let mut rng = Rng::new(23);
        let (m, n) = (13, 9);
        let src = randm(&mut rng, m, n);
        let mem = randm(&mut rng, m, n);
        let se = 0.22f32;
        let (steps, codes) = quantize_q8(&src);
        let bcodes: Vec<u16> = src.data().iter().map(|&v| quant::bf16_encode(v)).collect();
        for (tr, max_err) in [
            (TraceRef::Bf16 { cols: n, codes: &bcodes }, 1.0 / 256.0),
            (TraceRef::Q8 { cols: n, steps: &steps, codes: &codes }, 1.0 / 254.0),
        ] {
            let mut out = vec![f32::NAN; m * n];
            fold_trace_rows(tr, &mem, se, 0..m, &mut out);
            for r in 0..m {
                let row_scale = src.row(r).iter().fold(0.0f32, |a, v| a.max(v.abs()));
                for c in 0..n {
                    // fold of the decoded value, exactly
                    let exact_of_deq = se * tr.at(r, c) + mem[(r, c)];
                    assert_eq!(out[r * n + c], exact_of_deq, "({r},{c})");
                    // and the decoded value is within the codec bound
                    let drift = (out[r * n + c] - (se * src[(r, c)] + mem[(r, c)])).abs();
                    assert!(drift <= se * row_scale.max(src[(r, c)].abs()) * max_err * 1.01);
                }
            }
            // residual correction recovers the exact fold to f32 tolerance
            let mut fixed = out.clone();
            trace_residual_rows(&src, tr, se, 0..m, &mut fixed);
            for r in 0..m {
                for c in 0..n {
                    let exact = se * src[(r, c)] + mem[(r, c)];
                    assert!((fixed[r * n + c] - exact).abs() <= 1e-6 + exact.abs() * 1e-6);
                }
            }
        }
        // the F32 view's residual is a strict no-op
        let mut out = vec![7.0f32; m * n];
        trace_residual_rows(&src, TraceRef::F32(&src), se, 0..m, &mut out);
        assert!(out.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn widened_score_and_col_sum_variants() {
        let mut rng = Rng::new(24);
        let (m, n, p) = (15, 33, 5);
        let xhat = randm(&mut rng, m, n);
        let ghat = randm(&mut rng, m, p);
        let mut base = vec![0.0f32; m];
        score_rows(rows_of(&xhat, 0..m), rows_of(&ghat, 0..m), n, p, &mut base);
        let mut acc = vec![0.0f32; m];
        score_rows_acc(
            rows_of(&xhat, 0..m),
            rows_of(&ghat, 0..m),
            n,
            p,
            &mut acc,
            AccumMode::F32,
        );
        assert_eq!(base, acc, "F32 dispatch is bitwise the seed kernel");
        for mode in [AccumMode::F64, AccumMode::Kahan] {
            score_rows_acc(
                rows_of(&xhat, 0..m),
                rows_of(&ghat, 0..m),
                n,
                p,
                &mut acc,
                mode,
            );
            for r in 0..m {
                assert!((acc[r] - base[r]).abs() <= 1e-4 * (1.0 + base[r].abs()), "{mode:?}");
            }
        }
        let g = randm(&mut rng, 40, 11);
        let mut cs = vec![0.0f32; 11];
        col_sums_rows_into_acc(rows_of(&g, 0..40), 11, &mut cs, AccumMode::F32);
        assert_eq!(cs, g.col_sums(), "F32 dispatch is bitwise the seed kernel");
        for mode in [AccumMode::F64, AccumMode::Kahan] {
            col_sums_rows_into_acc(rows_of(&g, 0..40), 11, &mut cs, mode);
            // f64 column sums, rounded once
            for c in 0..11 {
                let refd: f64 = (0..40).map(|r| g[(r, c)] as f64).sum();
                assert!((cs[c] as f64 - refd).abs() <= 1e-5 * (1.0 + refd.abs()), "{mode:?}");
            }
        }
    }
}
