//! Persistent worker pool for scoped shard dispatch.
//!
//! [`ExecPool`] bridges the gap between the long-lived
//! [`util::pool::TaskPool`](crate::util::pool::TaskPool) (whose tasks
//! must be `'static`) and per-step shard closures that borrow the step's
//! matrices: a [`ShardJob`] carries a lifetime-erased pointer to the
//! caller's closure plus a completion latch, and [`ExecPool::run`] blocks
//! until every shard has finished — so the borrow provably outlives every
//! use. This is the same contract `std::thread::scope` provides, but
//! without respawning OS threads on every dispatch (a training step
//! dispatches twice — `fwd_score` and `apply` — and thread spawn latency
//! would eat the speedup on the paper's small shapes).
//!
//! Shards are claimed dynamically (atomic counter), so which *thread*
//! runs which shard varies run to run; determinism comes from the shard
//! *grid* being fixed (`exec::plan`) and results being combined in shard
//! order (`exec::reduce`), never from scheduling.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::pool::TaskPool;

/// Worker pool executing indexed shard tasks with `threads` total compute
/// threads (the calling thread participates; `threads - 1` pool workers
/// are spawned). `threads <= 1` spawns nothing and runs inline — the
/// serial path is literally the same code minus the dispatch.
pub struct ExecPool {
    workers: Option<TaskPool>,
    threads: usize,
}

impl ExecPool {
    pub fn new(threads: usize) -> ExecPool {
        let threads = threads.max(1);
        let workers = if threads > 1 {
            Some(TaskPool::new("exec", threads - 1))
        } else {
            None
        };
        ExecPool { workers, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n_tasks`, potentially in parallel;
    /// returns only after every invocation has completed. Each index is
    /// claimed exactly once. A panic inside `f` is re-raised here after
    /// the remaining shards finish.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let Some(pool) = &self.workers else {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        };
        if n_tasks == 1 {
            f(0);
            return;
        }
        let job = Arc::new(ShardJob::new(f, n_tasks));
        // one runner per spare thread, never more than could claim a task
        let runners = (self.threads - 1).min(n_tasks - 1);
        for _ in 0..runners {
            let j = job.clone();
            // submit can only fail after shutdown; the caller's drain
            // below completes every task itself in that case
            let _ = pool.submit(move || j.drain());
        }
        {
            // Workers hold a pointer into this stack frame: we must not
            // return — or unwind past here — before every shard is done.
            // The guard waits on drop, so even a panic inside the
            // caller-thread drain below parks until the workers finish.
            let _wait = WaitGuard { job: &job };
            job.drain(); // the calling thread works too
        }
        if job.panicked.load(Ordering::SeqCst) {
            panic!("exec shard task panicked");
        }
    }
}

/// One dispatched batch of shard tasks. Holds a lifetime-erased pointer
/// to the caller's closure; see the safety argument on [`ShardJob::new`].
struct ShardJob {
    /// Points at the caller's `&dyn Fn(usize) + Sync`, valid until
    /// `wait()` observes `done == n`.
    f: *const (dyn Fn(usize) + Sync + 'static),
    n: usize,
    next: AtomicUsize,
    done: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

// SAFETY: the raw pointer is only dereferenced by `drain`, and only for
// claimed indices `< n`; `ExecPool::run` keeps the pointee alive (and the
// `Sync` bound makes shared calls sound) until `wait()` confirms all `n`
// completions. Runners that outlive the batch (queued but executed after
// the tasks ran out) observe `next >= n` and never touch the pointer.
unsafe impl Send for ShardJob {}
unsafe impl Sync for ShardJob {}

impl ShardJob {
    fn new(f: &(dyn Fn(usize) + Sync), n: usize) -> ShardJob {
        // SAFETY (lifetime erasure): `ExecPool::run` does not return until
        // every task completed, so the borrow outlives every dereference.
        let f = unsafe {
            std::mem::transmute::<
                *const (dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(f as *const _)
        };
        ShardJob {
            f,
            n,
            next: AtomicUsize::new(0),
            done: Mutex::new(0),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    /// Claim and execute tasks until none remain.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            // the guard records completion even if `f` unwinds, so
            // `wait()` can never deadlock on a panicked shard
            let guard = CompletionGuard { job: self };
            // SAFETY: i < n, so the batch is still live (see struct docs).
            let f = unsafe { &*self.f };
            f(i);
            drop(guard);
        }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while *done < self.n {
            done = self.cv.wait(done).unwrap();
        }
    }
}

/// Blocks on drop until every task of the batch completed — the borrow
/// safety backstop of [`ExecPool::run`].
struct WaitGuard<'a> {
    job: &'a ShardJob,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.job.wait();
    }
}

struct CompletionGuard<'a> {
    job: &'a ShardJob,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.job.panicked.store(true, Ordering::SeqCst);
        }
        let mut done = self.job.done.lock().unwrap();
        *done += 1;
        if *done == self.job.n {
            self.job.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pool_runs_inline_in_order() {
        let pool = ExecPool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(5, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn parallel_pool_claims_each_task_exactly_once() {
        let pool = ExecPool::new(4);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(97, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn pool_is_reusable_across_dispatches() {
        let pool = ExecPool::new(3);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.run(10, &|i| {
                sum.fetch_add(i + round, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 45 + 10 * round);
        }
    }

    #[test]
    fn borrowed_state_is_written_before_run_returns() {
        let pool = ExecPool::new(4);
        let slots: Vec<Mutex<Option<usize>>> = (0..40).map(|_| Mutex::new(None)).collect();
        pool.run(40, &|i| {
            // a little uneven work so threads interleave
            let spin = (i % 5) * 10;
            let mut acc = 0usize;
            for k in 0..spin {
                acc = acc.wrapping_add(std::hint::black_box(k));
            }
            *slots[i].lock().unwrap() = Some(i + acc.min(0));
        });
        for (i, s) in slots.iter().enumerate() {
            assert_eq!(s.lock().unwrap().unwrap(), i);
        }
    }

    #[test]
    fn zero_and_single_task_edge_cases() {
        let pool = ExecPool::new(4);
        pool.run(0, &|_| panic!("must not be called"));
        let hit = AtomicUsize::new(0);
        pool.run(1, &|i| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic] // message depends on which thread hit the bad shard
    fn shard_panic_propagates_to_caller() {
        let pool = ExecPool::new(2);
        pool.run(8, &|i| {
            if i == 3 {
                panic!("shard blew up");
            }
        });
    }
}
