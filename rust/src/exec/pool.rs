//! Persistent worker pool for scoped shard dispatch.
//!
//! [`ExecPool`] owns `threads - 1` dedicated workers parked on a condvar
//! and a single *job slot*: [`ExecPool::run`] installs a lifetime-erased
//! pointer to the caller's closure plus the shard count, wakes the
//! workers, participates in the drain itself, and blocks until every
//! shard has completed — so the borrow provably outlives every use. This
//! is the same contract `std::thread::scope` provides, but without
//! respawning OS threads on every dispatch, and (unlike the previous
//! `TaskPool`-backed design) **without any per-dispatch heap
//! allocation**: no `Arc`'d job, no boxed runner tasks — a training step
//! dispatches a dozen times and the steady state must stay at zero
//! allocations (§Perf pass, asserted by `benches/kernels.rs`).
//!
//! Shard indices are claimed under the job mutex (a shard is ≥16 rows of
//! real math, so one uncontended lock per claim is noise), which makes
//! the claim and the epoch check atomic: a worker that wakes late —
//! even after the job it slept through has been fully drained and a new
//! one installed — can never claim an index against a stale closure
//! pointer. Which *thread* runs which shard still varies run to run;
//! determinism comes from the shard *grid* being fixed (`exec::plan`)
//! and results being combined in shard order (`exec::reduce`), never
//! from scheduling.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Lifetime-erased shard closure pointer. Only dereferenced for indices
/// claimed while the installing [`ExecPool::run`] call is still blocked
/// (see the safety argument there), and the `Sync` bound on the pointee
/// makes shared calls sound.
#[derive(Clone, Copy)]
struct RawFn(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointer is produced from a `&(dyn Fn(usize) + Sync)` whose
// referent outlives every dereference (ExecPool::run blocks until
// `done == n`), and the pointee is `Sync`, so sharing the pointer across
// worker threads is sound.
unsafe impl Send for RawFn {}

/// The single job slot all dispatches go through, guarded by one mutex.
struct JobState {
    /// Monotonic dispatch counter; a worker's claims are valid only while
    /// its snapshot matches.
    epoch: u64,
    /// The active closure, `None` between dispatches.
    f: Option<RawFn>,
    /// Shard count of the active job.
    n: usize,
    /// Next unclaimed shard index.
    next: usize,
    /// Completed shard count.
    done: usize,
    /// A shard closure panicked (re-raised by `run` after the drain).
    panicked: bool,
    /// Pool is shutting down; workers exit once no work remains.
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers wait here for a new job (or shutdown).
    work_cv: Condvar,
    /// The dispatching caller waits here for `done == n`.
    done_cv: Condvar,
}

/// Worker pool executing indexed shard tasks with `threads` total compute
/// threads (the calling thread participates; `threads - 1` pool workers
/// are spawned). `threads <= 1` spawns nothing and runs inline — the
/// serial path is literally the same code minus the dispatch.
pub struct ExecPool {
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Shard dispatches issued over the pool's lifetime (obs counter;
    /// covers the inline serial path too). Two relaxed atomic ops per
    /// dispatch — allocation-free and invisible to the math.
    dispatches: AtomicU64,
    /// Dispatches currently executing (0 or 1 per owning trainer; a
    /// shared Executor can momentarily show more while calls queue on
    /// the job slot).
    active: AtomicUsize,
}

/// RAII decrement for [`ExecPool::active`]: keeps the gauge honest even
/// when a shard panic unwinds out of `run`.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl ExecPool {
    pub fn new(threads: usize) -> ExecPool {
        let threads = threads.max(1);
        if threads == 1 {
            return ExecPool {
                shared: None,
                handles: Vec::new(),
                threads,
                dispatches: AtomicU64::new(0),
                active: AtomicUsize::new(0),
            };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                f: None,
                n: 0,
                next: 0,
                done: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("exec-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning exec worker")
            })
            .collect();
        ExecPool {
            shared: Some(shared),
            handles,
            threads,
            dispatches: AtomicU64::new(0),
            active: AtomicUsize::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total non-empty dispatches issued through [`ExecPool::run`].
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Dispatches executing right now (metrics gauge).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Run `f(i)` for every `i in 0..n_tasks`, potentially in parallel;
    /// returns only after every invocation has completed. Each index is
    /// claimed exactly once. A panic inside `f` is re-raised here after
    /// the remaining shards finish. Allocation-free in steady state.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.active.fetch_add(1, Ordering::Relaxed);
        let _active = ActiveGuard(&self.active);
        let Some(sh) = &self.shared else {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        };
        if n_tasks == 1 {
            f(0);
            return;
        }
        // SAFETY (lifetime erasure): this function does not return until
        // `done == n_tasks` (the wait below runs even if the caller's own
        // drain panicked — see `drain`'s catch), so the borrow outlives
        // every dereference; stale workers cannot claim against it after
        // that because claims are epoch-checked under the same lock that
        // installs jobs.
        let raw = RawFn(unsafe {
            std::mem::transmute::<
                *const (dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(f as *const _)
        });
        let epoch;
        {
            let mut st = sh.state.lock().unwrap();
            // Concurrent dispatches on a shared Executor serialize here:
            // the slot holds one job at a time, and it is freed (f =
            // None, work_cv notified) only after every shard of the
            // previous dispatch completed — so no dispatch can clobber
            // another's job or steal its completion count.
            while st.f.is_some() {
                st = sh.work_cv.wait(st).unwrap();
            }
            st.epoch += 1;
            epoch = st.epoch;
            st.f = Some(raw);
            st.n = n_tasks;
            st.next = 0;
            st.done = 0;
            st.panicked = false;
        }
        sh.work_cv.notify_all();
        // the calling thread works too
        drain(sh, raw, epoch);
        // wait for the stragglers, then free the slot (waking any
        // dispatcher queued on it — workers woken spuriously re-check
        // their condition and go back to sleep)
        let panicked = {
            let mut st = sh.state.lock().unwrap();
            while st.done < st.n {
                st = sh.done_cv.wait(st).unwrap();
            }
            st.f = None;
            st.panicked
        };
        sh.work_cv.notify_all();
        if panicked {
            panic!("exec shard task panicked");
        }
    }
}

/// Claim-and-execute loop shared by the caller and the workers. Claims
/// happen under the job lock and are epoch-checked, so a participant can
/// never execute an index of a job it did not snapshot.
fn drain(sh: &Shared, raw: RawFn, epoch: u64) {
    loop {
        let i = {
            let mut st = sh.state.lock().unwrap();
            if st.epoch != epoch || st.next >= st.n {
                return;
            }
            let i = st.next;
            st.next += 1;
            i
        };
        // SAFETY: `i` was claimed while `epoch` was current, so the
        // installing `run` is still blocked and the pointee is alive.
        let f = unsafe { &*raw.0 };
        // catch so one bad shard cannot leave `done` short and deadlock
        // the dispatcher; `run` re-raises after the drain completes.
        let ok = std::panic::catch_unwind(AssertUnwindSafe(|| f(i))).is_ok();
        let mut st = sh.state.lock().unwrap();
        if !ok {
            st.panicked = true;
        }
        st.done += 1;
        if st.done == st.n {
            sh.done_cv.notify_all();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let (raw, epoch) = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if let Some(raw) = st.f {
                    if st.next < st.n {
                        break (raw, st.epoch);
                    }
                }
                if st.shutdown {
                    return;
                }
                st = sh.work_cv.wait(st).unwrap();
            }
        };
        drain(sh, raw, epoch);
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        if let Some(sh) = &self.shared {
            sh.state.lock().unwrap().shutdown = true;
            sh.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline_in_order() {
        let pool = ExecPool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(5, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn parallel_pool_claims_each_task_exactly_once() {
        let pool = ExecPool::new(4);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(97, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn dispatch_counter_counts_both_paths_and_gauge_settles() {
        for threads in [1, 3] {
            let pool = ExecPool::new(threads);
            assert_eq!(pool.dispatches(), 0);
            pool.run(0, &|_| panic!("empty dispatch must not count or run"));
            assert_eq!(pool.dispatches(), 0, "threads={threads}");
            for _ in 0..7 {
                pool.run(4, &|_| {});
            }
            assert_eq!(pool.dispatches(), 7, "threads={threads}");
            assert_eq!(pool.active(), 0, "threads={threads}");
            // the gauge recovers even when a shard panics out of run()
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.run(4, &|i| {
                    if i == 1 {
                        panic!("boom");
                    }
                })
            }));
            assert!(r.is_err());
            assert_eq!(pool.active(), 0, "threads={threads}");
            assert_eq!(pool.dispatches(), 8, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reusable_across_dispatches() {
        let pool = ExecPool::new(3);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.run(10, &|i| {
                sum.fetch_add(i + round, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 45 + 10 * round);
        }
    }

    #[test]
    fn borrowed_state_is_written_before_run_returns() {
        let pool = ExecPool::new(4);
        let slots: Vec<Mutex<Option<usize>>> = (0..40).map(|_| Mutex::new(None)).collect();
        pool.run(40, &|i| {
            // a little uneven work so threads interleave
            let spin = (i % 5) * 10;
            let mut acc = 0usize;
            for k in 0..spin {
                acc = acc.wrapping_add(std::hint::black_box(k));
            }
            *slots[i].lock().unwrap() = Some(i + acc.min(0));
        });
        for (i, s) in slots.iter().enumerate() {
            assert_eq!(s.lock().unwrap().unwrap(), i);
        }
    }

    #[test]
    fn zero_and_single_task_edge_cases() {
        let pool = ExecPool::new(4);
        pool.run(0, &|_| panic!("must not be called"));
        let hit = AtomicUsize::new(0);
        pool.run(1, &|i| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "exec shard task panicked")]
    fn shard_panic_propagates_to_caller() {
        let pool = ExecPool::new(2);
        pool.run(8, &|i| {
            if i == 3 {
                panic!("shard blew up");
            }
        });
    }

    #[test]
    fn concurrent_dispatches_on_shared_pool_serialize() {
        // two threads hammering one pool: the job slot must serialize
        // them so every dispatch runs all of its own shards
        let pool = ExecPool::new(3);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..25 {
                    pool.run(8, &|i| {
                        a.fetch_add(i + 1, Ordering::Relaxed);
                    });
                }
            });
            for _ in 0..25 {
                pool.run(8, &|i| {
                    b.fetch_add(i + 1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(a.load(Ordering::Relaxed), 25 * 36);
        assert_eq!(b.load(Ordering::Relaxed), 25 * 36);
    }

    #[test]
    fn pool_survives_a_panicked_dispatch() {
        // the job slot must be cleanly recycled after a panicked run
        let pool = ExecPool::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(6, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            })
        }));
        assert!(r.is_err());
        let sum = AtomicUsize::new(0);
        pool.run(6, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 15);
    }
}
