//! Fixed-order reduction of per-shard partials.
//!
//! f32 addition is not associative, so the *grouping* of a reduction is
//! part of its definition. Everything here combines shard partials in
//! ascending shard order on a single thread — together with the
//! thread-count-independent grid of `exec::plan`, that makes every
//! reduced quantity a pure function of the inputs, identical at any
//! parallelism.
//!
//! Since the §Perf-pass workspace refactor, the *per-step* reductions
//! (bias gradients, AOP weight partials) run as in-place fixed-order
//! loops over workspace buffers inside `train::step` — the historical
//! `sum_vecs`/`sum_matrices` helpers they replaced are gone so the
//! determinism-critical reduction has exactly one live definition.
//! What remains here are the scalar reducers the evaluation path uses.

/// Sum scalars in iteration (= shard) order.
pub fn sum_f32(parts: impl IntoIterator<Item = f32>) -> f32 {
    let mut acc = 0.0f32;
    for p in parts {
        acc += p;
    }
    acc
}

/// Sum counters (exact in any order, kept here for symmetry).
pub fn sum_usize(parts: impl IntoIterator<Item = usize>) -> usize {
    parts.into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_sum_is_left_to_right() {
        // a grouping-sensitive triple: (1e8 + 1) + -1e8 != 1e8 + (1 + -1e8)
        let parts = [1.0e8f32, 1.0, -1.0e8];
        assert_eq!(sum_f32(parts), ((1.0e8f32 + 1.0) + -1.0e8));
    }

    #[test]
    fn counts_sum() {
        assert_eq!(sum_usize([3usize, 4, 5]), 12);
    }
}
