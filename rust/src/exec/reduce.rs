//! Fixed-order reduction of per-shard partials.
//!
//! f32 addition is not associative, so the *grouping* of a reduction is
//! part of its definition. Everything here combines shard partials in
//! ascending shard order on a single thread — together with the
//! thread-count-independent grid of `exec::plan`, that makes every
//! reduced quantity (losses, bias gradients, AOP weight updates) a pure
//! function of the inputs, identical at any parallelism.

use crate::tensor::Matrix;

/// Sum scalars in iteration (= shard) order.
pub fn sum_f32(parts: impl IntoIterator<Item = f32>) -> f32 {
    let mut acc = 0.0f32;
    for p in parts {
        acc += p;
    }
    acc
}

/// Sum counters (exact in any order, kept here for symmetry).
pub fn sum_usize(parts: impl IntoIterator<Item = usize>) -> usize {
    parts.into_iter().sum()
}

/// Elementwise-sum equal-length vectors in iteration (= shard) order.
pub fn sum_vecs<'a>(len: usize, parts: impl IntoIterator<Item = &'a [f32]>) -> Vec<f32> {
    let mut acc = vec![0.0f32; len];
    for p in parts {
        assert_eq!(p.len(), len, "partial length mismatch");
        for (a, &v) in acc.iter_mut().zip(p.iter()) {
            *a += v;
        }
    }
    acc
}

/// Sum optional shard-partial matrices in iteration (= shard) order into
/// an `rows × cols` accumulator. `None` marks a shard with no
/// contribution (e.g. no selected rows) and is skipped — whether a shard
/// is `None` depends only on the selection, never on scheduling, so
/// skipping is deterministic too.
pub fn sum_matrices(
    rows: usize,
    cols: usize,
    parts: impl IntoIterator<Item = Option<Matrix>>,
) -> Matrix {
    let mut acc = Matrix::zeros(rows, cols);
    for p in parts.into_iter().flatten() {
        acc.axpy(1.0, &p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_sum_is_left_to_right() {
        // a grouping-sensitive triple: (1e8 + 1) + -1e8 != 1e8 + (1 + -1e8)
        let parts = [1.0e8f32, 1.0, -1.0e8];
        assert_eq!(sum_f32(parts), ((1.0e8f32 + 1.0) + -1.0e8));
    }

    #[test]
    fn vec_sum_matches_manual() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        let s = sum_vecs(2, [&a[..], &b[..], &c[..]]);
        assert_eq!(s, vec![111.0, 222.0]);
    }

    #[test]
    fn matrix_sum_skips_none_deterministically() {
        let m1 = Matrix::full(2, 2, 1.0);
        let m2 = Matrix::full(2, 2, 2.0);
        let s = sum_matrices(2, 2, vec![Some(m1.clone()), None, Some(m2.clone())]);
        assert_eq!(s, m1.add(&m2));
        let empty = sum_matrices(2, 2, vec![None, None]);
        assert_eq!(empty, Matrix::zeros(2, 2));
    }

    #[test]
    fn counts_sum() {
        assert_eq!(sum_usize([3usize, 4, 5]), 12);
    }

    #[test]
    #[should_panic(expected = "partial length mismatch")]
    fn vec_sum_rejects_ragged_partials() {
        let a = [1.0f32];
        let b = [1.0f32, 2.0];
        sum_vecs(1, [&a[..], &b[..]]);
    }
}
