//! Deterministic shard plans: how a mini-batch's rows are cut into
//! parallel work units.
//!
//! **The invariant that makes the whole exec subsystem deterministic**:
//! the shard grid is a pure function of the row count — it NEVER depends
//! on the worker/thread count. Every thread count therefore executes the
//! *same* floating-point operations grouped the *same* way; only the
//! assignment of shards to OS threads varies, and the fixed-order
//! reduction (`exec::reduce`) erases that. `threads=7` and `threads=1`
//! produce bit-identical weights by construction, not by tolerance.

use std::ops::Range;

/// Rows per shard. Chosen so the paper's shapes split into enough units
/// to keep 4-8 threads busy (energy M=144 → 9 shards, mnist M=64 → 4)
/// while each unit still amortizes dispatch overhead. Changing this
/// value changes the fixed reduction grouping — and therefore the
/// low-order bits of every curve — so it is a compile-time constant, not
/// a runtime knob.
pub const SHARD_ROWS: usize = 16;

/// A contiguous partition of `rows` into blocks of `granularity` rows
/// (last block may be short).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    rows: usize,
    granularity: usize,
}

impl ShardPlan {
    /// The canonical plan for a row count (fixed [`SHARD_ROWS`] grid).
    pub fn for_rows(rows: usize) -> ShardPlan {
        ShardPlan::with_granularity(rows, SHARD_ROWS)
    }

    /// Custom granularity (tests / benches only — production paths must
    /// share one grid or their bits diverge).
    pub fn with_granularity(rows: usize, granularity: usize) -> ShardPlan {
        assert!(granularity > 0, "shard granularity must be positive");
        ShardPlan { rows, granularity }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn granularity(&self) -> usize {
        self.granularity
    }

    /// Number of shards (0 only for an empty batch).
    pub fn len(&self) -> usize {
        self.rows.div_ceil(self.granularity)
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row range of shard `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        let start = i * self.granularity;
        assert!(start < self.rows, "shard {i} out of range");
        start..(start + self.granularity).min(self.rows)
    }

    /// Shard ranges in shard order.
    pub fn iter(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.len()).map(|i| self.range(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_rows_exactly_once_in_order() {
        for rows in [1usize, 15, 16, 17, 64, 144, 1000] {
            let plan = ShardPlan::for_rows(rows);
            let mut next = 0usize;
            for r in plan.iter() {
                assert_eq!(r.start, next);
                assert!(!r.is_empty());
                assert!(r.len() <= SHARD_ROWS);
                next = r.end;
            }
            assert_eq!(next, rows);
        }
    }

    #[test]
    fn paper_shapes() {
        assert_eq!(ShardPlan::for_rows(144).len(), 9);
        assert_eq!(ShardPlan::for_rows(64).len(), 4);
        assert_eq!(ShardPlan::for_rows(12).len(), 1); // tiny batches: one shard
    }

    #[test]
    fn empty_plan() {
        let p = ShardPlan::for_rows(0);
        assert_eq!(p.len(), 0);
        assert!(p.is_empty());
        assert_eq!(p.iter().count(), 0);
    }

    #[test]
    fn custom_granularity() {
        let p = ShardPlan::with_granularity(10, 4);
        assert_eq!(p.len(), 3);
        assert_eq!(p.range(2), 8..10);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_shard_panics() {
        ShardPlan::for_rows(16).range(1);
    }
}
