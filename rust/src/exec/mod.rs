//! `exec` — deterministic data-parallel execution engine.
//!
//! Shards a mini-batch's rows across worker threads and recombines the
//! results so that **any** thread count produces bit-identical training
//! curves and final weights. The pieces:
//!
//! * [`plan`] — the shard grid: contiguous [`plan::SHARD_ROWS`]-row
//!   blocks, a pure function of the batch size and *never* of the thread
//!   count. This is the determinism keystone: every thread count executes
//!   the same float ops with the same grouping;
//! * [`pool`] — [`ExecPool`], a persistent scoped-dispatch pool with a
//!   single epoch-checked job slot, so per-step dispatch costs a condvar
//!   wake — not a thread spawn, and (§Perf pass) not a single heap
//!   allocation; the serve scheduler keeps the separate generalized
//!   [`util::pool::TaskPool`](crate::util::pool::TaskPool) for its
//!   boxed long-lived jobs;
//! * [`shard`] — row-range kernels (forward, memory folding, scores,
//!   column sums, retention) writing into disjoint borrowed row blocks;
//!   each is bit-identical per row to its serial twin in `tensor::ops`;
//! * [`reduce`] — fixed ascending-shard-order scalar reducers (losses,
//!   counts), single-threaded; the per-step vector/matrix reductions
//!   run as in-place fixed-order loops over workspace buffers in
//!   `train::step` (§Perf pass).
//!
//! What stays on the coordinator thread: the policy decision. Shards
//! compute *scores*; `out_K` selection happens once, globally, from a
//! counter-based RNG stream (`Rng::for_stream`) keyed by (seed, epoch,
//! step) — so stochastic policies select identically at any parallelism,
//! and the selected row set is then filtered per shard for the partial
//! outer products.
//!
//! `AopEngine::step_exec` / `Mlp::train_step_aop_exec` assemble these
//! into full training steps; `ExperimentConfig::threads` (and the serve
//! protocol's `threads` field / `repro train --threads N`) picks the
//! worker count. `rust/tests/exec.rs` asserts bit-identity for
//! `threads ∈ {1, 2, 4, 7}` across every policy, both execution regimes,
//! and through a served job.
//!
//! **One-time re-baselining (deliberate)**: bit-identity across thread
//! counts and bit-identity to the *pre-exec* whole-batch accumulation
//! cannot both hold — f32 addition is non-associative, so a fixed shard
//! grid is itself a (new) grouping, and position-keyed policy streams
//! replace the old sequentially-consumed generator. The serial
//! `threads = 1` path of THIS engine is therefore the definition of
//! "the serial curve" from this version forward; curves recorded by
//! earlier builds re-run under the same seed land at the same quality
//! but not the same bits. Within a build, all determinism guarantees
//! (same seed ⇒ same curve, native ≡ HLO decisions, any `threads`)
//! are exact.

pub mod plan;
pub mod pool;
pub mod reduce;
pub mod shard;

use std::ops::Range;
use std::sync::Mutex;

pub use plan::{ShardPlan, SHARD_ROWS};
pub use pool::ExecPool;

/// Handle tying a worker pool to the canonical shard grid. Cheap to
/// create at `threads == 1` (no threads are spawned); owns `threads - 1`
/// persistent workers otherwise. The engine/trainer holds one for its
/// whole lifetime so per-step dispatch reuses warm threads.
pub struct Executor {
    pool: ExecPool,
}

impl Executor {
    pub fn new(threads: usize) -> Executor {
        Executor {
            pool: ExecPool::new(threads),
        }
    }

    /// Inline executor: same grid, same reductions, zero threads — the
    /// serial reference every parallel run is bit-compared against.
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Shard dispatches issued over this executor's lifetime (obs).
    pub fn dispatches(&self) -> u64 {
        self.pool.dispatches()
    }

    /// Dispatches executing right now (obs gauge).
    pub fn active(&self) -> usize {
        self.pool.active()
    }

    /// The canonical plan for a batch of `rows`.
    pub fn plan(&self, rows: usize) -> ShardPlan {
        ShardPlan::for_rows(rows)
    }

    /// Run `f(shard, rows)` for every shard of `plan`; blocks until all
    /// shards completed.
    pub fn run_each<F>(&self, plan: &ShardPlan, f: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        let call = |i: usize| f(i, plan.range(i));
        self.pool.run(plan.len(), &call);
    }

    /// Run `f(shard, rows)` for every shard and collect the returns in
    /// shard order (ready for `exec::reduce`).
    ///
    /// Allocates the result slots per call — fine for epoch-level work
    /// (evaluation, sweeps); the per-step training hot path uses
    /// [`Executor::run_each`] with workspace-resident partial buffers
    /// instead, keeping steady-state steps allocation-free.
    pub fn map<R, F>(&self, plan: &ShardPlan, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let n = plan.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let call = |i: usize| {
            let r = f(i, plan.range(i));
            *slots[i].lock().unwrap() = Some(r);
        };
        self.pool.run(n, &call);
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("missing shard result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_shard_order() {
        for threads in [1usize, 2, 4, 7] {
            let ex = Executor::new(threads);
            let plan = ShardPlan::with_granularity(100, 9);
            let got = ex.map(&plan, |i, range| (i, range.start, range.end));
            assert_eq!(got.len(), plan.len());
            for (i, (gi, s, e)) in got.iter().enumerate() {
                assert_eq!(*gi, i);
                assert_eq!(*s..*e, plan.range(i));
            }
        }
    }

    #[test]
    fn run_each_sees_every_shard_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ex = Executor::new(4);
        let plan = ShardPlan::with_granularity(33, 4);
        let hits: Vec<AtomicUsize> = (0..plan.len()).map(|_| AtomicUsize::new(0)).collect();
        ex.run_each(&plan, |i, range| {
            assert_eq!(range, plan.range(i));
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_plan_is_a_noop() {
        let ex = Executor::serial();
        let plan = ShardPlan::for_rows(0);
        let got: Vec<u8> = ex.map(&plan, |_, _| panic!("no shards to run"));
        assert!(got.is_empty());
        ex.run_each(&plan, |_, _| panic!("no shards to run"));
    }
}
